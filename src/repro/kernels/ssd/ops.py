"""Public SSD op: dt-weighting, padding, D-skip — kernel-backed."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ssd import CHUNK, ssd_pallas


@functools.partial(jax.jit, static_argnums=(6,))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, D: jax.Array, interpret: bool = True):
    """Mamba-2 SSD, matching repro.layers.ssd.ssd_chunked semantics.

    x (B,S,H,P), dt (B,S,H) positive, A (H,) negative, Bm/Cm (B,S,H,N),
    D (H,).  Returns (y (B,S,H,P), h_last (B,H,N,P) f32)."""
    B, S, H, P = x.shape
    dtf = dt.astype(jnp.float32)
    la = dtf * A.astype(jnp.float32)[None, None, :]
    xw = x.astype(jnp.float32) * dtf[..., None]
    pad = -S % CHUNK
    if pad:
        xw = jnp.pad(xw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, h_last = ssd_pallas(xw.astype(x.dtype), la, Bm, Cm, interpret)
    y = y[:, :S]
    y = y.astype(jnp.float32) + x.astype(jnp.float32) * \
        D.astype(jnp.float32)[None, None, :, None]
    # padded steps: la = 0 -> exp(0)=1 state decay, x = 0 -> no update, so
    # h_last after padding equals h_last at step S.
    return y.astype(x.dtype), h_last
