"""Pallas TPU kernel: Mamba-2 SSD (state-space duality) chunked forward.

Grid: (B, H, num_chunks) — chunks are the sequential minor grid dim; the
(N, P) recurrent state lives in VMEM scratch.  Per chunk, everything is MXU
matmul work (the whole point of SSD):

    G        = (C_q B_q^T) .* decay_mask          (Q x Q)
    y_intra  = G @ X                              (Q x P)
    y_inter  = (C_q @ h) .* decay_in              (Q x P)
    h'       = exp(total) h + (B_q .* w)^T @ X    (N x P)

Inputs are pre-projected (B,S,H,*) tensors (the projections are dense
matmuls XLA already handles); dt-weighting is folded into X by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 128


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, hlast_ref, h_scr):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    la = la_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    Bq = b_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    Cq = c_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    h = h_scr[...]                                  # (N, P)

    cum = jnp.cumsum(la)                            # (Q,)
    total = cum[-1]
    Q = x.shape[0]

    # intra-chunk: decay(t,s) = exp(cum_t - cum_s), s <= t
    dmat = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    dmat = jnp.where(tri, jnp.exp(dmat), 0.0)
    G = jax.lax.dot_general(Cq, Bq, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * dmat
    y = jax.lax.dot_general(G, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: incoming state
    y += jax.lax.dot_general(Cq, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]

    # state update
    w = jnp.exp(total - cum)                        # (Q,)
    dB = Bq * w[:, None]
    h_new = jnp.exp(total) * h + jax.lax.dot_general(
        dB, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    h_scr[...] = h_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        hlast_ref[0, 0] = h_new


@functools.partial(jax.jit, static_argnums=(4,))
def ssd_pallas(x: jax.Array, la: jax.Array, Bm: jax.Array, Cm: jax.Array,
               interpret: bool = True):
    """x: (B,S,H,P) dt-weighted input; la: (B,S,H) per-step log decay;
    Bm/Cm: (B,S,H,N).  S must be a CHUNK multiple (ops pads).
    Returns (y (B,S,H,P) f32-accurate in x.dtype, h_last (B,H,N,P) f32)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % CHUNK == 0, S
    from jax.experimental.pallas import tpu as pltpu

    grid = (B, H, S // CHUNK)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, CHUNK, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, CHUNK, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, CHUNK, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, CHUNK, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, la, Bm, Cm)
