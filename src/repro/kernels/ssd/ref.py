"""Pure-jnp oracle for the SSD kernel: the sequential SSM recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_rec_ref(x, la, Bm, Cm):
    """Sequential recurrence reference.

    x (B,S,H,P) dt-weighted, la (B,S,H) log-decay, Bm/Cm (B,S,H,N).
    h_t = exp(la_t) h_{t-1} + B_t x_t^T ;  y_t = C_t h_t.
    Returns (y (B,S,H,P), h_last (B,H,N,P))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, xs):
        xt, lat, bt, ct = xs
        h = jnp.exp(lat.astype(jnp.float32))[..., None, None] * h + jnp.einsum(
            "bhn,bhp->bhnp", bt.astype(jnp.float32), xt.astype(jnp.float32))
        y = jnp.einsum("bhn,bhnp->bhp", ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_last, ys = jax.lax.scan(
        step, h0,
        (x.transpose(1, 0, 2, 3), la.transpose(1, 0, 2),
         Bm.transpose(1, 0, 2, 3), Cm.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_last
