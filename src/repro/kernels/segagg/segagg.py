"""Pallas TPU kernel: blocked GROUP-BY partial aggregation (the paper's
query-executor hot spot — CQ1..CQ4 / TPC-H COUNT/SUM GROUP BY).

Two formulations of the same segment-sum, selected per call shape by
``ops.segagg`` (see ``tuning.crossover``):

MATMUL (DESIGN.md §2): instead of a hash table (the CPU/Spark formulation —
pointer chasing, no TPU analogue), aggregation is a blocked ONE-HOT MATMUL
on the MXU:

    partial[g, v] = sum_i  [keys_i == g] * values[i, v]

Grid: (num_group_blocks, num_row_blocks).  Each instance builds the
(block_n x block_g) one-hot membership matrix in VMEM from an iota compare
(never in HBM) and contracts it with the (block_n x V) value block on the
MXU, accumulating into the (block_g x V) output block across the row-block
grid dimension (the sequential minor axis on TPU).  Work is O(N·G·V) MXU
FLOPs — cheap for narrow G, quadratic waste for wide G.

SCATTER-ADD: the classic formulation — one sequential pass over the row
block doing ``out[key] += value`` into the full (G, V) accumulator held
on-chip.  Work is O(N·V), independent of G, so it wins once the one-hot's
O(N·G) FLOPs dominate; the price is a serial row loop (VPU, no MXU) and a
resident (G, V) accumulator (must fit VMEM on real hardware — ``ops``
checks before selecting it).

Batches of rows become independent partial aggregates; the paper's "final
aggregation" is then a trivial add over partials (`combine`), whose cost
grows with num_groups x num_batches exactly as the paper's §6.2 model says.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512    # default rows per block
BLOCK_G = 256    # default groups per block (lane-dim multiple of 128)
# value width is padded to the 128-lane MXU boundary by ops.segagg

# VMEM budget for the scatter variant's resident (G, V) accumulator
# (~16 MB/core on TPU; leave headroom for the row block + loop state).
SCATTER_VMEM_BYTES = 8 * 2**20


def _segagg_matmul_kernel(keys_ref, values_ref, out_ref, *, block_g: int):
    gi = pl.program_id(0)
    ni = pl.program_id(1)

    keys = keys_ref[...]                     # (block_n,) int32
    vals = values_ref[...]                   # (block_n, V)

    g0 = gi * block_g
    # (block_n, block_g) one-hot membership, built in VMEM.
    gids = g0 + jax.lax.broadcasted_iota(
        jnp.int32, (keys.shape[0], block_g), 1)
    onehot = (keys[:, None] == gids).astype(vals.dtype)

    # MXU contraction: (block_g, block_n) @ (block_n, V) -> (block_g, V)
    partial = jax.lax.dot_general(
        onehot, vals,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ni == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


def _segagg_scatter_kernel(keys_ref, values_ref, out_ref):
    ni = pl.program_id(0)

    @pl.when(ni == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]                     # (block_n,) int32
    vals = values_ref[...].astype(jnp.float32)

    def body(i, _):
        # out[key_i] += value_i — dynamic single-row accumulate.
        out_ref[pl.ds(keys[i], 1), :] += vals[i][None, :]
        return 0

    jax.lax.fori_loop(0, keys.shape[0], body, 0)


@functools.partial(jax.jit, static_argnums=(2, 3, 4, 5, 6))
def segagg_pallas(keys: jax.Array, values: jax.Array, num_groups: int,
                  interpret: bool = True, block_n: int = BLOCK_N,
                  block_g: int = BLOCK_G,
                  formulation: str = "matmul") -> jax.Array:
    """keys: (N,) int32 in [0, num_groups); values: (N, V) float.
    Returns (num_groups, V) f32 partial aggregate.  N must be a block_n
    multiple; for the matmul formulation num_groups must be a block_g
    multiple (ops.segagg handles padding).  ``formulation`` selects the
    one-hot MXU matmul vs the sequential scatter-add variant."""
    N, V = values.shape
    assert N % block_n == 0, (N, block_n)
    if formulation == "scatter":
        return pl.pallas_call(
            _segagg_scatter_kernel,
            grid=(N // block_n,),
            in_specs=[
                pl.BlockSpec((block_n,), lambda n: (n,)),
                pl.BlockSpec((block_n, V), lambda n: (n, 0)),
            ],
            out_specs=pl.BlockSpec((num_groups, V), lambda n: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((num_groups, V), jnp.float32),
            interpret=interpret,
        )(keys, values)
    assert num_groups % block_g == 0, (num_groups, block_g)
    grid = (num_groups // block_g, N // block_n)
    return pl.pallas_call(
        functools.partial(_segagg_matmul_kernel, block_g=block_g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda g, n: (n,)),
            pl.BlockSpec((block_n, V), lambda g, n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((block_g, V), lambda g, n: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, V), jnp.float32),
        interpret=interpret,
    )(keys, values)
