"""Pallas TPU kernel: blocked GROUP-BY partial aggregation (the paper's
query-executor hot spot — CQ1..CQ4 / TPC-H COUNT/SUM GROUP BY).

TPU adaptation (DESIGN.md §2): instead of a hash table (the CPU/Spark
formulation — pointer chasing, no TPU analogue), aggregation is a blocked
ONE-HOT MATMUL on the MXU:

    partial[g, v] = sum_i  [keys_i == g] * values[i, v]

Grid: (num_group_blocks, num_row_blocks).  Each instance builds the
(BLOCK_N x BLOCK_G) one-hot membership matrix in VMEM from an iota compare
(never in HBM) and contracts it with the (BLOCK_N x V) value block on the
MXU, accumulating into the (BLOCK_G x V) output block across the row-block
grid dimension (the sequential minor axis on TPU).

Batches of rows become independent partial aggregates; the paper's "final
aggregation" is then a trivial add over partials (`combine`), whose cost
grows with num_groups x num_batches exactly as the paper's §6.2 model says.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512    # rows per block
BLOCK_G = 256    # groups per block (lane-dim multiple of 128)
# value width is padded to the 128-lane MXU boundary by ops.segagg


def _segagg_kernel(keys_ref, values_ref, out_ref):
    gi = pl.program_id(0)
    ni = pl.program_id(1)

    keys = keys_ref[...]                     # (BLOCK_N,) int32
    vals = values_ref[...]                   # (BLOCK_N, V)

    g0 = gi * BLOCK_G
    # (BLOCK_N, BLOCK_G) one-hot membership, built in VMEM.
    gids = g0 + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_N, BLOCK_G), 1)
    onehot = (keys[:, None] == gids).astype(vals.dtype)

    # MXU contraction: (BLOCK_G, BLOCK_N) @ (BLOCK_N, V) -> (BLOCK_G, V)
    partial = jax.lax.dot_general(
        onehot, vals,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ni == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnums=(2, 3))
def segagg_pallas(keys: jax.Array, values: jax.Array, num_groups: int,
                  interpret: bool = True) -> jax.Array:
    """keys: (N,) int32 in [0, num_groups); values: (N, V) float.
    Returns (num_groups, V) f32 partial aggregate.  N, V, num_groups must be
    pre-padded to block multiples (ops.segagg handles padding)."""
    N, V = values.shape
    assert N % BLOCK_N == 0 and num_groups % BLOCK_G == 0, (N, num_groups)
    grid = (num_groups // BLOCK_G, N // BLOCK_N)
    return pl.pallas_call(
        _segagg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda g, n: (n,)),
            pl.BlockSpec((BLOCK_N, V), lambda g, n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_G, V), lambda g, n: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, V), jnp.float32),
        interpret=interpret,
    )(keys, values)
