"""Tuned launch parameters for the segagg kernels.

``benchmarks/hillclimb.py --segagg`` measures candidate (block_n, block_g)
pairs and the matmul-vs-scatter crossover per (backend, shape-class) and
persists the winners to ``tuned_blocks.json`` next to this module; the
dispatch layer (``ops.segagg``) reads them at call time.  Shape classes
bucket call shapes coarsely — rows below/above ``_N_SMALL`` x groups
below/above ``_G_NARROW`` — so one tuned entry covers a regime, not an
exact shape (an exact-shape table would never hit on real workloads).

Missing file / missing entry falls back to the compiled-in defaults
(``segagg.BLOCK_N`` / ``segagg.BLOCK_G``, crossover ``DEFAULT_MATMUL_MAX_G``),
so the package works untuned.
"""
from __future__ import annotations

import functools
import json
import pathlib
from typing import Dict, Optional, Tuple

from .segagg import BLOCK_G, BLOCK_N, SCATTER_VMEM_BYTES

TUNED_PATH = pathlib.Path(__file__).resolve().parent / "tuned_blocks.json"

# Shape-class boundaries (rows / groups).
_N_SMALL = 32_768
_G_NARROW = 1_024

# Below this group count the one-hot matmul's O(N·G) FLOPs are cheaper than
# the scatter pass's serial row loop; above it scatter-add wins.  Overridden
# per backend by the tuned table ("crossover" section).
DEFAULT_MATMUL_MAX_G = 256


def shape_class(n: int, g: int) -> str:
    """Coarse (rows x groups) regime bucket: small/large x narrow/wide."""
    rows = "small" if n <= _N_SMALL else "large"
    width = "narrow" if g <= _G_NARROW else "wide"
    return f"{rows}-{width}"


@functools.lru_cache(maxsize=1)
def _load() -> Dict:
    try:
        return json.loads(TUNED_PATH.read_text())
    except (OSError, ValueError):
        return {}


def reload() -> None:
    """Drop the cached table (after hillclimb rewrites the file)."""
    _load.cache_clear()


def tuned_blocks(backend: str, n: int, g: int) -> Tuple[int, int]:
    """(block_n, block_g) for a call shape, tuned entry or defaults."""
    entry = _load().get("blocks", {}).get(f"{backend}:{shape_class(n, g)}")
    if entry:
        return int(entry["block_n"]), int(entry["block_g"])
    return BLOCK_N, BLOCK_G


def matmul_max_g(backend: str) -> int:
    """Largest group count at which the one-hot matmul formulation is still
    selected (the measured matmul/scatter crossover for ``backend``)."""
    entry = _load().get("crossover", {}).get(backend)
    if entry:
        return int(entry["matmul_max_g"])
    return DEFAULT_MATMUL_MAX_G


def pick_formulation(backend: str, n: int, g: int, v: int,
                     override: Optional[str] = None) -> str:
    """matmul vs scatter for one call shape.  The scatter variant keeps the
    full (G, V) accumulator resident (VMEM on TPU), so it is only eligible
    while that fits ``SCATTER_VMEM_BYTES``."""
    if override is not None:
        if override not in ("matmul", "scatter"):
            raise ValueError(f"unknown segagg formulation: {override!r} "
                             "(expected 'matmul' or 'scatter')")
        return override
    if g <= matmul_max_g(backend):
        return "matmul"
    if backend in ("pallas", "interpret") and g * v * 4 > SCATTER_VMEM_BYTES:
        return "matmul"  # scatter accumulator would not fit on-chip
    return "scatter"


def save(table: Dict) -> pathlib.Path:
    """Persist a tuned table (hillclimb writes through this) and reload."""
    TUNED_PATH.write_text(json.dumps(table, indent=2, sort_keys=True) + "\n")
    reload()
    return TUNED_PATH
