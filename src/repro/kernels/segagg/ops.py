"""Public segagg op: padding, dtype handling, multi-level combine."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .segagg import BLOCK_G, BLOCK_N, segagg_pallas


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnums=(2, 3))
def segagg(keys: jax.Array, values: jax.Array, num_groups: int,
           interpret: bool = True) -> jax.Array:
    """GROUP-BY partial aggregation: (N,) keys + (N, V) values ->
    (num_groups, V) f32 sums.  Pads rows/groups/width to kernel blocks;
    padded rows are routed to a sacrificial group and sliced away.

    ``interpret=True`` executes the kernel body with the Pallas interpreter
    (CPU container); on TPU pass interpret=False.
    """
    N = keys.shape[0]
    if values.ndim == 1:
        values = values[:, None]
    V = values.shape[1]
    Np = _pad_to(N, BLOCK_N)
    Gp = _pad_to(num_groups + 1, BLOCK_G)   # +1 sacrificial group for padding
    Vp = _pad_to(V, 128)
    keys_p = jnp.full((Np,), num_groups, jnp.int32).at[:N].set(
        keys.astype(jnp.int32))
    vals_p = jnp.zeros((Np, Vp), values.dtype).at[:N, :V].set(values)
    out = segagg_pallas(keys_p, vals_p, Gp, interpret)
    return out[:num_groups, :V]


def group_count(keys: jax.Array, num_groups: int,
                interpret: bool = True) -> jax.Array:
    """COUNT(*) GROUP BY — values = ones."""
    ones = jnp.ones((keys.shape[0], 1), jnp.float32)
    return segagg(keys, ones, num_groups, interpret)[:, 0]


def combine(partials: jax.Array) -> jax.Array:
    """Final aggregation step over per-batch partials: (B, G, V) -> (G, V)."""
    return partials.sum(axis=0)


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def pane_segagg(keys: jax.Array, values: jax.Array, pane_ids: jax.Array,
                num_panes: int, num_groups: int,
                interpret: bool = True) -> jax.Array:
    """Pane-partial aggregation for shared execution (repro.core.panes):
    one scan over (N,) keys + (N, V) values with per-row pane assignments
    ``pane_ids`` -> (num_panes, num_groups, V) f32 per-pane group sums.

    Runs through the SAME blocked segagg kernel via composite keys
    ``pane * num_groups + group`` — the pane axis is just more segments, so
    one kernel pass produces every pane's partial at once, ready to be
    cached in a ``PaneStore`` and fanned out to subscribed windows with
    ``merge_panes``.
    """
    if values.ndim == 1:
        values = values[:, None]
    composite = pane_ids.astype(jnp.int32) * num_groups + keys.astype(jnp.int32)
    flat = segagg(composite, values, num_panes * num_groups, interpret)
    return flat.reshape(num_panes, num_groups, values.shape[1])


def merge_panes(pane_partials: jax.Array) -> jax.Array:
    """Fan-out merge of cached pane partials into one window aggregate:
    (P, G, V) -> (G, V).  The merge side of "one scan + k merges" — same
    combine as the final aggregation, over panes instead of batches."""
    return pane_partials.sum(axis=0)
