"""Public segagg op: padding, dtype handling, multi-level combine."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .segagg import BLOCK_G, BLOCK_N, segagg_pallas


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnums=(2, 3))
def segagg(keys: jax.Array, values: jax.Array, num_groups: int,
           interpret: bool = True) -> jax.Array:
    """GROUP-BY partial aggregation: (N,) keys + (N, V) values ->
    (num_groups, V) f32 sums.  Pads rows/groups/width to kernel blocks;
    padded rows are routed to a sacrificial group and sliced away.

    ``interpret=True`` executes the kernel body with the Pallas interpreter
    (CPU container); on TPU pass interpret=False.
    """
    N = keys.shape[0]
    if values.ndim == 1:
        values = values[:, None]
    V = values.shape[1]
    Np = _pad_to(N, BLOCK_N)
    Gp = _pad_to(num_groups + 1, BLOCK_G)   # +1 sacrificial group for padding
    Vp = _pad_to(V, 128)
    keys_p = jnp.full((Np,), num_groups, jnp.int32).at[:N].set(
        keys.astype(jnp.int32))
    vals_p = jnp.zeros((Np, Vp), values.dtype).at[:N, :V].set(values)
    out = segagg_pallas(keys_p, vals_p, Gp, interpret)
    return out[:num_groups, :V]


def group_count(keys: jax.Array, num_groups: int,
                interpret: bool = True) -> jax.Array:
    """COUNT(*) GROUP BY — values = ones."""
    ones = jnp.ones((keys.shape[0], 1), jnp.float32)
    return segagg(keys, ones, num_groups, interpret)[:, 0]


def combine(partials: jax.Array) -> jax.Array:
    """Final aggregation step over per-batch partials: (B, G, V) -> (G, V)."""
    return partials.sum(axis=0)
