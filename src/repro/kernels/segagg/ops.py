"""Public segagg op: backend dispatch, padding, dtype handling, multi-level
combine.

Backend resolution (``backend=``):

* ``"auto"`` (default) — compiled Pallas kernel on TPU/GPU, the jitted XLA
  scatter-add formulation on CPU.  Every call site gets the fastest
  compiled path for the platform it runs on.
* ``"pallas"`` — the compiled Pallas kernel (requires a TPU/GPU backend;
  raises on CPU, where Pallas can only interpret).
* ``"xla"`` — jitted XLA formulation: ``zeros.at[keys].add(values)``
  scatter-add, or a scan-blocked one-hot matmul for narrow G (the measured
  crossover in ``tuning`` selects per call shape).
* ``"interpret"`` — the Pallas kernel body run under the Pallas interpreter
  (the pre-PR-8 default).  Kept for CI parity on CPU: it executes the SAME
  kernel code the TPU path compiles, just slowly.

The legacy ``interpret: bool`` positional is still accepted (``True`` →
``backend="interpret"``, ``False`` → ``backend="pallas"``) so pre-dispatch
callers keep working unchanged.

Both kernel formulations (one-hot matmul vs scatter-add) exist in the
Pallas and XLA backends; ``tuning.pick_formulation`` selects by the
measured crossover group count, and ``tuning.tuned_blocks`` supplies
hillclimb-tuned (block_n, block_g) per (backend, shape-class).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import tuning
from .segagg import segagg_pallas

BACKENDS = ("auto", "pallas", "xla", "interpret")

_INT32_MAX = jnp.iinfo(jnp.int32).max


def resolve_backend(backend: Optional[str] = None,
                    interpret: Optional[bool] = None) -> str:
    """Canonical concrete backend for one call.

    ``interpret`` is the legacy knob: when given (not None) it wins, mapping
    ``True`` → ``"interpret"`` and ``False`` → ``"pallas"``.  ``backend``
    is then resolved: ``None``/``"auto"`` picks compiled Pallas on TPU/GPU
    and compiled XLA on CPU; explicit names are validated.
    """
    if interpret is not None:
        if backend not in (None, "auto"):
            raise ValueError(
                "pass either the legacy interpret= bool or backend=, not both")
        backend = "interpret" if interpret else "pallas"
    if backend is None:
        backend = "auto"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown segagg backend: {backend!r} (expected one of {BACKENDS})")
    if backend == "auto":
        return "pallas" if jax.default_backend() in ("tpu", "gpu") else "xla"
    if backend == "pallas" and jax.default_backend() not in ("tpu", "gpu"):
        raise ValueError(
            "backend='pallas' compiles the Pallas kernel and needs a TPU/GPU "
            "jax backend; on CPU use backend='xla' (compiled) or "
            "backend='interpret' (Pallas interpreter, CI parity path)")
    return backend


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


# -- XLA formulations ------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(2,))
def _segagg_xla_scatter(keys: jax.Array, values: jax.Array,
                        num_groups: int) -> jax.Array:
    """Scatter-add: O(N·V) work regardless of G.  Out-of-range keys (the
    contract is keys in [0, num_groups)) are dropped, matching the kernel
    path's sacrificial padding group."""
    return jnp.zeros((num_groups, values.shape[1]), jnp.float32).at[keys].add(
        values.astype(jnp.float32), mode="drop")


_XLA_MM_BLOCK_N = 16_384  # rows per scan step: bounds the one-hot to ~G*64KB


@functools.partial(jax.jit, static_argnums=(2,))
def _segagg_xla_matmul(keys: jax.Array, values: jax.Array,
                       num_groups: int) -> jax.Array:
    """Scan-blocked one-hot matmul: same formulation the Pallas kernel runs
    on the MXU, expressed as XLA ops.  O(N·G·V) FLOPs — only selected for
    narrow G (below the measured crossover)."""
    N, V = values.shape
    block = min(_XLA_MM_BLOCK_N, _pad_to(N, 8))
    Np = _pad_to(N, block)
    # Padding rows carry key == num_groups: outside every gid, so their
    # one-hot row is all zero.
    keys_p = jnp.full((Np,), num_groups, jnp.int32).at[:N].set(keys)
    vals_p = jnp.zeros((Np, V), jnp.float32).at[:N].set(
        values.astype(jnp.float32))
    gids = jnp.arange(num_groups, dtype=jnp.int32)

    def body(acc, kv):
        k, v = kv
        onehot = (k[:, None] == gids[None, :]).astype(jnp.float32)
        return acc + onehot.T @ v, None

    out, _ = jax.lax.scan(
        body, jnp.zeros((num_groups, V), jnp.float32),
        (keys_p.reshape(-1, block), vals_p.reshape(-1, block, V)))
    return out


# -- dispatch --------------------------------------------------------------

def segagg(keys: jax.Array, values: jax.Array, num_groups: int,
           interpret: Optional[bool] = None, *,
           backend: Optional[str] = None,
           formulation: Optional[str] = None) -> jax.Array:
    """GROUP-BY partial aggregation: (N,) keys + (N, V) values ->
    (num_groups, V) f32 sums.

    ``backend=`` selects the execution path (see module docstring);
    ``formulation=`` overrides the matmul/scatter crossover ("matmul" |
    "scatter", default measured per shape).  The legacy positional
    ``interpret`` bool still works: True → the interpreter path, False →
    compiled Pallas.
    """
    be = resolve_backend(backend, interpret)
    if num_groups <= 0:
        raise ValueError(f"num_groups must be positive, got {num_groups}")
    if values.ndim == 1:
        values = values[:, None]
    N = keys.shape[0]
    V = values.shape[1]
    if N == 0:
        return jnp.zeros((num_groups, V), jnp.float32)
    if be == "xla":
        form = tuning.pick_formulation(be, N, num_groups, V, formulation)
        keys = keys.astype(jnp.int32)
        if form == "scatter":
            return _segagg_xla_scatter(keys, values, num_groups)
        return _segagg_xla_matmul(keys, values, num_groups)
    # Pallas paths (compiled or interpreted): pad rows/groups/width to the
    # tuned kernel blocks; padded rows are routed to a sacrificial group
    # and sliced away.  The formulation choice sees the PADDED width — that
    # is what the scatter accumulator keeps resident on-chip.
    block_n, block_g = tuning.tuned_blocks(be, N, num_groups)
    Np = _pad_to(N, block_n)
    Gp = _pad_to(num_groups + 1, block_g)   # +1 sacrificial group for padding
    Vp = _pad_to(V, 128)
    form = tuning.pick_formulation(be, N, num_groups, Vp, formulation)
    keys_p = jnp.full((Np,), num_groups, jnp.int32).at[:N].set(
        keys.astype(jnp.int32))
    vals_p = jnp.zeros((Np, Vp), values.dtype).at[:N, :V].set(values)
    out = segagg_pallas(keys_p, vals_p, Gp, be == "interpret",
                        block_n, block_g, form)
    return out[:num_groups, :V]


def group_count(keys: jax.Array, num_groups: int,
                interpret: Optional[bool] = None, *,
                backend: Optional[str] = None) -> jax.Array:
    """COUNT(*) GROUP BY — values = ones."""
    ones = jnp.ones((keys.shape[0], 1), jnp.float32)
    return segagg(keys, ones, num_groups, interpret, backend=backend)[:, 0]


def combine(partials: jax.Array) -> jax.Array:
    """Final aggregation step over per-batch partials: (B, G, V) -> (G, V)."""
    return partials.sum(axis=0)


def pane_composite_groups(num_panes: int, num_groups: int) -> int:
    """Composite segment count for the pane x group key space, guarded
    against int32 overflow: pane_segagg keys are ``pane * num_groups +
    group`` in int32, so the product must stay addressable."""
    total = num_panes * num_groups  # Python ints: no silent wraparound
    if total > _INT32_MAX:
        raise ValueError(
            f"pane_segagg composite key space num_panes*num_groups = "
            f"{num_panes}*{num_groups} = {total} exceeds int32 "
            f"({_INT32_MAX}); split the pane run into "
            f"<= {_INT32_MAX // max(num_groups, 1)} panes per scan")
    return total


def pane_segagg(keys: jax.Array, values: jax.Array, pane_ids: jax.Array,
                num_panes: int, num_groups: int,
                interpret: Optional[bool] = None, *,
                backend: Optional[str] = None) -> jax.Array:
    """Pane-partial aggregation for shared execution (repro.core.panes):
    one scan over (N,) keys + (N, V) values with per-row pane assignments
    ``pane_ids`` -> (num_panes, num_groups, V) f32 per-pane group sums.

    Runs through the SAME blocked segagg kernel via composite keys
    ``pane * num_groups + group`` — the pane axis is just more segments, so
    one kernel pass produces every pane's partial at once, ready to be
    cached in a ``PaneStore`` and fanned out to subscribed windows with
    ``merge_panes``.  ``backend=`` dispatches exactly like ``segagg``.
    """
    if values.ndim == 1:
        values = values[:, None]
    total = pane_composite_groups(num_panes, num_groups)
    composite = pane_ids.astype(jnp.int32) * num_groups + keys.astype(jnp.int32)
    flat = segagg(composite, values, total, interpret, backend=backend)
    return flat.reshape(num_panes, num_groups, values.shape[1])


def merge_panes(pane_partials: jax.Array) -> jax.Array:
    """Fan-out merge of cached pane partials into one window aggregate:
    (P, G, V) -> (G, V).  The merge side of "one scan + k merges" — same
    combine as the final aggregation, over panes instead of batches."""
    return pane_partials.sum(axis=0)


def flops_bytes(n: int, num_groups: int, v: int, formulation: str,
                backend: str = "xla") -> Tuple[float, float]:
    """Analytic (FLOPs, HBM bytes) of one segagg call — the numerators of
    the roofline terms (benchmarks/bench_roofline.py).  The Pallas paths
    pad rows/groups/width to kernel blocks and that padded work really
    runs, so their counts use padded extents; the XLA paths only pad rows
    for the matmul scan.  Matmul counts the one-hot contraction; scatter
    one multiply-accumulate per row element.  Bytes: keys + values read,
    (G, V) f32 partial written."""
    if backend in ("pallas", "interpret"):
        vp = _pad_to(v, 128)
        bn, bg = tuning.tuned_blocks(backend, n, num_groups)
        np_, gp = _pad_to(n, bn), _pad_to(num_groups + 1, bg)
    else:
        vp, gp = v, num_groups
        np_ = _pad_to(n, min(_XLA_MM_BLOCK_N, _pad_to(n, 8))) \
            if formulation == "matmul" else n
    if formulation == "matmul":
        flops = 2.0 * np_ * gp * vp
    else:
        flops = 2.0 * np_ * vp
    bytes_ = 4.0 * np_ + 4.0 * np_ * vp + 4.0 * gp * vp
    return flops, bytes_
