"""Pure-jnp oracle for the segagg kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segagg_ref(keys: jax.Array, values: jax.Array, num_groups: int) -> jax.Array:
    """keys (N,) int32, values (N, V) -> (num_groups, V) f32 group sums."""
    return jax.ops.segment_sum(
        values.astype(jnp.float32), keys, num_segments=num_groups)


def combine_ref(partials: jax.Array) -> jax.Array:
    """Final aggregation (paper §2.1): sum the per-batch partials.
    partials: (num_batches, G, V) -> (G, V)."""
    return partials.sum(axis=0)
