"""Pure-jnp oracle for the segagg kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segagg_ref(keys: jax.Array, values: jax.Array, num_groups: int) -> jax.Array:
    """keys (N,) int32, values (N, V) -> (num_groups, V) f32 group sums."""
    return jax.ops.segment_sum(
        values.astype(jnp.float32), keys, num_segments=num_groups)


def combine_ref(partials: jax.Array) -> jax.Array:
    """Final aggregation (paper §2.1): sum the per-batch partials.
    partials: (num_batches, G, V) -> (G, V)."""
    return partials.sum(axis=0)


def pane_segagg_ref(keys: jax.Array, values: jax.Array, pane_ids: jax.Array,
                    num_panes: int, num_groups: int) -> jax.Array:
    """Oracle for the pane-partial aggregation op: ONE pass over (N,) keys /
    (N, V) values / (N,) pane assignments -> (num_panes, num_groups, V)
    per-pane group sums (pane sharing, repro.core.panes)."""
    composite = pane_ids.astype(jnp.int32) * num_groups + keys.astype(jnp.int32)
    flat = jax.ops.segment_sum(
        values.astype(jnp.float32), composite,
        num_segments=num_panes * num_groups)
    return flat.reshape(num_panes, num_groups, values.shape[-1])
