"""Public RG-LRU recurrence op: gate math in XLA, scan in the kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .rglru import BLOCK_N, BLOCK_S, rglru_pallas

_C = 8.0  # Griffin decay sharpness (matches repro.layers.rglru)


@functools.partial(jax.jit, static_argnums=(5,))
def rglru(x: jax.Array, r: jax.Array, i: jax.Array, a_param: jax.Array,
          h0: jax.Array | None = None, interpret: bool = True):
    """Full RG-LRU (gates + recurrence), kernel-backed.

    x, r, i: (B, S, N); a_param: (N,).  Returns (y (B,S,N), h_last (B,N))."""
    B, S, N = x.shape
    rf = r.astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(a_param.astype(jnp.float32)) * rf
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    u = beta * (i.astype(jnp.float32) * x.astype(jnp.float32))
    if h0 is None:
        h0 = jnp.zeros((B, N), jnp.float32)

    pad_s = -S % BLOCK_S
    pad_n = -N % BLOCK_N
    if pad_s or pad_n:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad_s), (0, pad_n)))
        u = jnp.pad(u, ((0, 0), (0, pad_s), (0, pad_n)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_n)))
    y, h_last = rglru_pallas(log_a.astype(x.dtype), u.astype(x.dtype), h0,
                             interpret)
    y = y[:, :S, :N]
    # h_last must reflect the true last step, not padded steps (padded steps
    # have log_a = 0 -> a = 1, u = 0 => state unchanged, so slicing is safe).
    return y, h_last[:, :N]
