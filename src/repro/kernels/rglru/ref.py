"""Pure-jnp oracle for the RG-LRU recurrence kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_rec_ref(log_a: jax.Array, u: jax.Array, h0: jax.Array):
    """Sequential reference: h_t = exp(log_a_t) h_{t-1} + u_t.
    log_a, u: (B, S, N); h0: (B, N).  Returns (y, h_last)."""
    a = jnp.exp(log_a.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(h, xs):
        at, ut = xs
        h = at * h + ut
        return h, h

    h_last, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.transpose(1, 0, 2), uf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(u.dtype), h_last
