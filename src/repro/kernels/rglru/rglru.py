"""Pallas TPU kernel: RG-LRU chunked linear recurrence (recurrentgemma).

The recurrence h_t = a_t * h_{t-1} + b_t is memory-bound elementwise work
(VPU, not MXU).  Grid: (B, num_width_blocks, num_seq_blocks) — the sequence
axis is the sequential minor grid dimension, with the carried state h in
VMEM scratch.  Within a block the recurrence runs as a fori_loop over time
steps on (BN,)-wide vectors.

Gate/decay math (sigmoid projections) stays in XLA — it is MXU matmul work
that fuses well there; the kernel takes precomputed per-step (log_a, u) and
does the part XLA handles badly: the sequential scan, without materialising
per-step f32 carries in HBM (the associative_scan fallback keeps
O(S log S) HBM traffic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128   # channel-block width (lane dim)
BLOCK_S = 256   # time steps per grid step


def _rglru_kernel(log_a_ref, u_ref, h0_ref, y_ref, h_last_ref, h_scr):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)    # (1, BN)

    log_a = log_a_ref[0].astype(jnp.float32)            # (BS, BN)
    u = u_ref[0].astype(jnp.float32)                    # (BS, BN)
    a = jnp.exp(log_a)

    def body(t, carry):
        h = carry                                       # (1, BN)
        h = a[t][None, :] * h + u[t][None, :]
        y_ref[0, pl.ds(t, 1), :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, log_a.shape[0], body, h_scr[...])
    h_scr[...] = h

    @pl.when(si == ns - 1)
    def _final():
        h_last_ref[...] = h


@functools.partial(jax.jit, static_argnums=(3,))
def rglru_pallas(log_a: jax.Array, u: jax.Array, h0: jax.Array,
                 interpret: bool = True):
    """log_a, u: (B, S, N) per-step decay (log) and input; h0: (B, N) f32.
    Returns (y (B,S,N) u.dtype, h_last (B,N) f32).  S, N must be multiples
    of the block sizes (ops pads)."""
    B, S, N = u.shape
    assert S % BLOCK_S == 0 and N % BLOCK_N == 0, (S, N)
    from jax.experimental.pallas import tpu as pltpu

    grid = (B, N // BLOCK_N, S // BLOCK_S)
    return pl.pallas_call(
        _rglru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_S, BLOCK_N), lambda b, n, s: (b, s, n)),
            pl.BlockSpec((1, BLOCK_S, BLOCK_N), lambda b, n, s: (b, s, n)),
            pl.BlockSpec((1, BLOCK_N), lambda b, n, s: (b, n)),
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK_S, BLOCK_N), lambda b, n, s: (b, s, n)),
            pl.BlockSpec((1, BLOCK_N), lambda b, n, s: (b, n)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, N), u.dtype),
            jax.ShapeDtypeStruct((B, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, BLOCK_N), jnp.float32)],
        interpret=interpret,
    )(log_a, u, h0)
