"""Pure-jnp oracle for the flash-attention kernel: plain masked softmax
attention (materialised S x S — fine at test sizes)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True, window=0, logit_cap=0.0, seq_k=-1):
    """q (B,H,Sq,D), k/v (B,Hkv,Sk,D) -> (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    seq_k = Sk if seq_k < 0 else seq_k
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if logit_cap > 0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    ok = k_pos < seq_k
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window > 0:
        ok = ok & (k_pos > q_pos - window)
    s = jnp.where(ok[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
