"""Pallas TPU kernel: flash attention forward (causal / sliding-window /
GQA / logit soft-cap).

Grid: (B, H, num_q_blocks, num_k_blocks).  The last grid dimension is
sequential on TPU, so the online-softmax state (m, l, acc) lives in VMEM
scratch and persists across k-blocks; the normalised output is written on
the final k-block.  GQA maps query head h to KV head h // group in the
K/V BlockSpec index maps — KV blocks are never replicated in HBM.

Block shapes: q (BQ, D), k/v (BK, D) with D the head dim (128-lane aligned
for the MXU); the (BQ, BK) logit tile exists only in VMEM — this is what
removes the O(S*chunk) HBM traffic of the XLA-lowered jnp path (see
EXPERIMENTS.md §Perf, iteration 1).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -2.0 ** 30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      scale: float, causal: bool, window: int,
                      logit_cap: float, bq: int, bk: int, nk: int,
                      seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Skip tiles that are fully masked (above the causal diagonal or outside
    # the sliding window) — no MXU work is issued for them.
    live = True
    if causal:
        live = jnp.logical_and(live, qi * bq + bq - 1 >= ki * bk)
    if window > 0:
        live = jnp.logical_and(live, ki * bk + bk - 1 > qi * bq - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (BQ, BK)
        if logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        ok = k_pos < seq_k
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                              # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                   # (BQ, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (BQ, D)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention_pallas(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, Hkv, Sk, D)
    v: jax.Array,            # (B, Hkv, Sk, D)
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    seq_k: int = -1,          # true (unpadded) key length
    interpret: bool = True,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = H // Hkv
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    seq_k = Sk if seq_k < 0 else seq_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, bq=bq, bk=bk, nk=nk, seq_k=seq_k)

    from jax.experimental.pallas import tpu as pltpu

    scratch = [
        pltpu.VMEM((bq, 1), jnp.float32),   # running max m
        pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
        pltpu.VMEM((bq, D), jnp.float32),   # unnormalised accumulator
    ]

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
