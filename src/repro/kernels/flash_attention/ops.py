"""Public flash-attention op: layout adaptation + padding + block sizing.

Model code uses (B, S, H, D) layout; the kernel wants (B, H, S, D) with
block-aligned sequence lengths.  On TPU (interpret=False) this is the
production attention; the jnp path (repro.layers.attention) is the
algorithmically identical fallback + oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import DEFAULT_BK, DEFAULT_BQ, flash_attention_pallas


def _pad_seq(x: jax.Array, block: int) -> jax.Array:
    s = x.shape[2]
    pad = -s % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return x


@functools.partial(jax.jit, static_argnums=(3, 4, 5, 6))
def flash_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Sk, Hkv, D)
    v: jax.Array,            # (B, Sk, Hkv, D)
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    bq = min(DEFAULT_BQ, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(DEFAULT_BK, max(8, 1 << (Sk - 1).bit_length()))
    qt = _pad_seq(q.transpose(0, 2, 1, 3), bq)
    kt = _pad_seq(k.transpose(0, 2, 1, 3), bk)
    vt = _pad_seq(v.transpose(0, 2, 1, 3), bk)
    out = flash_attention_pallas(
        qt, kt, vt, causal, window, logit_cap, bq, bk, Sk, interpret)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
