"""Unified model configuration covering the 10 assigned architectures.

A model is a stack of SEGMENTS; each segment is ``num_units`` repetitions of
a layer PATTERN (a tuple of layer kinds).  Uniform models have one segment
with pattern ("attn",); recurrentgemma has ("rglru", "rglru", "attn") x 12
plus a ("rglru", "rglru") tail.  Segments are scanned over units, which keeps
the lowered HLO (and compile time) independent of depth.

Layer kinds:
  attn   — self-attention mixer + dense MLP
  moe    — self-attention mixer + MoE FFN
  rglru  — RG-LRU recurrent mixer (+ short conv) + dense MLP
  ssm    — Mamba-2 SSD block (no separate MLP; d_ff == 0)
  xattn  — self-attention + cross-attention + MLP (whisper decoder)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: Tuple[str, ...]
    num_units: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.num_units


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    segments: Tuple[Segment, ...]
    # attention
    window: int = 0                 # sliding/local attention window (0 = full)
    logit_cap: float = 0.0
    rope_theta: float = 10_000.0
    rotary_frac: float = 1.0
    norm: str = "rms"               # rms | ln
    act: str = "silu"
    mlp_gated: bool = True
    bias: bool = False              # projection biases (whisper)
    tie_embeddings: bool = False
    abs_positions: bool = False     # sinusoidal absolute positions (whisper)
    # MoE
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    moe_group_size: int = 2048
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 128
    # RG-LRU (recurrentgemma)
    lru_width: int = 0              # recurrent width N (== d_model for RG-9B)
    # encoder-decoder (whisper)
    encoder_segments: Tuple[Segment, ...] = ()
    encoder_seq: int = 0            # whisper: 1500 frames
    # modality frontend stub
    frontend: str = "none"          # none | audio | vision
    num_patches: int = 0            # vision prefix length (internvl2)
    # dry-run costing: unroll inner chunk scans so XLA cost_analysis (which
    # counts while bodies once) sees every chunk.  Never used in production.
    inner_unroll: bool = False
    # KV-chunk length of the online-softmax attention scan (the jnp flash
    # path materialises one (Sq x chunk) f32 block per step; the Pallas
    # kernel keeps it in VMEM).  Smaller chunk = smaller transient on the
    # XLA-lowered path.
    attn_chunk: int = 256
    # Memory/throughput knobs for the assigned production shapes:
    # gradient-accumulation microbatches (train) and sequential batch-row
    # chunks (prefill).  Set per-arch where a cell would exceed 16 GiB HBM.
    train_microbatches: int = 1
    prefill_row_chunks: int = 1
    # Cost-attribution variant (dry-run only): replace the attention chunk
    # scan with an identity of the same shape, keeping qkv/out projections.
    # The delta vs the real program isolates exactly the HBM traffic the
    # Pallas flash kernel eliminates (its tiles live in VMEM); see
    # EXPERIMENTS.md section Perf iteration K1.
    attn_skip: bool = False
    note: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.segments)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True if decode-time state is bounded (SSM / windowed attention):
        the archs eligible for the long_500k cell."""
        kinds = {k for s in self.segments for k in s.pattern}
        if kinds <= {"ssm"}:
            return True
        has_full_attn = any(
            k in ("attn", "moe", "xattn") for s in self.segments for k in s.pattern
        )
        return not has_full_attn or self.window > 0

    def reduced(self) -> "ModelConfig":
        """Smoke-test configuration: same family/pattern, tiny dims."""
        def shrink_segments(segs):
            out = []
            for s in segs:
                out.append(Segment(pattern=s.pattern, num_units=1))
            return tuple(out)

        return dataclasses.replace(
            self,
            segments=shrink_segments(self.segments),
            encoder_segments=shrink_segments(self.encoder_segments),
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2))
            if self.num_kv_heads < self.num_heads
            else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            expert_d_ff=32 if self.expert_d_ff else 0,
            moe_group_size=64,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            lru_width=64 if self.lru_width else 0,
            window=min(self.window, 16) if self.window else 0,
            encoder_seq=24 if self.encoder_seq else 0,
            num_patches=4 if self.num_patches else 0,
        )


def uniform(kind: str, n: int) -> Tuple[Segment, ...]:
    return (Segment(pattern=(kind,), num_units=n),)


def patterned(pattern: Tuple[str, ...], total_layers: int) -> Tuple[Segment, ...]:
    """Repeat ``pattern`` as many full times as fits; the remainder becomes a
    tail segment (recurrentgemma: 38 = 12 x (R,R,A) + (R,R))."""
    plen = len(pattern)
    full, rem = divmod(total_layers, plen)
    segs = []
    if full:
        segs.append(Segment(pattern=pattern, num_units=full))
    if rem:
        segs.append(Segment(pattern=pattern[:rem], num_units=1))
    return tuple(segs)
