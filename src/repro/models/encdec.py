"""Encoder-decoder backbone (whisper-medium).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, encoder_seq, d_model).  Positions are
sinusoidal (computed on the fly, any length — noted deviation from whisper's
learned decoder positions, which cap at 448; the decode_32k cell is exercised
structurally).

Params:  "enc{si}/..." encoder segments, "seg{si}/..." decoder segments
         (decoder layers are 'xattn' kind: self-attn + cross-attn + MLP).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .lm import (
    Params,
    _KIND_SPECS,
    _segment_params,
    backbone,
    decode_step as _lm_decode_step,
    embed_tokens,
    unembed,
)
from .params import ParamSpec, Specs


def sinusoidal_positions(S: int, D: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10_000.0) * dim / max(D // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def build_encdec_specs(cfg: ModelConfig) -> Specs:
    specs: Specs = {
        "embed/tokens": ParamSpec((cfg.vocab_size, cfg.d_model),
                                  ("vocab", "embed"), fan_in_axis=1),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "enc_final_norm": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }
    if cfg.norm == "ln":
        specs["final_norm_bias"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
        specs["enc_final_norm_bias"] = ParamSpec((cfg.d_model,), ("embed",),
                                                 init="zeros")
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"))
    for si, seg in enumerate(cfg.encoder_segments):
        for li, kind in enumerate(seg.pattern):
            specs.update(_KIND_SPECS[kind](cfg, seg.num_units, f"enc{si}/l{li}"))
    for si, seg in enumerate(cfg.segments):
        for li, kind in enumerate(seg.pattern):
            specs.update(_KIND_SPECS[kind](cfg, seg.num_units, f"seg{si}/l{li}"))
    return specs


def encode(cfg: ModelConfig, params: Params, frames: jax.Array,
           remat: bool = True) -> jax.Array:
    """frames: (B, S_enc, D) precomputed frontend embeddings (stub)."""
    from ..layers.common import layer_norm, rms_norm

    x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model, frames.dtype)
    positions = jnp.arange(frames.shape[1])
    x, _ = backbone(cfg, params, x, positions, remat=remat,
                    segments=cfg.encoder_segments, key_prefix="enc",
                    causal=False)
    if cfg.norm == "ln":
        return layer_norm(x, params["enc_final_norm"],
                          params["enc_final_norm_bias"])
    return rms_norm(x, params["enc_final_norm"])


def encdec_loss(cfg: ModelConfig, params: Params,
                batch: Dict[str, jax.Array], remat: bool = True):
    """batch: frames (B,S_enc,D), tokens (B,S), labels (B,S)."""
    enc_out = encode(cfg, params, batch["frames"], remat=remat)
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)
    positions = jnp.arange(x.shape[1])
    x, _ = backbone(cfg, params, x, positions, enc_out=enc_out, remat=remat)
    from .lm import xent_loss

    return xent_loss(cfg, params, x, batch["labels"])


def encdec_prefill(cfg: ModelConfig, params: Params, frames: jax.Array,
                   tokens: jax.Array, cache_size: int):
    """Encode + prompt-prefill the decoder (cross-attn K/V are computed and
    cached inside the decoder layer scan).

    Returns (last-logits (B,V), cache, cache_len, enc_out)."""
    from .lm import prefill

    enc_out = encode(cfg, params, frames, remat=False)
    logits, cache, clen = prefill(cfg, params, tokens, cache_size,
                                  enc_out=enc_out)
    return logits, cache, clen, enc_out


def encdec_decode_step(cfg: ModelConfig, params: Params, cache, cache_len,
                       tokens: jax.Array):
    """Single decoder step; cross-attn K/V come from the cache."""
    return _lm_decode_step(cfg, params, cache, cache_len, tokens)
