"""Unified decoder-only LM covering dense / MoE / hybrid (RG-LRU) / SSM / VLM
families.  One code path, driven by ModelConfig.segments; every segment scans
over its units so lowered-HLO size and compile time are depth-independent.

Conventions:
* params: flat dict  "seg{i}/l{j}/<block>/<leaf>" -> (U, ...) stacked arrays
* cache:  flat dict  "seg{i}/l{j}/<leaf>"          -> (U, B, ...) stacked
* logical axes per leaf drive sharding (see repro.dist.sharding)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.context import constrain
from ..layers.attention import AttnSpec, chunked_attention, decode_attention
from ..layers.common import apply_rope, gated_mlp, layer_norm, mlp, rms_norm
from ..layers.moe import MoESpec, moe_ffn
from ..layers.rglru import rglru_scan, rglru_step, short_conv1d
from ..layers.ssd import ssd_chunked, ssd_step
from .config import ModelConfig, Segment
from .params import ParamSpec, Specs

Params = Dict[str, jax.Array]
Cache = Dict[str, jax.Array]


# ===========================================================================
# Parameter specs
# ===========================================================================

def _attn_specs(cfg: ModelConfig, u: int, p: str, cross: bool = False) -> Specs:
    D, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s: Specs = {
        f"{p}/norm": ParamSpec((u, D), ("layers", "embed"), init="zeros"),
        f"{p}/wq": ParamSpec((u, D, H, Dh), ("layers", "embed", "heads", None)),
        f"{p}/wk": ParamSpec((u, D, Hkv, Dh), ("layers", "embed", "kv_heads", None)),
        f"{p}/wv": ParamSpec((u, D, Hkv, Dh), ("layers", "embed", "kv_heads", None)),
        f"{p}/wo": ParamSpec((u, H, Dh, D), ("layers", "heads", None, "embed"),
                             fan_in_axis=1),
    }
    if cfg.norm == "ln":
        s[f"{p}/norm_bias"] = ParamSpec((u, D), ("layers", "embed"), init="zeros")
    if cfg.bias:
        s[f"{p}/bq"] = ParamSpec((u, H, Dh), ("layers", "heads", None), init="zeros")
        s[f"{p}/bk"] = ParamSpec((u, Hkv, Dh), ("layers", "kv_heads", None), init="zeros")
        s[f"{p}/bv"] = ParamSpec((u, Hkv, Dh), ("layers", "kv_heads", None), init="zeros")
        s[f"{p}/bo"] = ParamSpec((u, D), ("layers", "embed"), init="zeros")
    return s


def _mlp_specs(cfg: ModelConfig, u: int, p: str) -> Specs:
    D, F = cfg.d_model, cfg.d_ff
    s: Specs = {
        f"{p}/norm": ParamSpec((u, D), ("layers", "embed"), init="zeros"),
    }
    if cfg.norm == "ln":
        s[f"{p}/norm_bias"] = ParamSpec((u, D), ("layers", "embed"), init="zeros")
    if cfg.mlp_gated:
        s[f"{p}/w_gate"] = ParamSpec((u, D, F), ("layers", "embed", "ffn"))
        s[f"{p}/w_up"] = ParamSpec((u, D, F), ("layers", "embed", "ffn"))
        s[f"{p}/w_down"] = ParamSpec((u, F, D), ("layers", "ffn", "embed"))
    else:
        s[f"{p}/w_up"] = ParamSpec((u, D, F), ("layers", "embed", "ffn"))
        s[f"{p}/w_down"] = ParamSpec((u, F, D), ("layers", "ffn", "embed"))
        if cfg.bias:
            s[f"{p}/b_up"] = ParamSpec((u, F), ("layers", "ffn"), init="zeros")
            s[f"{p}/b_down"] = ParamSpec((u, D), ("layers", "embed"), init="zeros")
    return s


def _moe_specs(cfg: ModelConfig, u: int, p: str) -> Specs:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.expert_d_ff or cfg.d_ff
    return {
        f"{p}/norm": ParamSpec((u, D), ("layers", "embed"), init="zeros"),
        f"{p}/router": ParamSpec((u, D, E), ("layers", "embed", None)),
        f"{p}/w_gate": ParamSpec((u, E, D, F), ("layers", "experts", "embed", "ffn")),
        f"{p}/w_up": ParamSpec((u, E, D, F), ("layers", "experts", "embed", "ffn")),
        f"{p}/w_down": ParamSpec((u, E, F, D), ("layers", "experts", "ffn", "embed"),
                                 fan_in_axis=2),
    }


def _rglru_specs(cfg: ModelConfig, u: int, p: str) -> Specs:
    D, N, T = cfg.d_model, cfg.lru_width, cfg.conv_width
    return {
        f"{p}/norm": ParamSpec((u, D), ("layers", "embed"), init="zeros"),
        f"{p}/w_x": ParamSpec((u, D, N), ("layers", "embed", "rnn")),
        f"{p}/w_gate": ParamSpec((u, D, N), ("layers", "embed", "rnn")),
        f"{p}/conv_w": ParamSpec((u, T, N), ("layers", None, "rnn")),
        f"{p}/w_r": ParamSpec((u, N, N), ("layers", "rnn_in", "rnn")),
        f"{p}/w_i": ParamSpec((u, N, N), ("layers", "rnn_in", "rnn")),
        f"{p}/a_param": ParamSpec((u, N), ("layers", "rnn"), init="rglru_a"),
        f"{p}/w_out": ParamSpec((u, N, D), ("layers", "rnn", "embed")),
    }


def _ssm_specs(cfg: ModelConfig, u: int, p: str) -> Specs:
    D, Din, N = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    H, T = cfg.ssm_num_heads, cfg.conv_width
    return {
        f"{p}/norm": ParamSpec((u, D), ("layers", "embed"), init="zeros"),
        f"{p}/w_z": ParamSpec((u, D, Din), ("layers", "embed", "rnn")),
        f"{p}/w_x": ParamSpec((u, D, Din), ("layers", "embed", "rnn")),
        f"{p}/w_B": ParamSpec((u, D, N), ("layers", "embed", "state")),
        f"{p}/w_C": ParamSpec((u, D, N), ("layers", "embed", "state")),
        f"{p}/w_dt": ParamSpec((u, D, H), ("layers", "embed", None)),
        f"{p}/dt_bias": ParamSpec((u, H), ("layers", None), init="ssm_dt"),
        f"{p}/a_log": ParamSpec((u, H), ("layers", None), init="ones"),
        f"{p}/d_skip": ParamSpec((u, H), ("layers", None), init="ones"),
        f"{p}/conv_w": ParamSpec((u, T, Din), ("layers", None, "rnn")),
        f"{p}/gate_norm": ParamSpec((u, Din), ("layers", "rnn"), init="zeros"),
        f"{p}/w_out": ParamSpec((u, Din, D), ("layers", "rnn", "embed")),
    }


_KIND_SPECS = {
    "attn": lambda cfg, u, p: {**_attn_specs(cfg, u, f"{p}/attn"),
                               **_mlp_specs(cfg, u, f"{p}/mlp")},
    "moe": lambda cfg, u, p: {**_attn_specs(cfg, u, f"{p}/attn"),
                              **_moe_specs(cfg, u, f"{p}/moe")},
    "rglru": lambda cfg, u, p: {**_rglru_specs(cfg, u, f"{p}/rglru"),
                                **_mlp_specs(cfg, u, f"{p}/mlp")},
    "ssm": lambda cfg, u, p: _ssm_specs(cfg, u, p + "/ssm"),
    "xattn": lambda cfg, u, p: {**_attn_specs(cfg, u, f"{p}/attn"),
                                **_attn_specs(cfg, u, f"{p}/xattn"),
                                **_mlp_specs(cfg, u, f"{p}/mlp")},
}


def build_specs(cfg: ModelConfig) -> Specs:
    specs: Specs = {
        "embed/tokens": ParamSpec((cfg.vocab_size, cfg.d_model),
                                  ("vocab", "embed"), fan_in_axis=1),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }
    if cfg.norm == "ln":
        specs["final_norm_bias"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"))
    for si, seg in enumerate(cfg.segments):
        for li, kind in enumerate(seg.pattern):
            specs.update(_KIND_SPECS[kind](cfg, seg.num_units, f"seg{si}/l{li}"))
    return specs


# ===========================================================================
# Blocks (per-unit application; params already sliced to this unit)
# ===========================================================================

def _norm(cfg: ModelConfig, x, p, prefix):
    if cfg.norm == "ln":
        return layer_norm(x, p[f"{prefix}/norm"], p[f"{prefix}/norm_bias"])
    return rms_norm(x, p[f"{prefix}/norm"])


def _attn_spec(cfg: ModelConfig, causal: bool = True) -> AttnSpec:
    return AttnSpec(causal=causal, window=cfg.window,
                    logit_cap=cfg.logit_cap, chunk=cfg.attn_chunk,
                    unroll=cfg.inner_unroll)


def _qkv(cfg, p, prefix, x, positions, rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p[f"{prefix}/wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p[f"{prefix}/wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p[f"{prefix}/wv"])
    if cfg.bias:
        q = q + p[f"{prefix}/bq"]
        k = k + p[f"{prefix}/bk"]
        v = v + p[f"{prefix}/bv"]
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_frac)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_frac)
    return q, k, v


def _attn_out(cfg, p, prefix, o):
    y = jnp.einsum("bshk,hkd->bsd", o, p[f"{prefix}/wo"])
    if cfg.bias:
        y = y + p[f"{prefix}/bo"]
    return y


def _self_attn_block(cfg, p, prefix, x, positions, causal=True):
    h = _norm(cfg, x, p, prefix)
    q, k, v = _qkv(cfg, p, prefix, h, positions)
    if cfg.attn_skip:  # cost-attribution variant: see ModelConfig.attn_skip
        o = q + 0.0 * (k.sum() + v.sum())
    else:
        o = chunked_attention(q, k, v, _attn_spec(cfg, causal))
    return x + _attn_out(cfg, p, prefix, o), (k, v)


def _mlp_block(cfg, p, prefix, x):
    h = _norm(cfg, x, p, prefix)
    if cfg.mlp_gated:
        y = gated_mlp(h, p[f"{prefix}/w_gate"], p[f"{prefix}/w_up"],
                      p[f"{prefix}/w_down"], cfg.act)
    else:
        y = mlp(h, p[f"{prefix}/w_up"], p[f"{prefix}/w_down"],
                p.get(f"{prefix}/b_up"), p.get(f"{prefix}/b_down"), cfg.act)
    return x + y


def _moe_block(cfg, p, prefix, x):
    h = _norm(cfg, x, p, prefix)
    spec = MoESpec(num_experts=cfg.num_experts, top_k=cfg.top_k,
                   capacity_factor=cfg.capacity_factor, act=cfg.act,
                   group_size=cfg.moe_group_size)
    y, aux = moe_ffn(h, p[f"{prefix}/router"], p[f"{prefix}/w_gate"],
                     p[f"{prefix}/w_up"], p[f"{prefix}/w_down"], spec)
    return x + y, aux


def _rglru_gates(p, prefix, xb):
    r = jax.nn.sigmoid(jnp.einsum("bsn,nm->bsm", xb, p[f"{prefix}/w_r"]))
    i = jax.nn.sigmoid(jnp.einsum("bsn,nm->bsm", xb, p[f"{prefix}/w_i"]))
    return r, i


def _rglru_block(cfg, p, prefix, x, conv_state=None, h_state=None):
    """Griffin recurrent block.  Returns (y, (conv_state, h_state))."""
    h = _norm(cfg, x, p, prefix)
    xb = jnp.einsum("bsd,dn->bsn", h, p[f"{prefix}/w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dn->bsn", h, p[f"{prefix}/w_gate"]))
    xb, conv_state = short_conv1d(xb, p[f"{prefix}/conv_w"], conv_state)
    r, i = _rglru_gates(p, prefix, xb)
    y, h_last = rglru_scan(xb, r, i, p[f"{prefix}/a_param"], h_state)
    y = y * gate
    return x + jnp.einsum("bsn,nd->bsd", y, p[f"{prefix}/w_out"]), (conv_state, h_last)


def _ssm_block(cfg, p, prefix, x, conv_state=None, h_state=None):
    """Mamba-2 block.  Returns (y, (conv_state, h_state))."""
    B_, S, D = x.shape
    Hs, P = cfg.ssm_num_heads, cfg.ssm_head_dim
    h = _norm(cfg, x, p, prefix)
    z = jnp.einsum("bsd,dn->bsn", h, p[f"{prefix}/w_z"])
    xi = jnp.einsum("bsd,dn->bsn", h, p[f"{prefix}/w_x"])
    xi, conv_state = short_conv1d(xi, p[f"{prefix}/conv_w"], conv_state)
    xi = jax.nn.silu(xi)
    Bm = jnp.einsum("bsd,dn->bsn", h, p[f"{prefix}/w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", h, p[f"{prefix}/w_C"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p[f"{prefix}/w_dt"])
        + p[f"{prefix}/dt_bias"]
    )
    A = -jax.nn.softplus(p[f"{prefix}/a_log"].astype(jnp.float32))
    xh = xi.reshape(B_, S, Hs, P)
    Bh = jnp.broadcast_to(Bm[:, :, None, :], (B_, S, Hs, cfg.ssm_state))
    Ch = jnp.broadcast_to(Cm[:, :, None, :], (B_, S, Hs, cfg.ssm_state))
    y, h_last = ssd_chunked(xh, dt, A, Bh, Ch, p[f"{prefix}/d_skip"],
                            chunk=cfg.ssm_chunk, h0=h_state,
                            unroll=cfg.inner_unroll)
    y = y.reshape(B_, S, -1)
    y = rms_norm(y, p[f"{prefix}/gate_norm"]) * jax.nn.silu(z)
    return x + jnp.einsum("bsn,nd->bsd", y, p[f"{prefix}/w_out"]), (conv_state, h_last)


# ===========================================================================
# Full forward (train / scoring): scan over units per segment
# ===========================================================================

def _unit_forward(cfg: ModelConfig, seg: Segment, si: int, x, positions,
                  unit_params, enc_out=None, key_prefix: str = "seg",
                  causal: bool = True):
    """One pattern unit.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    for li, kind in enumerate(seg.pattern):
        pref = f"{key_prefix}{si}/l{li}"
        if kind in ("attn", "moe", "xattn"):
            x, _ = _self_attn_block(cfg, unit_params, f"{pref}/attn", x,
                                    positions, causal=causal)
            if kind == "xattn":
                h = _norm(cfg, x, unit_params, f"{pref}/xattn")
                q = jnp.einsum("bsd,dhk->bshk", h, unit_params[f"{pref}/xattn/wq"])
                if cfg.bias:
                    q = q + unit_params[f"{pref}/xattn/bq"]
                k = jnp.einsum("bsd,dhk->bshk", enc_out, unit_params[f"{pref}/xattn/wk"])
                v = jnp.einsum("bsd,dhk->bshk", enc_out, unit_params[f"{pref}/xattn/wv"])
                if cfg.bias:
                    k = k + unit_params[f"{pref}/xattn/bk"]
                    v = v + unit_params[f"{pref}/xattn/bv"]
                o = chunked_attention(q, k, v, AttnSpec(causal=False, chunk=cfg.attn_chunk, unroll=cfg.inner_unroll))
                x = x + _attn_out(cfg, unit_params, f"{pref}/xattn", o)
            if kind == "moe":
                x, a = _moe_block(cfg, unit_params, f"{pref}/moe", x)
                aux = aux + a
            else:
                x = _mlp_block(cfg, unit_params, f"{pref}/mlp", x)
        elif kind == "rglru":
            x, _ = _rglru_block(cfg, unit_params, f"{pref}/rglru", x)
            x = _mlp_block(cfg, unit_params, f"{pref}/mlp", x)
        elif kind == "ssm":
            x, _ = _ssm_block(cfg, unit_params, f"{pref}/ssm", x)
        else:
            raise ValueError(kind)
    return x, aux


def _segment_params(params: Params, si: int, key_prefix: str = "seg") -> Params:
    pref = f"{key_prefix}{si}/"
    return {k: v for k, v in params.items() if k.startswith(pref)}


def backbone(cfg: ModelConfig, params: Params, x: jax.Array,
             positions: jax.Array, enc_out: Optional[jax.Array] = None,
             remat: bool = True, segments: Optional[Tuple[Segment, ...]] = None,
             key_prefix: str = "seg", causal: bool = True
             ) -> Tuple[jax.Array, jax.Array]:
    """Apply all segments.  Returns (hidden, total_aux_loss)."""
    from ..dist.context import constrain_param
    from .encdec import build_encdec_specs as _enc_specs

    segs = cfg.segments if segments is None else segments
    total_aux = jnp.zeros((), jnp.float32)
    all_specs = (_enc_specs(cfg) if cfg.encoder_segments else
                 build_specs(cfg))
    for si, seg in enumerate(segs):
        sp = _segment_params(params, si, key_prefix)
        axes_map = {k: all_specs[k].axes[1:] for k in sp if k in all_specs}

        def unit(carry, unit_params, seg=seg, si=si, axes_map=axes_map):
            h, aux = carry
            # Sequence-parallel layer boundary: the scan carry (the saved
            # activation in the remat scheme) is stored seq-sharded on the
            # model axis — 16x less per-chip activation memory.
            h = constrain(h, "batch", "seq_model", None)
            # Pin per-unit param slices (=> their cotangents) to the param
            # sharding; unsharded per-unit weight grads otherwise dominate
            # temp memory for MoE/large-d archs.
            unit_params = {k: constrain_param(v, axes_map[k])
                           if k in axes_map else v
                           for k, v in unit_params.items()}
            h, a = _unit_forward(cfg, seg, si, h, positions, unit_params,
                                 enc_out=enc_out, key_prefix=key_prefix,
                                 causal=causal)
            h = constrain(h, "batch", "seq_model", None)
            return (h, aux + a), None

        if remat:
            unit = jax.checkpoint(
                unit, policy=jax.checkpoint_policies.nothing_saveable
            )
        (x, total_aux), _ = jax.lax.scan(unit, (x, total_aux), sp)
    return x, total_aux


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    x = params["embed/tokens"][tokens]
    return x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)


def unembed(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "ln":
        x = layer_norm(x, params["final_norm"], params["final_norm_bias"])
    else:
        x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed/tokens"])
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"])


def lm_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token loss.  batch: tokens (B,S) int32, labels (B,S) int32
    (-1 = masked), optional patches (B,P,D) for VLM."""
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vision":
        patches = batch["patches"].astype(x.dtype)  # precomputed stub embeds
        x = jnp.concatenate([patches, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, aux = backbone(cfg, params, x, positions, remat=remat)
    if cfg.frontend == "vision":
        x = x[:, batch["patches"].shape[1]:]
    loss, metrics = xent_loss(cfg, params, x, batch["labels"])
    if cfg.num_experts:
        loss = loss + 0.01 * aux
    metrics["aux"] = aux
    return loss, metrics


def xent_loss(cfg: ModelConfig, params: Params, hidden: jax.Array,
              labels: jax.Array, block: int = 1024
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Blockwise sharded next-token cross-entropy.

    The full (B, S, V) f32 logits tensor is ~1 GiB/chip at the assigned
    train cells and the naive loss keeps tens of copies live (fwd + bwd).
    Instead the sequence is scanned in blocks: each block computes its
    logits, lse and label logit, wrapped in jax.checkpoint so the backward
    recomputes them blockwise too.  Logits stay vocab-sharded on "model";
    the label logit is a one-hot contraction (partition-friendly — no
    cross-shard gather)."""
    hidden = constrain(hidden, "batch", "seq_model", None)
    B, S, D = hidden.shape
    nb = max(S // block, 1)
    while S % nb:
        nb -= 1
    blk = S // nb
    hb = hidden.reshape(B, nb, blk, D).transpose(1, 0, 2, 3)
    lb = labels.reshape(B, nb, blk).transpose(1, 0, 2)

    @jax.checkpoint
    def block_loss(carry, xs):
        h, lab = xs
        logits = unembed(cfg, params, h).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "model")
        mask = (lab >= 0).astype(jnp.float32)
        lmax = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
        shifted = logits - lmax
        lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
        onehot = jax.nn.one_hot(jnp.maximum(lab, 0), cfg.vocab_size,
                                dtype=shifted.dtype)
        label_logit = jnp.einsum("bsv,bsv->bs", shifted, onehot)
        nll, cnt = carry
        nll = nll - ((label_logit - lse) * mask).sum()
        return (nll, cnt + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(
        block_loss, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hb, lb), unroll=nb if cfg.inner_unroll else 1)
    loss = nll / jnp.maximum(cnt, 1.0)
    return loss, {"xent": loss, "tokens": cnt}


# ===========================================================================
# Serving: prefill + single-token decode with caches
# ===========================================================================

def cache_shape_specs(cfg: ModelConfig, batch: int, cache_size: int,
                      dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the decode cache.  Attention KV caches are
    bounded by the window size when the arch is windowed (ring buffer) —
    that is exactly why windowed/SSM archs run the long_500k cell."""
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    dt = dtype
    attn_S = min(cache_size, cfg.window) if cfg.window > 0 else cache_size
    for si, seg in enumerate(cfg.segments):
        U = seg.num_units
        for li, kind in enumerate(seg.pattern):
            pref = f"seg{si}/l{li}"
            if kind in ("attn", "moe", "xattn"):
                kv = (U, batch, attn_S, cfg.num_kv_heads, cfg.head_dim)
                out[f"{pref}/k"] = jax.ShapeDtypeStruct(kv, dt)
                out[f"{pref}/v"] = jax.ShapeDtypeStruct(kv, dt)
                if kind == "xattn":
                    xkv = (U, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
                    out[f"{pref}/xk"] = jax.ShapeDtypeStruct(xkv, dt)
                    out[f"{pref}/xv"] = jax.ShapeDtypeStruct(xkv, dt)
            elif kind == "rglru":
                N, T = cfg.lru_width, cfg.conv_width
                out[f"{pref}/conv"] = jax.ShapeDtypeStruct((U, batch, T - 1, N), dt)
                out[f"{pref}/h"] = jax.ShapeDtypeStruct((U, batch, N), jnp.float32)
            elif kind == "ssm":
                Din, T = cfg.ssm_d_inner, cfg.conv_width
                Hs, N, P = cfg.ssm_num_heads, cfg.ssm_state, cfg.ssm_head_dim
                out[f"{pref}/conv"] = jax.ShapeDtypeStruct((U, batch, T - 1, Din), dt)
                out[f"{pref}/h"] = jax.ShapeDtypeStruct((U, batch, Hs, N, P), jnp.float32)
    return out


def init_cache(cfg: ModelConfig, batch: int, cache_size: int,
               dtype=jnp.bfloat16) -> Cache:
    return {k: jnp.zeros(s.shape, s.dtype)
            for k, s in cache_shape_specs(cfg, batch, cache_size, dtype).items()}


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                cache_len: jax.Array, tokens: jax.Array,
                enc_out: Optional[jax.Array] = None) -> Tuple[jax.Array, Cache]:
    """One decode step.  tokens: (B, 1) int32; cache_len: scalar int32 —
    number of tokens already in the cache.  Returns (logits (B,1,V), cache).

    Attention caches are ring buffers of size min(cache, window): the write
    slot is cache_len % size; RoPE is applied at insert with the absolute
    position so the ring ordering is irrelevant to attention math.
    """
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.asarray(cache_len, jnp.int32)[None]  # (1,) absolute
    if cfg.abs_positions:
        from ..layers.common import sinusoidal_at

        x = x + sinusoidal_at(positions, cfg.d_model, x.dtype)
    new_cache: Cache = {}

    for si, seg in enumerate(cfg.segments):
        sp = _segment_params(params, si)
        seg_cache = {k[len(f"seg{si}/"):]: v for k, v in cache.items()
                     if k.startswith(f"seg{si}/")}

        # The cache rides in the scan CARRY and is updated in place with
        # dynamic_update_index; emitting updated slices as stacked ys would
        # double-buffer the entire multi-GiB cache in temp memory (observed
        # +14 GiB on internvl2 decode_32k).
        def unit(carry, xs, seg=seg, si=si):
            h, cache_full = carry
            unit_params, u = xs
            unit_cache = {k: jax.lax.dynamic_index_in_dim(v, u, 0, False)
                          for k, v in cache_full.items()}
            upd: Dict[str, jax.Array] = {}
            for li, kind in enumerate(seg.pattern):
                pref = f"seg{si}/l{li}"
                cpref = f"l{li}"
                if kind in ("attn", "moe", "xattn"):
                    hh = _norm(cfg, h, unit_params, f"{pref}/attn")
                    q, k, v = _qkv(cfg, unit_params, f"{pref}/attn", hh, positions)
                    kc, vc = unit_cache[f"{cpref}/k"], unit_cache[f"{cpref}/v"]
                    size = kc.shape[1]
                    slot = jnp.mod(cache_len, size)
                    kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
                    vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
                    upd[f"{cpref}/k"], upd[f"{cpref}/v"] = kc, vc
                    valid = jnp.minimum(cache_len + 1, size)
                    o = decode_attention(q, kc, vc, valid,
                                         AttnSpec(causal=True, window=0,
                                                  logit_cap=cfg.logit_cap))
                    h = h + _attn_out(cfg, unit_params, f"{pref}/attn", o)
                    if kind == "xattn":
                        hh = _norm(cfg, h, unit_params, f"{pref}/xattn")
                        q = jnp.einsum("bsd,dhk->bshk", hh,
                                       unit_params[f"{pref}/xattn/wq"])
                        if cfg.bias:
                            q = q + unit_params[f"{pref}/xattn/bq"]
                        xk, xv = unit_cache[f"{cpref}/xk"], unit_cache[f"{cpref}/xv"]
                        o = decode_attention(q, xk, xv, xk.shape[1],
                                             AttnSpec(causal=False))
                        h = h + _attn_out(cfg, unit_params, f"{pref}/xattn", o)
                    if kind == "moe":
                        y, _ = _moe_block(cfg, unit_params, f"{pref}/moe", h)
                        h = y
                    else:
                        h = _mlp_block(cfg, unit_params, f"{pref}/mlp", h)
                elif kind == "rglru":
                    hh = _norm(cfg, h, unit_params, f"{pref}/rglru")
                    xb = jnp.einsum("bsd,dn->bsn", hh, unit_params[f"{pref}/rglru/w_x"])
                    gate = jax.nn.gelu(jnp.einsum(
                        "bsd,dn->bsn", hh, unit_params[f"{pref}/rglru/w_gate"]))
                    xb, conv = short_conv1d(xb, unit_params[f"{pref}/rglru/conv_w"],
                                            unit_cache[f"{cpref}/conv"])
                    r, i = _rglru_gates(unit_params, f"{pref}/rglru", xb)
                    y, hst = rglru_step(xb[:, 0], r[:, 0], i[:, 0],
                                        unit_params[f"{pref}/rglru/a_param"],
                                        unit_cache[f"{cpref}/h"])
                    y = y[:, None] * gate
                    h = h + jnp.einsum("bsn,nd->bsd", y,
                                       unit_params[f"{pref}/rglru/w_out"])
                    upd[f"{cpref}/conv"], upd[f"{cpref}/h"] = conv, hst
                    h = _mlp_block(cfg, unit_params, f"{pref}/mlp", h)
                elif kind == "ssm":
                    hh = _norm(cfg, h, unit_params, f"{pref}/ssm")
                    pr = f"{pref}/ssm"
                    z = jnp.einsum("bsd,dn->bsn", hh, unit_params[f"{pr}/w_z"])
                    xi = jnp.einsum("bsd,dn->bsn", hh, unit_params[f"{pr}/w_x"])
                    xi, conv = short_conv1d(xi, unit_params[f"{pr}/conv_w"],
                                            unit_cache[f"{cpref}/conv"])
                    xi = jax.nn.silu(xi)
                    Bm = jnp.einsum("bsd,dn->bsn", hh, unit_params[f"{pr}/w_B"])[:, 0]
                    Cm = jnp.einsum("bsd,dn->bsn", hh, unit_params[f"{pr}/w_C"])[:, 0]
                    dt = jax.nn.softplus(
                        jnp.einsum("bsd,dh->bsh", hh, unit_params[f"{pr}/w_dt"])[:, 0]
                        + unit_params[f"{pr}/dt_bias"])
                    A = -jax.nn.softplus(unit_params[f"{pr}/a_log"].astype(jnp.float32))
                    B_, _, Din = xi.shape
                    Hs, P = cfg.ssm_num_heads, cfg.ssm_head_dim
                    xh = xi[:, 0].reshape(B_, Hs, P)
                    Bh = jnp.broadcast_to(Bm[:, None, :], (B_, Hs, cfg.ssm_state))
                    Ch = jnp.broadcast_to(Cm[:, None, :], (B_, Hs, cfg.ssm_state))
                    y, hst = ssd_step(xh, dt, A, Bh, Ch,
                                      unit_params[f"{pr}/d_skip"],
                                      unit_cache[f"{cpref}/h"])
                    y = y.reshape(B_, 1, Din)
                    y = rms_norm(y, unit_params[f"{pr}/gate_norm"]) * jax.nn.silu(z)
                    h = h + jnp.einsum("bsn,nd->bsd", y, unit_params[f"{pr}/w_out"])
                    upd[f"{cpref}/conv"], upd[f"{cpref}/h"] = conv, hst
                else:
                    raise ValueError(kind)
            new_full = dict(cache_full)
            for k, val in upd.items():
                new_full[k] = jax.lax.dynamic_update_index_in_dim(
                    cache_full[k], val, u, 0)
            return (h, new_full), None

        U = next(iter(sp.values())).shape[0]
        (x, seg_cache), _ = jax.lax.scan(
            unit, (x, seg_cache), (sp, jnp.arange(U)))
        for k, v in seg_cache.items():
            new_cache[f"seg{si}/{k}"] = v

    logits = unembed(cfg, params, x).astype(jnp.float32)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            cache_size: int, patches: Optional[jax.Array] = None,
            enc_out: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Cache, jax.Array]:
    """Run the full prompt, build the decode cache.  Returns
    (last-position logits (B,V), cache, cache_len scalar).

    With cfg.prefill_row_chunks > 1 the batch rows are processed in
    sequential chunks, each writing its rows of the shared cache in place —
    bounding prefill activation memory for the 32k cells."""
    nchunks = max(cfg.prefill_row_chunks, 1)
    if nchunks > 1 and tokens.shape[0] % nchunks == 0:
        return _prefill_row_chunked(cfg, params, tokens, cache_size,
                                    patches, enc_out, nchunks)
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vision" and patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    S_total = x.shape[1]
    positions = jnp.arange(S_total)
    if cfg.abs_positions:
        from ..layers.common import sinusoidal_at

        x = x + sinusoidal_at(positions, cfg.d_model, x.dtype)
    cache = init_cache(cfg, B, cache_size, dtype=params["embed/tokens"].dtype)
    new_cache: Cache = {}

    for si, seg in enumerate(cfg.segments):
        sp = _segment_params(params, si)
        seg_cache = {k[len(f"seg{si}/"):]: v for k, v in cache.items()
                     if k.startswith(f"seg{si}/")}

        def unit(h, xs, seg=seg, si=si):
            unit_params, unit_cache = xs
            upd: Dict[str, jax.Array] = {}
            for li, kind in enumerate(seg.pattern):
                pref = f"seg{si}/l{li}"
                cpref = f"l{li}"
                if kind in ("attn", "moe", "xattn"):
                    hh = _norm(cfg, h, unit_params, f"{pref}/attn")
                    q, k, v = _qkv(cfg, unit_params, f"{pref}/attn", hh, positions)
                    if cfg.attn_skip:  # cost-attribution variant
                        o = q + 0.0 * (k.sum() + v.sum())
                    else:
                        o = chunked_attention(q, k, v, _attn_spec(cfg, True))
                    h = h + _attn_out(cfg, unit_params, f"{pref}/attn", o)
                    kc, vc = unit_cache[f"{cpref}/k"], unit_cache[f"{cpref}/v"]
                    size = kc.shape[1]
                    ins = min(size, S_total)
                    if ins < S_total:
                        # Ring buffer: keep slot t%size = token t so decode's
                        # write pointer (cache_len % size) evicts the oldest.
                        slots = jnp.mod(jnp.arange(S_total - ins, S_total), size)
                        kc = kc.at[:, slots].set(k[:, -ins:])
                        vc = vc.at[:, slots].set(v[:, -ins:])
                    else:
                        kc = jax.lax.dynamic_update_slice(
                            kc, k[:, -ins:], (0, 0, 0, 0))
                        vc = jax.lax.dynamic_update_slice(
                            vc, v[:, -ins:], (0, 0, 0, 0))
                    upd[f"{cpref}/k"], upd[f"{cpref}/v"] = kc, vc
                    if kind == "xattn":
                        hh = _norm(cfg, h, unit_params, f"{pref}/xattn")
                        q = jnp.einsum("bsd,dhk->bshk", hh,
                                       unit_params[f"{pref}/xattn/wq"])
                        xk = jnp.einsum("bsd,dhk->bshk", enc_out,
                                        unit_params[f"{pref}/xattn/wk"])
                        xv = jnp.einsum("bsd,dhk->bshk", enc_out,
                                        unit_params[f"{pref}/xattn/wv"])
                        if cfg.bias:
                            q = q + unit_params[f"{pref}/xattn/bq"]
                            xk = xk + unit_params[f"{pref}/xattn/bk"]
                            xv = xv + unit_params[f"{pref}/xattn/bv"]
                        o = chunked_attention(q, xk, xv,
                                              AttnSpec(causal=False, chunk=cfg.attn_chunk, unroll=cfg.inner_unroll))
                        h = h + _attn_out(cfg, unit_params, f"{pref}/xattn", o)
                        upd[f"{cpref}/xk"], upd[f"{cpref}/xv"] = xk, xv
                    if kind == "moe":
                        h, _ = _moe_block(cfg, unit_params, f"{pref}/moe", h)
                    else:
                        h = _mlp_block(cfg, unit_params, f"{pref}/mlp", h)
                elif kind == "rglru":
                    h, (conv, hst) = _rglru_block(
                        cfg, unit_params, f"{pref}/rglru", h,
                        conv_state=unit_cache[f"{cpref}/conv"],
                        h_state=unit_cache[f"{cpref}/h"])
                    upd[f"{cpref}/conv"], upd[f"{cpref}/h"] = conv, hst
                    h = _mlp_block(cfg, unit_params, f"{pref}/mlp", h)
                elif kind == "ssm":
                    h, (conv, hst) = _ssm_block(
                        cfg, unit_params, f"{pref}/ssm", h,
                        conv_state=unit_cache[f"{cpref}/conv"],
                        h_state=unit_cache[f"{cpref}/h"])
                    upd[f"{cpref}/conv"], upd[f"{cpref}/h"] = conv, hst
                else:
                    raise ValueError(kind)
            return h, upd

        x, updates = jax.lax.scan(unit, x, (sp, seg_cache))
        for k, v in updates.items():
            new_cache[f"seg{si}/{k}"] = v

    logits = unembed(cfg, params, x[:, -1:]).astype(jnp.float32)[:, 0]
    return logits, new_cache, jnp.asarray(S_total, jnp.int32)


def _prefill_row_chunked(cfg: ModelConfig, params: Params, tokens: jax.Array,
                         cache_size: int, patches, enc_out, nchunks: int):
    """Sequential batch-row chunks; cache rides the scan carry and each
    chunk dynamic-updates its rows (dim 1 of every cache leaf)."""
    import dataclasses as _dc

    B = tokens.shape[0]
    Bc = B // nchunks
    inner_cfg = _dc.replace(cfg, prefill_row_chunks=1)
    cache = init_cache(cfg, B, cache_size,
                       dtype=params["embed/tokens"].dtype)

    def chunk(carry_cache, idx):
        tok_c = jax.lax.dynamic_slice_in_dim(tokens, idx * Bc, Bc, 0)
        pat_c = (jax.lax.dynamic_slice_in_dim(patches, idx * Bc, Bc, 0)
                 if patches is not None else None)
        enc_c = (jax.lax.dynamic_slice_in_dim(enc_out, idx * Bc, Bc, 0)
                 if enc_out is not None else None)
        logits_c, cache_c, clen = prefill(inner_cfg, params, tok_c,
                                          cache_size, pat_c, enc_c)
        new_cache = {
            k: jax.lax.dynamic_update_slice_in_dim(carry_cache[k],
                                                   cache_c[k].astype(
                                                       carry_cache[k].dtype),
                                                   idx * Bc, 1)
            for k in carry_cache
        }
        return new_cache, logits_c

    cache, logits_chunks = jax.lax.scan(
        chunk, cache, jnp.arange(nchunks),
        unroll=nchunks if cfg.inner_unroll else 1)
    logits = logits_chunks.reshape(B, -1)
    S_total = tokens.shape[1] + (patches.shape[1] if patches is not None
                                 and cfg.frontend == "vision" else 0)
    return logits, cache, jnp.asarray(S_total, jnp.int32)
