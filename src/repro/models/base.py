"""Architecture registry + assigned shape cells + input specs.

Every assigned architecture is a selectable config (``--arch <id>``); each
(arch x shape) cell is exercised by ``repro.launch.dryrun`` via
``input_specs`` (ShapeDtypeStruct stand-ins — no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

ARCH_IDS: List[str] = [
    "recurrentgemma_9b",
    "yi_6b",
    "starcoder2_7b",
    "granite_8b",
    "chatglm3_6b",
    "olmoe_1b_7b",
    "mixtral_8x22b",
    "internvl2_76b",
    "whisper_medium",
    "mamba2_370m",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.config()


def cell_supported(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    """None if the (arch x shape) cell runs; else the documented skip reason
    (DESIGN.md §Arch-applicability)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return (f"{cfg.name}: pure full-attention arch — long_500k needs "
                "sub-quadratic attention (see DESIGN.md)")
    return None


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one cell, as ShapeDtypeStructs (weak-type-correct,
    shardable, no device allocation)."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if cfg.frontend == "vision":
        s_text = S - cfg.num_patches
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, s_text), i32),
            "patches": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), bf16),
        }
        if cell.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
        return specs
    if cfg.frontend == "audio":
        specs = {
            "frames": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), bf16),
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cell.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if cell.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    return specs
