"""Parameter specification system.

Each model family builds a flat ``{path: ParamSpec}`` table once; everything
else derives from it:

* ``init_params``     — real arrays (smoke tests / examples; small configs only),
* ``shape_structs``   — ``jax.ShapeDtypeStruct`` stand-ins (dry-run; no alloc),
* ``partition_specs`` — ``PartitionSpec`` per leaf from logical-axis rules
                        (``repro.dist.sharding``).

Logical axis names used across the zoo:

  layers   — scanned layer stack (never sharded)
  embed    — d_model dims           (FSDP -> "data")
  heads    — attention-head dims    (TP -> "model")
  kv_heads — KV-head dims           (TP -> "model" when divisible else None)
  ffn      — feed-forward hidden    (TP -> "model")
  vocab    — vocabulary             (TP -> "model")
  experts  — MoE expert dim         (EP -> "model" when divisible)
  state    — SSM/RG-LRU recurrent state (None)
  conv     — short-conv taps        (None)
  frames   — frontend positions     (None)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"  # "normal" | "zeros" | "ones" | "rglru_a" | "ssm_dt"
    fan_in_axis: Optional[int] = None  # for scaled normal init

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape/axes rank mismatch: {self.shape} vs {self.axes}")


Specs = Dict[str, ParamSpec]


def num_params(specs: Specs) -> int:
    return sum(int(np.prod(s.shape)) for s in specs.values())


def shape_structs(specs: Specs) -> Dict[str, jax.ShapeDtypeStruct]:
    return {k: jax.ShapeDtypeStruct(s.shape, s.dtype) for k, s in specs.items()}


def _init_leaf(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "rglru_a":
        # Griffin's a-parameter: softplus-inverse spread so that the gate
        # a = sigmoid(param)^(c*r) starts near 0.9..0.999 per channel.
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        return jnp.log(u / (1 - u)).astype(spec.dtype)
    if spec.init == "ssm_dt":
        # Mamba dt bias: log-uniform in [1e-3, 1e-1] through softplus-inverse.
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
        return jnp.log(jnp.expm1(dt)).astype(spec.dtype)
    fan_in = (
        spec.shape[spec.fan_in_axis]
        if spec.fan_in_axis is not None
        else (spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1])
    )
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def init_params(specs: Specs, key: jax.Array) -> Dict[str, jax.Array]:
    keys = jax.random.split(key, len(specs))
    return {k: _init_leaf(kk, s) for (k, s), kk in zip(sorted(specs.items()), keys)}


def count_table(specs: Specs) -> str:
    rows = [f"{k:60s} {str(s.shape):28s} {int(np.prod(s.shape)):>14,d}"
            for k, s in sorted(specs.items())]
    rows.append(f"{'TOTAL':60s} {'':28s} {num_params(specs):>14,d}")
    return "\n".join(rows)
