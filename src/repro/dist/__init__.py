"""repro.dist — logical-axis sharding and roofline utilities.

Three small modules consumed across the model zoo and launch tooling:

* ``context``  — ``constrain``/``constrain_param``: logical-axis sharding
                 constraints that are no-ops outside a mesh context, so the
                 same model code runs on a laptop CPU and a multi-pod mesh.
* ``sharding`` — PartitionSpec derivation from the logical axis names of
                 ``repro.models.params.ParamSpec`` (FSDP on "data", TP on
                 "model", DP for inputs/caches), plus the scheduler's 1-D
                 batch splits (``batch_shard_extents`` /
                 ``weighted_shard_extents``) and divisibility-fallback
                 reporting (``on_fallback``).
* ``mesh``     — ``DeviceMesh``/``MeshBackend``: real multi-device
                 execution of the scheduler's shard dispatch (fused
                 ``shard_map`` segagg across the data axis, worker clocks
                 from measured wall seconds).
* ``roofline`` — compute/memory/collective roofline record + HLO collective
                 parser used by ``repro.launch.dryrun``.
"""
from .context import (
    ACT_AXIS_RULES,
    PARAM_AXIS_RULES,
    active_mesh,
    constrain,
    constrain_param,
    mesh_context,
)
from .roofline import (
    CollectiveStats,
    KernelRooflineManager,
    MachineSpec,
    Roofline,
    parse_collectives,
)
from .mesh import DeviceMesh, MeshBackend
from .sharding import (
    batch_shard_extents,
    batch_spec,
    cache_pspecs,
    input_pspecs,
    on_fallback,
    param_pspecs,
    param_shardings,
    weighted_shard_extents,
)

__all__ = [
    "ACT_AXIS_RULES",
    "CollectiveStats",
    "DeviceMesh",
    "KernelRooflineManager",
    "MachineSpec",
    "MeshBackend",
    "PARAM_AXIS_RULES",
    "Roofline",
    "active_mesh",
    "batch_shard_extents",
    "batch_spec",
    "cache_pspecs",
    "constrain",
    "constrain_param",
    "input_pspecs",
    "mesh_context",
    "on_fallback",
    "param_pspecs",
    "param_shardings",
    "parse_collectives",
    "weighted_shard_extents",
]
