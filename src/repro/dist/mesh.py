"""DeviceMesh: the scheduling-side facade over real jax devices.

Everything the scheduler proved on modelled clocks — MinBatch sizing,
shard dispatch, the C_max blocking bound — is only half-validated until
the shards run on REAL devices.  This module is the bridge:

* ``DeviceMesh`` — a 1-D ``jax.sharding.Mesh`` over the scheduling data
  axis.  It maps ``batch_shard_extents`` (the pool's 1-D batch splits)
  onto per-device ``NamedSharding``s, and runs ``segagg``/``pane_segagg``
  as ONE fused ``shard_map`` call across the axis with a final
  cross-device ``merge_panes`` combine.  On CPU, set
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
  initializes to get an N-device host mesh (CI does exactly this).

* ``MeshBackend`` — a ``repro.core.runtime.WorkerBackend`` with one
  worker per mesh device whose clocks are stitched from MEASURED wall
  seconds instead of cost-model predictions.  It prefers GROUP dispatch:
  a ``PolicyDecision``'s whole shard group becomes one fused mesh call,
  so per-dispatch overhead is paid once per logical batch instead of once
  per shard — the paper's overhead-amortization argument applied to
  dispatch fan-out (see ``ShardedCostModel`` for the planning-side view).

Donation invariants: the sharded segagg jit donates its VALUES operand
(the large buffer) so XLA may overlap the host→device transfer of the
next batch with compute and reuse the donated pages for the output.
Callers must therefore treat the values array as CONSUMED — pass a fresh
(or numpy-backed) array per call, never reuse a jax array across calls.
Keys are small and not donated.  Padding rows (to make N divisible by the
device count) carry ``key == num_groups``: dropped by the scatter path,
an all-zero one-hot row in the matmul path, the sacrificial group in the
Pallas path — numerics are unaffected on every backend.
"""
from __future__ import annotations

import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.runtime import Dispatch, WorkerBackend
from ..kernels.segagg.ops import merge_panes, pane_composite_groups, segagg
from .context import constrain, mesh_context
from .sharding import batch_shard_extents, batch_spec, on_fallback

# Donation is a best-effort hint: platforms without buffer aliasing (CPU)
# warn per compile that the donated operand was not usable.  The fallback
# (a copy) is correct, and the warning would fire on every cache miss of
# the sharded-segagg jit, so silence exactly that message.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable"
)


class DeviceMesh:
    """A 1-D device mesh over the scheduling data axis.

    ``devices`` may be an int (the first k of ``jax.devices()``), an
    explicit device sequence, or None for every visible device.  The axis
    is named ``"data"`` so ``dist.sharding``'s data-parallel rules
    (``batch_spec``, ``constrain(x, "batch")``) resolve against it
    unchanged.

    ``on_event`` (plus the ``events`` list) receives ``sharding_fallback``
    dicts whenever a batch dim stays replicated because the device count
    does not divide it — under-sharding is correct but slow, so it is
    reported, never silent.
    """

    def __init__(
        self,
        devices: Union[int, Sequence, None] = None,
        *,
        axis: str = "data",
        on_event: Optional[Callable[[Dict], None]] = None,
    ):
        if devices is None:
            devs = list(jax.devices())
        elif isinstance(devices, int):
            if devices < 1:
                raise ValueError(f"need at least one device, got {devices}")
            visible = list(jax.devices())
            if len(visible) < devices:
                raise ValueError(
                    f"need {devices} devices but jax sees {len(visible)}; "
                    f"on CPU set XLA_FLAGS=--xla_force_host_platform_"
                    f"device_count={devices} in the environment BEFORE jax "
                    f"initializes (first import wins)"
                )
            devs = visible[:devices]
        else:
            devs = list(devices)
            if not devs:
                raise ValueError("need at least one device")
        self.axis = axis
        self.mesh = Mesh(np.array(devs), (axis,))
        self.events: List[Dict] = []
        self._on_event = on_event
        self._jit_cache: Dict[Tuple, Callable] = {}

    # -- introspection ----------------------------------------------------
    @property
    def num_devices(self) -> int:
        return self.mesh.devices.size

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        kind = self.mesh.devices.flat[0].platform
        return f"DeviceMesh({self.num_devices}x{kind}, axis={self.axis!r})"

    def _emit(self, event: Dict) -> None:
        self.events.append(event)
        if self._on_event is not None:
            self._on_event(event)

    # -- extents <-> shardings --------------------------------------------
    def shard_extents(self, num_tuples: int) -> Tuple[Tuple[int, int], ...]:
        """The pool's 1-D batch split for this mesh: ``batch_shard_extents``
        over the device count.  When the count divides ``num_tuples`` these
        extents are EXACTLY the per-device rows of ``batch_sharding`` (the
        consistency the tests pin)."""
        return batch_shard_extents(num_tuples, self.num_devices)

    def batch_sharding(self, batch_rows: int, ndim: int) -> NamedSharding:
        """NamedSharding for a ``(batch_rows, ...)`` array of rank ``ndim``:
        dim 0 split over the data axis when divisible, replicated (with a
        ``sharding_fallback`` event) otherwise."""
        unsub = on_fallback(self._emit)
        try:
            spec = P(*batch_spec(self.mesh, batch_rows, ndim))
        finally:
            unsub()
        return NamedSharding(self.mesh, spec)

    # -- sharded kernels ---------------------------------------------------
    def _sharded_segagg(self, num_groups: int, backend: Optional[str]):
        key = (num_groups, backend)
        fn = self._jit_cache.get(key)
        if fn is not None:
            return fn
        mesh, axis = self.mesh, self.axis

        def per_shard(k: jax.Array, v: jax.Array) -> jax.Array:
            # Each device runs the SAME compiled single-device kernel over
            # its rows; the leading length-1 axis makes the stacked result
            # (D, G, V) — shaped exactly like pane partials, so the final
            # cross-device combine IS merge_panes.
            return segagg(k, v, num_groups, backend=backend)[None]

        sharded = shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(P(axis), P(axis, None)),
            out_specs=P(axis, None, None),
        )

        def run(k: jax.Array, v: jax.Array) -> jax.Array:
            with mesh_context(mesh):
                k = constrain(k, "batch")
                v = constrain(v, "batch", None)
                return merge_panes(sharded(k, v))

        fn = jax.jit(run, donate_argnums=(1,))
        self._jit_cache[key] = fn
        return fn

    def segagg(
        self,
        keys: jax.Array,
        values: jax.Array,
        num_groups: int,
        *,
        backend: Optional[str] = None,
    ) -> jax.Array:
        """GROUP-BY partial aggregation sharded across the mesh: rows split
        over the data axis, one ``segagg`` per device, partials merged.
        Bit-compatible with the single-device op for integer-valued f32
        inputs; ``values`` is donated (see the module docstring)."""
        keys = jnp.asarray(keys).astype(jnp.int32)
        values = jnp.asarray(values)
        if values.ndim == 1:
            values = values[:, None]
        D = self.num_devices
        if D == 1:
            return segagg(keys, values, num_groups, backend=backend)
        N, V = keys.shape[0], values.shape[1]
        Np = -(-max(N, 1) // D) * D
        if Np != N:
            keys = jnp.concatenate(
                [keys, jnp.full((Np - N,), num_groups, jnp.int32)]
            )
            values = jnp.concatenate(
                [values, jnp.zeros((Np - N, V), values.dtype)]
            )
        return self._sharded_segagg(num_groups, backend)(keys, values)

    def pane_segagg(
        self,
        keys: jax.Array,
        values: jax.Array,
        pane_ids: jax.Array,
        num_panes: int,
        num_groups: int,
        *,
        backend: Optional[str] = None,
    ) -> jax.Array:
        """Pane-partial aggregation sharded across the mesh, via the same
        composite-key reduction as the single-device op: (N,) keys +
        pane_ids -> (num_panes, num_groups, V) per-pane group sums."""
        values = jnp.asarray(values)
        if values.ndim == 1:
            values = values[:, None]
        total = pane_composite_groups(num_panes, num_groups)
        composite = (
            jnp.asarray(pane_ids).astype(jnp.int32) * num_groups
            + jnp.asarray(keys).astype(jnp.int32)
        )
        flat = self.segagg(composite, values, total, backend=backend)
        return flat.reshape(num_panes, num_groups, values.shape[1])


class MeshBackend(WorkerBackend):
    """Worker backend over a ``DeviceMesh``: one worker per device, clocks
    stitched from MEASURED wall seconds.

    The worker clocks still form the scheduling timeline (decision
    instants, waits, deadlines) — but every dispatch advances them by the
    measured duration of the real mesh call instead of a cost-model
    prediction, so traces ARE wall-clock and the cost models can be
    validated against them.

    ``prefers_group_dispatch``: the runtime loop hands a whole shard group
    to ``run_shard_group``, which runs the covering tuple range as ONE
    fused ``shard_map`` call (``_group_execute``) — all claimed workers
    share its start/end.  Subclasses implement the three physical hooks
    (``_batch_execute``/``_group_execute``/``_agg_execute``); see
    ``repro.serve.analytics.MeshAnalyticsBackend`` for the serving one.

    ``worker_weights`` reports measured per-worker throughput ratios from
    SOLO dispatches (group calls are indivisible, so they do not
    attribute).  A homogeneous host mesh stays all-1.0 (below the
    heterogeneity threshold), which keeps shard splits on the balanced
    default path.
    """

    prefers_group_dispatch = True

    #: measured max/min throughput ratio above which the mesh is reported
    #: heterogeneous (weighted shard extents kick in).  Below it, noise.
    heterogeneity_threshold = 1.25

    def __init__(self, mesh: DeviceMesh, names: Optional[Sequence[str]] = None):
        self.mesh = mesh
        if names is None:
            names = tuple(f"d{i}" for i in range(mesh.num_devices))
        elif len(names) != mesh.num_devices:
            raise ValueError(
                f"{len(names)} names for {mesh.num_devices} devices"
            )
        super().__init__(names)
        self._solo_tuples: Dict[str, float] = {n: 0.0 for n in names}
        self._solo_secs: Dict[str, float] = {n: 0.0 for n in names}

    # -- measured heterogeneity -------------------------------------------
    @property
    def worker_weights(self) -> Tuple[float, ...]:
        tp = []
        for n in self.worker_names:
            if self._solo_secs[n] <= 0.0 or self._solo_tuples[n] <= 0.0:
                return (1.0,) * len(self.worker_names)
            tp.append(self._solo_tuples[n] / self._solo_secs[n])
        if max(tp) < self.heterogeneity_threshold * min(tp):
            return (1.0,) * len(self.worker_names)
        mean = sum(tp) / len(tp)
        return tuple(t / mean for t in tp)

    # -- dispatch ----------------------------------------------------------
    def _charge(self, query, dt: float) -> None:
        self.wall_seconds[query.query_id] = (
            self.wall_seconds.get(query.query_id, 0.0) + dt
        )

    def run_batch(self, query, num_tuples, offset, worker):
        start = self._clocks[worker]
        t0 = time.perf_counter()
        self._batch_execute(query, num_tuples, offset)
        dt = time.perf_counter() - t0
        self.last_batch_wall = dt
        self._charge(query, dt)
        self._solo_tuples[worker] += num_tuples
        self._solo_secs[worker] += dt
        end = start + dt
        self._clocks[worker] = end
        return Dispatch(worker=worker, start=start, end=end), dt

    def run_shard_group(self, query, sizes, base_offset, workers):
        # The fused call cannot start before the LAST claimed worker frees
        # (all devices participate in the shard_map).
        start = max(self._clocks[w] for w in workers)
        t0 = time.perf_counter()
        self._group_execute(query, sizes, base_offset, workers)
        dt = time.perf_counter() - t0
        self.last_batch_wall = dt
        self._charge(query, dt)
        end = start + dt
        for w in workers:
            self._clocks[w] = end
        return tuple(
            Dispatch(worker=w, start=start, end=end) for w in workers
        )

    def run_agg(self, query, num_batches, worker, start, barrier):
        t0 = time.perf_counter()
        self._agg_execute(query, num_batches)
        dt = time.perf_counter() - t0
        self.last_agg_wall = dt
        self._charge(query, dt)
        if dt > 0:
            self._clocks[worker] = start + dt
            return Dispatch(worker=worker, start=start, end=start + dt), dt
        return Dispatch(worker=worker, start=barrier, end=barrier), dt

    # -- physical hooks ----------------------------------------------------
    def _batch_execute(self, query, num_tuples: int, offset: int) -> None:
        """Process tuples [offset, offset + num_tuples) on the mesh (solo
        dispatch: one shard)."""
        raise NotImplementedError

    def _group_execute(
        self,
        query,
        sizes: Tuple[int, ...],
        base_offset: int,
        workers: Tuple[str, ...],
    ) -> None:
        """Process the covering range [base_offset, base_offset +
        sum(sizes)) as ONE fused mesh call."""
        raise NotImplementedError

    def _agg_execute(self, query, num_batches: int) -> None:
        """Combine the query's partials into its final result."""
        raise NotImplementedError
