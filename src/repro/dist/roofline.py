"""Roofline accounting + HLO collective parsing (repro.launch.dryrun).

``parse_collectives`` scans compiled HLO text for communication ops
(all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
including their async ``-start`` forms) and sums their output bytes —
the numerator of the ICI term of the roofline.

``Roofline`` records the three per-step time bounds (compute vs HBM vs
interconnect) under the usual overlap assumption: step time ~= the max of
the three ("whichever roof you hit").

``KernelRooflineManager`` applies the same model to single-kernel
micro-benchmarks (the RooflineManager pattern: a machine spec + per-op
analytic FLOPs/bytes -> the bound and the achieved fraction): used by
``benchmarks.bench_roofline`` to report how close the dispatched segagg
backends run to the measured machine roofs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `%name = <shape-or-tuple> <op>(` — shapes look like `bf16[2,16,128]{2,1,0}`.
_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVE_OPS) + r")(-start|-done)?\("
)


def _shape_bytes(shape_text: str) -> float:
    total = 0.0
    for dtype, dims in _ARRAY_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass(frozen=True)
class CollectiveStats:
    """Per-program communication summary: output bytes + op counts."""

    total_bytes: float
    counts: Dict[str, int]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective output bytes / count collective ops in HLO text.

    Async pairs are counted once (the ``-done`` halves are skipped; their
    bytes are already attributed to the ``-start``)."""
    total = 0.0
    counts: Dict[str, int] = {}
    for m in _LINE_RE.finditer(hlo_text):
        shape_text, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        total += _shape_bytes(shape_text)
        counts[op] = counts.get(op, 0) + 1
    return CollectiveStats(total_bytes=total, counts=counts)


@dataclasses.dataclass(frozen=True)
class Roofline:
    """Per-chip roofline for one compiled step program."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def step_seconds(self) -> float:
        """Overlap model: the binding roof decides the step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        bounds = (
            ("compute", self.compute_s),
            ("memory", self.memory_s),
            ("collective", self.collective_s),
        )
        return max(bounds, key=lambda kv: kv[1])[0]

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "step_seconds": self.step_seconds,
            "dominant": self.dominant,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "collective_counts": dict(self.collective_counts),
        }


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Peak rates of the machine a kernel micro-bench ran on.  For TPU these
    are datasheet numbers; for the CPU container they are MEASURED
    achievable rates (a copy-bandwidth probe and a big-matmul FLOPs probe),
    so "achieved fraction" compares against what the host demonstrably
    sustains, not a marketing peak.

    ``peak_flops``/``peak_bw`` are PER-DEVICE rates; ``devices`` records how
    many devices the spec aggregates over (1 = a single device, the
    pre-mesh convention).  ``scaled(n)`` builds the MESH roof — aggregate
    FLOPs/bandwidth across ``n`` devices — so ``bench_roofline`` can report
    achieved fraction of the whole mesh instead of one device's roof.  A
    forced-host CPU mesh shares one socket, so its honest mesh roof is the
    single measured host rate — pass ``n=1`` worth of scaling there (the
    bench decides from the platform)."""

    peak_flops: float    # FLOP/s (per device)
    peak_bw: float       # bytes/s (per device)
    source: str = "measured"
    devices: int = 1

    def scaled(self, num_devices: int) -> "MachineSpec":
        """Aggregate roof over ``num_devices`` devices: peaks multiplied,
        provenance recorded in ``source``."""
        if num_devices < 1:
            raise ValueError(f"need at least one device, got {num_devices}")
        if num_devices == 1:
            return self
        return dataclasses.replace(
            self,
            peak_flops=self.peak_flops * num_devices,
            peak_bw=self.peak_bw * num_devices,
            source=f"{self.source} x{num_devices} devices",
            devices=self.devices * num_devices,
        )


class KernelRooflineManager:
    """Achieved-vs-roofline accounting for single-kernel timings.

    ``info`` rows carry analytic ``flops``/``bytes`` for one call (e.g.
    ``repro.kernels.segagg.ops.flops_bytes``) plus the measured seconds;
    ``get_roofline`` returns the two time bounds, the binding roof, and the
    achieved fraction (bound / measured — 1.0 means running AT the roof).
    """

    def __init__(self, spec: MachineSpec):
        self.spec = spec

    def bound_seconds(self, flops: float, bytes_: float) -> float:
        return max(flops / self.spec.peak_flops, bytes_ / self.spec.peak_bw)

    def get_roofline(self, info: Dict) -> Dict:
        flops, bytes_ = float(info["flops"]), float(info["bytes"])
        measured = float(info["seconds"])
        compute_s = flops / self.spec.peak_flops
        memory_s = bytes_ / self.spec.peak_bw
        bound = max(compute_s, memory_s)
        return {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "bound_s": bound,
            "dominant": "compute" if compute_s >= memory_s else "memory",
            "measured_s": measured,
            "achieved_frac": bound / measured if measured > 0 else 0.0,
            "achieved_gbytes_s": bytes_ / measured / 1e9 if measured > 0 else 0.0,
            "achieved_gflops_s": flops / measured / 1e9 if measured > 0 else 0.0,
        }
