"""Logical-axis sharding constraints (model-code side of repro.dist).

Model code never names mesh axes directly; it names LOGICAL axes —
``constrain(h, "batch", "seq_model", None)`` — and this module resolves them
against the active mesh:

* activations (``constrain``):
    "batch"     -> the data-parallel axes ("pod", "data")
    "seq_model" -> sequence dim stored sharded on "model" (sequence-parallel
                   layer boundaries / remat saves)
    "model"     -> tensor-parallel dim ("model")
    None        -> replicated

* parameters (``constrain_param``): the ParamSpec logical names of
  ``repro.models.params`` ("embed" -> FSDP on "data", "heads"/"ffn"/"vocab"
  -> TP on "model", ...), used to pin per-unit scan slices (and therefore
  their cotangents) to the parameter sharding.

Outside any mesh context — CPU tests, single-device examples — both are
identity functions, so model code is mesh-agnostic.  A mesh is "active"
inside ``with mesh:`` (the jax.sharding.Mesh context manager, as used by
``repro.launch.steps``) or inside ``with mesh_context(mesh):``.

A mesh axis is only applied when the corresponding dim is divisible by the
axis size (XLA requires even sharding for constraints we emit) and when the
axis has not already been consumed by an earlier dim of the same tensor.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical activation axis -> mesh axes (tried in order, kept if present).
ACT_AXIS_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq_model": ("model",),
    "model": ("model",),
}

# Logical parameter axis -> mesh axes (see repro.models.params docstring).
PARAM_AXIS_RULES: Dict[str, Tuple[str, ...]] = {
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
    "state": (),
    "conv": (),
    "frames": (),
}

_local = threading.local()


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    """Explicitly activate ``mesh`` for ``constrain``/``constrain_param``."""
    prev = getattr(_local, "mesh", None)
    _local.mesh = mesh
    try:
        yield mesh
    finally:
        _local.mesh = prev


def active_mesh() -> Optional[Mesh]:
    """The mesh constraints resolve against, or None (constraints no-op).

    Checks the explicit ``mesh_context`` first, then jax's thread-local
    physical mesh (set by ``with mesh:``).
    """
    mesh = getattr(_local, "mesh", None)
    if mesh is not None and not mesh.empty:
        return mesh
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


def _resolve(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    rules: Dict[str, Tuple[str, ...]],
    mesh: Mesh,
) -> Optional[P]:
    """PartitionSpec for ``shape`` under ``rules``; None if fully replicated."""
    used: set = set()
    entries: list = []
    any_sharded = False
    for dim, name in zip(shape, logical_axes):
        axes: Tuple[str, ...] = ()
        if name is not None:
            want = rules.get(name, ())
            picked = []
            size = 1
            for ax in want:
                if ax in mesh.axis_names and ax not in used:
                    picked.append(ax)
                    size *= mesh.shape[ax]
            if picked and size > 0 and dim % size == 0:
                axes = tuple(picked)
        if axes:
            used.update(axes)
            entries.append(axes if len(axes) > 1 else axes[0])
            any_sharded = True
        else:
            entries.append(None)
    if not any_sharded:
        return None
    return P(*entries)


def _constrain_with(
    x: jax.Array,
    logical_axes: Sequence[Optional[str]],
    rules: Dict[str, Tuple[str, ...]],
) -> jax.Array:
    mesh = active_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"rank mismatch: {len(logical_axes)} logical axes for shape {x.shape}"
        )
    spec = _resolve(x.shape, logical_axes, rules, mesh)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Pin an ACTIVATION to the sharding implied by its logical axes.

    Identity when no mesh is active (CPU tests / single device)."""
    return _constrain_with(x, logical_axes, ACT_AXIS_RULES)


def constrain_param(
    x: jax.Array, axes: Union[Sequence[Optional[str]], Tuple[Optional[str], ...]]
) -> jax.Array:
    """Pin a PARAMETER (or its per-unit scan slice) to its spec sharding."""
    return _constrain_with(x, tuple(axes), PARAM_AXIS_RULES)
