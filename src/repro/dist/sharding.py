"""PartitionSpec derivation from logical axis names (launch-side of
repro.dist).

``repro.models.params.ParamSpec`` carries a logical axis name per dim
("embed", "heads", "ffn", ...); these helpers turn a whole spec table into
PartitionSpecs / NamedShardings for one mesh:

* parameters — FSDP on "data" over the embed dim, tensor-parallel on "model"
  over heads/ffn/vocab/experts dims (first eligible dim wins an axis);
* inputs     — batch dim (dim 0) sharded over the data-parallel axes
  ("pod" x "data" on the multi-pod mesh);
* caches     — decode caches are (layer_units, batch, ...): batch dim (dim 1)
  sharded over the data-parallel axes.

A mesh axis is applied to a dim only when the dim size is divisible by the
axis size — otherwise the dim stays replicated (correct, just less sharded),
which keeps reduced-config CPU tests working on 1-device meshes.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .context import PARAM_AXIS_RULES, _resolve

Structs = Dict[str, jax.ShapeDtypeStruct]

# Divisibility-fallback listeners: when a batch-like dim stays REPLICATED
# because the data-parallel axis size does not divide it, every registered
# listener receives one ``{"kind": "sharding_fallback", ...}`` event dict.
# Under-sharding is correct but slow (the whole array lands on every
# device), so it must be reported, not silent — a ``DeviceMesh`` registers
# a listener and surfaces the events on its trace hook.
_fallback_listeners: List[Callable[[Dict], None]] = []


def on_fallback(listener: Callable[[Dict], None]) -> Callable[[], None]:
    """Register a divisibility-fallback listener; returns an unsubscribe
    callable (idempotent)."""
    _fallback_listeners.append(listener)

    def unsubscribe() -> None:
        try:
            _fallback_listeners.remove(listener)
        except ValueError:
            pass

    return unsubscribe


def _emit_fallback(dim: int, axes: Tuple[str, ...], axis_size: int) -> None:
    event = {
        "kind": "sharding_fallback",
        "dim": dim,
        "axes": axes,
        "axis_size": axis_size,
        "detail": (
            f"dim {dim} not divisible by axis size {axis_size} "
            f"({'x'.join(axes)}); dim stays replicated"
        ),
    }
    for listener in tuple(_fallback_listeners):
        listener(event)


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel mesh axes, outermost first."""
    return tuple(ax for ax in ("pod", "data") if ax in mesh.axis_names)


def _dp_entry(mesh: Mesh, dim: int):
    """Spec entry for a batch-like dim: the DP axes if evenly divisible.
    A non-divisible dim stays replicated AND emits a ``sharding_fallback``
    event to the registered ``on_fallback`` listeners."""
    axes = _dp_axes(mesh)
    size = 1
    for ax in axes:
        size *= mesh.shape[ax]
    if not axes or size <= 0:
        return None
    if dim % size:  # only reachable with size >= 2: every dim divides 1
        _emit_fallback(dim, axes, size)
        return None
    return axes if len(axes) > 1 else axes[0]


def param_pspecs(specs: Dict[str, "ParamSpec"], mesh: Mesh) -> Dict[str, P]:  # noqa: F821
    """PartitionSpec per parameter leaf from its logical axes."""
    out: Dict[str, P] = {}
    for name, spec in specs.items():
        resolved = _resolve(spec.shape, spec.axes, PARAM_AXIS_RULES, mesh)
        out[name] = resolved if resolved is not None else P()
    return out


def param_shardings(
    specs: Dict[str, "ParamSpec"], mesh: Mesh  # noqa: F821
) -> Dict[str, NamedSharding]:
    return {k: NamedSharding(mesh, s) for k, s in param_pspecs(specs, mesh).items()}


def batch_spec(mesh: Mesh, batch_rows: int, ndim: int) -> Tuple[Optional[object], ...]:
    """Spec entries for an (batch_rows, ...) array of rank ``ndim``: DP axes
    on dim 0 (when divisible), replicated elsewhere.  Callers may prepend
    extra ``None`` entries for leading dims (e.g. a microbatch dim)."""
    return (_dp_entry(mesh, batch_rows),) + (None,) * (ndim - 1)


def input_pspecs(structs: Structs, mesh: Mesh) -> Dict[str, P]:
    """Batch-shard model inputs over the data-parallel axes (dim 0)."""
    return {
        k: P(*batch_spec(mesh, s.shape[0], len(s.shape)))
        for k, s in structs.items()
    }


def batch_shard_extents(
    num_tuples: int, num_shards: int
) -> Tuple[Tuple[int, int], ...]:
    """Contiguous (offset, size) extents splitting one logical batch across
    ``num_shards`` pool workers — the 1-D scheduling analogue of
    ``batch_spec``'s batch-dim sharding: tuples spread as evenly as
    possible, the remainder going to the earliest shards, empty shards
    dropped (``num_tuples < num_shards`` yields fewer extents, never
    zero-sized ones).  Offsets are relative to the logical batch start, so
    callers add their own base offset; the resulting per-shard partials are
    offset-keyed and combine in ``finalize`` like segagg partials.
    """
    if num_tuples < 0:
        raise ValueError(f"negative num_tuples {num_tuples}")
    if num_shards <= 0:
        raise ValueError(f"need at least one shard, got {num_shards}")
    base, rem = divmod(num_tuples, num_shards)
    extents = []
    offset = 0
    for i in range(num_shards):
        size = base + (1 if i < rem else 0)
        if size == 0:
            break
        extents.append((offset, size))
        offset += size
    return tuple(extents)


def weighted_shard_extents(
    num_tuples: int, weights: Sequence[float]
) -> Tuple[Tuple[int, int], ...]:
    """Contiguous (offset, size) extents splitting one logical batch across
    heterogeneous workers in proportion to ``weights`` (relative worker
    speeds from per-device calibration).  Largest-remainder apportionment:
    each worker gets ``floor(n * w_i / sum(w))`` tuples, the leftover going
    one-by-one to the largest fractional parts (ties to the earliest
    worker).  With equal weights this reduces EXACTLY to
    ``batch_shard_extents``.

    Unlike ``batch_shard_extents``, the result is aligned 1:1 with
    ``weights`` — zero-sized extents are KEPT so callers can zip the result
    with their worker list and drop empty assignments themselves.
    """
    if num_tuples < 0:
        raise ValueError(f"negative num_tuples {num_tuples}")
    if not weights:
        raise ValueError("need at least one weight")
    if any(w < 0 for w in weights):
        raise ValueError(f"weights must be non-negative, got {tuple(weights)}")
    total_w = float(sum(weights))
    if total_w <= 0:
        raise ValueError("at least one weight must be positive")
    ideal = [num_tuples * float(w) / total_w for w in weights]
    sizes = [int(math.floor(x)) for x in ideal]
    leftover = num_tuples - sum(sizes)
    # Largest fractional part first; ties broken toward the earliest worker
    # (matching batch_shard_extents' remainder-to-earliest rule).
    order = sorted(range(len(weights)), key=lambda i: (-(ideal[i] - sizes[i]), i))
    for i in order[:leftover]:
        sizes[i] += 1
    extents = []
    offset = 0
    for size in sizes:
        extents.append((offset, size))
        offset += size
    return tuple(extents)


def cache_pspecs(cfg, structs: Structs, mesh: Mesh) -> Dict[str, P]:
    """Decode-cache shardings: caches are (layer_units, batch, ...) — shard
    the batch dim (dim 1) over the data-parallel axes."""
    out: Dict[str, P] = {}
    for k, s in structs.items():
        if len(s.shape) >= 2:
            out[k] = P(None, _dp_entry(mesh, s.shape[1]),
                       *(None,) * (len(s.shape) - 2))
        else:
            out[k] = P(*(None,) * len(s.shape))
    return out
