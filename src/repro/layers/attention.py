"""Attention for train/prefill (chunked online-softmax) and decode.

The train/prefill path is the SAME blocked algorithm as the Pallas
``flash_attention`` kernel (``repro.kernels.flash_attention``): a scan over KV
chunks carrying running (max, sum, acc).  On TPU the Pallas kernel is used;
the dry-run and CPU tests lower this jnp version, which has identical FLOPs
and O(S * chunk) memory — never the S x S matrix.

GQA is computed with grouped einsums — KV heads are never materialised
repeated across the query-head group (that repeat would cost
(B, S, H, D) transient bytes, ruinous for 32k decode caches).

Supports: causal, sliding-window (SWA / local), bidirectional (whisper
encoder), cross-attention (whisper decoder), GQA, and attention logit
soft-capping (recurrentgemma).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30  # finite, bf16-safe sentinel (avoids NaN from inf-inf)


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    causal: bool = True
    window: int = 0          # >0: sliding window (only last `window` keys)
    logit_cap: float = 0.0   # >0: tanh soft-cap (recurrentgemma uses 50.0)
    chunk: int = 512         # KV chunk length for the online-softmax scan
    unroll: bool = False     # unroll the chunk scan (dry-run cost variants:
                             # XLA cost_analysis counts while bodies once)


def _mask_ok(q_pos: jax.Array, k_pos: jax.Array, spec: AttnSpec) -> jax.Array:
    """(Sq, Sk) boolean validity from causality/window."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if spec.causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if spec.window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - spec.window
    return ok


def _mask_bias(q_pos, k_pos, spec: AttnSpec, Sk: int, pad: int) -> jax.Array:
    """(Sq, C) additive f32 bias: 0 where attendable, NEG_INF elsewhere.
    A rank-2 additive bias broadcasts into the (B,Hkv,g,Sq,C) logits without
    XLA materialising a full boolean mask (observed 2.1 GiB pred tensors
    with the where-mask formulation)."""
    ok = _mask_ok(q_pos, k_pos, spec)
    if pad:
        ok &= (k_pos < Sk)[None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _chunk_kv(x: jax.Array, C: int, nchunks: int, pad: int) -> jax.Array:
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    B, _, Hkv, D = x.shape
    return x.reshape(B, nchunks, C, Hkv, D).transpose(1, 0, 2, 3, 4)


def _fwd_scan(qg, k, v, spec: AttnSpec, q_offset, kv_valid_len, Sk):
    """Forward online-softmax over KV chunks.  qg: (B,Sq,Hkv,g,D) pre-scaled.
    Returns (acc (B,Hkv,g,Sq,D) f32 unnormalised, m, l)."""
    B, Sq, Hkv, g, D = qg.shape
    C = min(spec.chunk, Sk)
    nchunks = -(-Sk // C)
    pad = nchunks * C - Sk
    kc, vc = _chunk_kv(k, C, nchunks, pad), _chunk_kv(v, C, nchunks, pad)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, xs):
        m, l, acc = carry
        kch, vch, cidx = xs
        k_pos = cidx * C + jnp.arange(C)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kch,
                       preferred_element_type=jnp.float32)
        if spec.logit_cap > 0:
            s = spec.logit_cap * jnp.tanh(s / spec.logit_cap)
        s = s + _mask_bias(q_pos, k_pos, spec, Sk, pad)[None, None, None]
        if kv_valid_len is not None:
            bad = (k_pos[None, :] >= kv_valid_len[:, None])
            s = s + jnp.where(bad, NEG_INF, 0.0)[:, None, None, None, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vch.dtype), vch,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(nchunks)),
        unroll=nchunks if spec.unroll else 1,
    )
    return acc, m, l


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash(q, k, v, spec: AttnSpec, q_offset):
    out, _ = _flash_fwd(q, k, v, spec, q_offset)
    return out


def _flash_fwd(q, k, v, spec: AttnSpec, q_offset):
    B, Sq, H, D = q.shape
    Hkv, Sk = k.shape[2], k.shape[1]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = ((q.astype(jnp.float32) * scale).astype(q.dtype)
          .reshape(B, Sq, Hkv, g, D))
    acc, m, l = _fwd_scan(qg, k, v, spec, q_offset, None, Sk)
    out = acc / jnp.maximum(l, 1e-20)[..., None]      # (B,Hkv,g,Sq,D) f32
    lse = m + jnp.log(jnp.maximum(l, 1e-20))          # (B,Hkv,g,Sq)
    o = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)
    # Residuals: only q, k, v, o, lse — the flash-attention backward
    # recomputes p per chunk instead of saving (B,Sq,Sk) anything.  The
    # residuals are STORED sequence-sharded on the model axis (they are the
    # dominant per-layer activation save under remat; ~16x smaller per chip,
    # at the cost of an all-gather when the backward re-reads them).
    from ..dist.context import constrain as _c

    res = tuple(_c(t, "batch", "seq_model", None, None) for t in (q, k, v, o))
    return o, (*res, lse)


def _flash_bwd(spec: AttnSpec, q_offset, res, do):
    q, k, v, o, lse = res
    B, Sq, H, D = q.shape
    Hkv, Sk = k.shape[2], k.shape[1]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    C = min(spec.chunk, Sk)
    nchunks = -(-Sk // C)
    pad = nchunks * C - Sk
    kc, vc = _chunk_kv(k, C, nchunks, pad), _chunk_kv(v, C, nchunks, pad)
    q_pos = q_offset + jnp.arange(Sq)

    qg = q.reshape(B, Sq, Hkv, g, D)
    dog = do.reshape(B, Sq, Hkv, g, D).transpose(0, 2, 3, 1, 4)   # (B,Hkv,g,Sq,D)
    og = o.reshape(B, Sq, Hkv, g, D).transpose(0, 2, 3, 1, 4)
    # delta = rowsum(dO * O)  (flash-attention-2 trick)
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), axis=-1)

    def step(dq_acc, xs):
        kch, vch, cidx = xs
        k_pos = cidx * C + jnp.arange(C)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kch,
                       preferred_element_type=jnp.float32) * scale
        if spec.logit_cap > 0:
            t = jnp.tanh(s / spec.logit_cap)
            s_capped = spec.logit_cap * t
            dcap = 1.0 - jnp.square(t)     # d(cap)/d(s)
        else:
            s_capped = s
            dcap = None
        s_capped = s_capped + _mask_bias(q_pos, k_pos, spec, Sk, pad)[None, None, None]
        p = jnp.exp(s_capped - lse[..., None])
        dp = jnp.einsum("bkgqd,bckd->bkgqc", dog, vch,
                        preferred_element_type=jnp.float32)
        dv = jnp.einsum("bkgqc,bkgqd->bckd", p.astype(do.dtype), dog,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        if dcap is not None:
            ds = ds * dcap
        ds = ds * scale
        dq = jnp.einsum("bkgqc,bckd->bqkgd", ds.astype(k.dtype), kch,
                        preferred_element_type=jnp.float32)
        dk = jnp.einsum("bkgqc,bqkgd->bckd", ds.astype(q.dtype), qg,
                        preferred_element_type=jnp.float32)
        return dq_acc + dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, Hkv, g, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        step, dq0, (kc, vc, jnp.arange(nchunks)),
        unroll=nchunks if spec.unroll else 1,
    )
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, nchunks * C, Hkv, D)[:, :Sk]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, nchunks * C, Hkv, D)[:, :Sk]
    return (dq.reshape(B, Sq, H, D).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(
    q: jax.Array,                 # (B, Sq, H, D)
    k: jax.Array,                 # (B, Sk, Hkv, D)
    v: jax.Array,                 # (B, Sk, Hkv, D)
    spec: AttnSpec,
    q_offset: int = 0,            # absolute position of q[0] (prefill continuation)
    kv_valid_len: Optional[jax.Array] = None,  # (B,) valid prefix of k/v
) -> jax.Array:
    """Flash attention (online softmax over KV chunks, recompute-in-backward
    custom VJP).  O(Sq * chunk) working set; never materialises Sq x Sk.
    Returns (B, Sq, H, D)."""
    assert q.shape[2] % k.shape[2] == 0, (q.shape, k.shape)
    if kv_valid_len is None:
        return _flash(q, k, v, spec, q_offset)
    # valid-length masking is only used on non-differentiated paths (serving)
    B, Sq, H, D = q.shape
    Hkv, Sk = k.shape[2], k.shape[1]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = ((q.astype(jnp.float32) * scale).astype(q.dtype)
          .reshape(B, Sq, Hkv, g, D))
    acc, m, l = _fwd_scan(qg, k, v, spec, q_offset, kv_valid_len, Sk)
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return (out.transpose(0, 3, 1, 2, 4)
            .reshape(B, Sq, H, D).astype(q.dtype))


def decode_attention(
    q: jax.Array,                # (B, 1, H, D) — one new token
    k_cache: jax.Array,          # (B, S, Hkv, D)
    v_cache: jax.Array,          # (B, S, Hkv, D)
    cache_len: jax.Array,        # (B,) or scalar: number of valid cache slots
    spec: AttnSpec,
) -> jax.Array:
    """Single-step attention over a KV cache (no repeat of KV across the
    GQA group; logits in f32)."""
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = ((q.astype(jnp.float32) * scale).astype(q.dtype)
          .reshape(B, Hkv, g, D))
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)       # (B,Hkv,g,S)
    if spec.logit_cap > 0:
        s = spec.logit_cap * jnp.tanh(s / spec.logit_cap)
    pos = jnp.arange(S)[None, :]                             # (1,S)
    clen = jnp.asarray(cache_len).reshape(-1, 1)             # (B,1)|(1,1)
    ok = pos < clen
    if spec.window > 0:
        ok &= pos >= clen - spec.window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)
