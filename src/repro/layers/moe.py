"""Mixture-of-Experts with token-choice top-k routing (GShard-style grouped
dense dispatch — the TPU-native formulation: dispatch/combine are einsums on
the MXU, expert parallelism falls out of sharding the expert/ffn dims).

Tokens are processed in GROUPS of <= ``group_size`` (a batch row is split
into sequence chunks): the dispatch tensor is (G, Tg, E, Cap) with
Cap = k * Tg * capacity_factor / E, so its footprint is linear in total
tokens (a flat dispatch over all tokens would be quadratic — infeasible at
the 1M-token train step of mixtral/train_4k).

Used by olmoe-1b-7b (64 experts, top-8) and mixtral-8x22b (8 experts, top-2).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .common import _activate


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act: str = "silu"
    renormalize: bool = True   # mixtral/olmoe renormalize top-k gates
    group_size: int = 2048     # tokens per dispatch group


def route_group(gate_logits: jax.Array, spec: MoESpec, cap: int):
    """Top-k routing within token groups.  gate_logits: (G, Tg, E).

    Returns (dispatch (G,Tg,E,cap), combine (G,Tg,E,cap), aux_loss scalar).
    """
    G, Tg, E = gate_logits.shape
    probs = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, spec.top_k)            # (G,Tg,k)
    if spec.renormalize:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )

    # Slot assignment: order token-choices (t, k) lexicographically within the
    # group, count prior assignments to the same expert.
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)           # (G,Tg,k,E)
    flat = onehot.reshape(G, Tg * spec.top_k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                             # 0-based slots
    slot = (pos.reshape(G, Tg, spec.top_k, E) * onehot).sum(-1).astype(jnp.int32)
    keep = slot < cap
    slot_oh = jax.nn.one_hot(slot, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot, slot_oh)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, slot_oh, gate_vals)

    # Switch-style load-balance aux loss, over all tokens.
    frac_tokens = jnp.mean(onehot.sum(2).reshape(-1, E), axis=0)
    frac_probs = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) / spec.top_k
    return dispatch, combine, aux


def moe_ffn(
    x: jax.Array,          # (B, S, D)
    gate_w: jax.Array,     # (D, E) router
    w_gate: jax.Array,     # (E, D, F) expert gate proj
    w_up: jax.Array,       # (E, D, F)
    w_down: jax.Array,     # (E, F, D)
    spec: MoESpec,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,D), aux_loss)."""
    from ..dist.context import constrain

    B, S, D = x.shape
    Tg = min(spec.group_size, S)
    assert S % Tg == 0, (S, Tg)
    G = B * (S // Tg)
    xt = x.reshape(G, Tg, D)
    cap = int(max(spec.top_k * Tg * spec.capacity_factor / spec.num_experts, 4))
    # hardware-align the expert buffer for the MXU
    cap = -(-cap // 8) * 8
    # The dispatch/combine tensors are the MoE memory hot spot (G,Tg,E,cap);
    # pin the group dim to the DP axes (propagation loses it through
    # cumsum/top_k and replicates multi-GiB buffers) and carry them in the
    # compute dtype.
    xt = constrain(xt, "batch", None, None)
    logits = jnp.einsum("gtd,de->gte", xt, gate_w)
    dispatch, combine, aux = route_group(logits, spec, cap)
    dd = constrain(dispatch.astype(x.dtype), "batch", None, None, None)
    cc = constrain(combine.astype(x.dtype), "batch", None, None, None)
    xe = jnp.einsum("gtd,gtec->gecd", xt, dd)                 # (G,E,cap,D)
    h = _activate(jnp.einsum("gecd,edf->gecf", xe, w_gate), spec.act)
    h = h * jnp.einsum("gecd,edf->gecf", xe, w_up)
    ye = jnp.einsum("gecf,efd->gecd", h, w_down)              # (G,E,cap,D)
    y = jnp.einsum("gecd,gtec->gtd", ye, cc)
    return y.reshape(B, S, D).astype(x.dtype), aux
