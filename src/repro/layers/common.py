"""Shared layer primitives: norms, RoPE variants, gated MLP."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    # (1 + scale) convention: zero-initialised scale params == identity norm.
    out = out * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)
    return out.astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    """Inverse frequencies for the rotating half of the head dim."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0, rotary_frac: float = 1.0) -> jax.Array:
    """Rotary embedding.

    x: (..., S, H, D); positions: broadcastable to (..., S).
    ``rotary_frac`` < 1 rotates only the leading fraction of the head dim —
    ChatGLM's "2d RoPE" rotates half the head dim and leaves the rest as-is
    (the second 'dimension' carried positionally), which is what we implement
    for ``rotary_frac=0.5``.
    """
    d = x.shape[-1]
    rot_d = int(d * rotary_frac)
    if rot_d == 0:
        return x
    rot_d -= rot_d % 2
    x_rot, x_pass = x[..., :rot_d], x[..., rot_d:]
    inv = rope_frequencies(rot_d, theta)  # (rot_d/2,)
    ang = positions.astype(jnp.float32)[..., None, None] * inv  # (...,S,1,rot_d/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x_rot[..., 0::2].astype(jnp.float32), x_rot[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x_rot.shape).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if x_pass.shape[-1] else out


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, act: str = "silu") -> jax.Array:
    """SwiGLU/GeGLU feed-forward: down( act(x@gate) * (x@up) )."""
    h_g = jnp.einsum("...d,df->...f", x, w_gate)
    h_u = jnp.einsum("...d,df->...f", x, w_up)
    h = _activate(h_g, act) * h_u
    return jnp.einsum("...f,fd->...d", h, w_down)


def mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
        b_up: Optional[jax.Array] = None, b_down: Optional[jax.Array] = None,
        act: str = "gelu") -> jax.Array:
    """Plain two-matrix feed-forward (whisper, starcoder-style)."""
    h = jnp.einsum("...d,df->...f", x, w_up)
    if b_up is not None:
        h = h + b_up
    h = _activate(h, act)
    out = jnp.einsum("...f,fd->...d", h, w_down)
    if b_down is not None:
        out = out + b_down
    return out


def sinusoidal_at(positions: jax.Array, d_model: int,
                  dtype=jnp.float32) -> jax.Array:
    """Sinusoidal absolute-position embeddings at arbitrary positions.
    positions: (S,) -> (S, d_model)."""
    import math as _math

    pos = positions.astype(jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-_math.log(10_000.0) * dim / max(d_model // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _activate(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "gelu_tanh":
        return jax.nn.gelu(x, approximate=True)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {act!r}")
