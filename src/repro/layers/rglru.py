"""RG-LRU: the Real-Gated Linear Recurrent Unit (Griffin / RecurrentGemma,
arXiv:2402.19427).

    r_t = sigmoid(W_r x_t + b_r)                    (recurrence gate)
    i_t = sigmoid(W_i x_t + b_i)                    (input gate)
    a_t = exp(-c * softplus(a_param) * r_t)         (per-channel decay, c=8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses an associative scan over the sequence (the recurrence is
a first-order linear scan, so log-depth parallel); the Pallas kernel
(``repro.kernels.rglru``) implements the same chunked recurrence for TPU.
Decode is the single-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_C = 8.0


def _log_a(a_param: jax.Array, r: jax.Array) -> jax.Array:
    """log a_t = -c * softplus(a_param) * r_t  (always < 0, stable)."""
    return -_C * jax.nn.softplus(a_param.astype(jnp.float32)) * r


def rglru_scan(
    x: jax.Array,        # (B, S, N) gated input
    r: jax.Array,        # (B, S, N) recurrence gate, in (0,1)
    i: jax.Array,        # (B, S, N) input gate, in (0,1)
    a_param: jax.Array,  # (N,)
    h0: jax.Array | None = None,  # (B, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,N), h_last (B,N)).  f32 state, cast back to x.dtype."""
    B, S, N = x.shape
    rf = r.astype(jnp.float32)
    log_a = _log_a(a_param, rf)                       # (B,S,N)
    a = jnp.exp(log_a)
    gated = (i.astype(jnp.float32) * x.astype(jnp.float32))
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    u = beta * gated                                   # (B,S,N)
    if h0 is not None:
        # fold h0 in as a virtual step: h_t = a_t h_{t-1} + u_t
        u = u.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, a2 * u1 + u2

    a_cum, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(
    x: jax.Array,        # (B, N)
    r: jax.Array,        # (B, N)
    i: jax.Array,        # (B, N)
    a_param: jax.Array,  # (N,)
    h: jax.Array,        # (B, N) carried state (f32)
) -> tuple[jax.Array, jax.Array]:
    """One decode step; returns (y (B,N), h_new (B,N) f32)."""
    rf = r.astype(jnp.float32)
    log_a = _log_a(a_param, rf)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h_new = a * h + beta * (i.astype(jnp.float32) * x.astype(jnp.float32))
    return h_new.astype(x.dtype), h_new


def short_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise temporal conv (width T), causal.  x: (B,S,N), w: (T,N).

    Returns (y, new_state) where state carries the last T-1 inputs for
    decode continuation; pass state=(B,T-1,N) and S=1 for decode.
    """
    B, S, N = x.shape
    T = w.shape[0]
    if state is None:
        state = jnp.zeros((B, T - 1, N), x.dtype)
    xx = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+T-1, N)
    y = sum(
        xx[:, t : t + S, :] * w[t][None, None, :] for t in range(T)
    )
    return y.astype(x.dtype), xx[:, -(T - 1):, :]
