"""Mamba-2 SSD — State-Space Duality (arXiv:2405.21060), chunked form.

The SSD recurrence per head (state N = d_state, head dim P):

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t  x_t^T      (N x P state)
    y_t = C_t h_t + D * x_t

The chunked ("block-decomposition") algorithm computes, per chunk of length
Q: the intra-chunk quadratic term (an attention-like masked matmul — MXU
friendly) and the inter-chunk term through the running state.  This is the
TPU-native mapping of the paper's insight: all heavy ops are matmuls.
The Pallas kernel (``repro.kernels.ssd``) implements the same blocking; this
jnp version is the oracle and the dry-run path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunked(
    x: jax.Array,       # (B, S, H, P)  input (already gated/conv'd)
    dt: jax.Array,      # (B, S, H)     positive step sizes
    A: jax.Array,       # (H,)          negative decay rates (A = -softplus(a))
    Bm: jax.Array,      # (B, S, H, N)  input projection ("B" matrix)
    Cm: jax.Array,      # (B, S, H, N)  output projection ("C" matrix)
    D: jax.Array,       # (H,)          skip gain
    chunk: int = 128,
    h0: jax.Array | None = None,  # (B, H, N, P)
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), h_last (B,H,N,P) f32)."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    # per-step log decay: la_t = dt_t * A_h  (<= 0)
    la = dtf * Af[None, None, :]                                  # (B,S',H)
    xw = x.astype(jnp.float32) * dtf[..., None]                   # dt-weighted input

    xc = xw.reshape(B_, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    lac = la.reshape(B_, nc, Q, H).transpose(1, 0, 2, 3)
    Bc = Bm.astype(jnp.float32).reshape(B_, nc, Q, H, N).transpose(1, 0, 2, 3, 4)
    Cc = Cm.astype(jnp.float32).reshape(B_, nc, Q, H, N).transpose(1, 0, 2, 3, 4)

    h_init = (
        jnp.zeros((B_, H, N, P), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )

    def step(h, xs):
        xq, laq, Bq, Cq = xs           # (B,Q,H,P), (B,Q,H), (B,Q,H,N) x2
        cum = jnp.cumsum(laq, axis=1)  # (B,Q,H) running log-decay in chunk
        total = cum[:, -1]             # (B,H)
        # ---- intra-chunk (quadratic, matmul): y_intra[t] = sum_{s<=t} ...
        # decay(t,s) = exp(cum_t - cum_s) for s <= t
        dmat = cum[:, :, None, :] - cum[:, None, :, :]            # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        dmat = jnp.where(tri, jnp.exp(dmat), 0.0)
        g = jnp.einsum("bqhn,bshn->bqsh", Cq, Bq) * dmat          # (B,Q,Q,H)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", g, xq)
        # ---- inter-chunk: contribution of the incoming state
        decay_in = jnp.exp(cum)                                    # (B,Q,H)
        y_inter = jnp.einsum("bqhn,bhnp->bqhp", Cq, h) * decay_in[..., None]
        # ---- state update: h' = exp(total) h + sum_s exp(total-cum_s) B_s x_s^T
        w = jnp.exp(total[:, None, :] - cum)                       # (B,Q,H)
        dB = Bq * w[..., None]
        h_new = jnp.exp(total)[..., None, None] * h + jnp.einsum(
            "bqhn,bqhp->bhnp", dB, xq
        )
        return h_new, y_intra + y_inter

    h_last, yc = jax.lax.scan(step, h_init, (xc, lac, Bc, Cc),
                              unroll=nc if unroll else 1)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B_, nc * Q, H, P)[:, :S]
    y = y + x.astype(jnp.float32)[:, :S] * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), h_last


def ssd_step(
    x: jax.Array,      # (B, H, P)
    dt: jax.Array,     # (B, H)
    A: jax.Array,      # (H,)
    Bm: jax.Array,     # (B, H, N)
    Cm: jax.Array,     # (B, H, N)
    D: jax.Array,      # (H,)
    h: jax.Array,      # (B, H, N, P) f32
) -> tuple[jax.Array, jax.Array]:
    """One decode step of the SSD recurrence."""
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf * A.astype(jnp.float32)[None, :])            # (B,H)
    xw = x.astype(jnp.float32) * dtf[..., None]                  # (B,H,P)
    h_new = a[..., None, None] * h + jnp.einsum(
        "bhn,bhp->bhnp", Bm.astype(jnp.float32), xw
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cm.astype(jnp.float32), h_new)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), h_new
