"""Deadline-aware batch serving engine: the paper's scheduler driving real
model execution (executor 3 of DESIGN.md §4).

A ``WindowJob`` is the serving analogue of the paper's intermittent query:
requests (prompts to score/prefill) arrive over a window and the aggregate
result (all logits / all scores) is due at a deadline.  Instead of running
every request eagerly (per-request dispatch overhead, the "streaming" mode),
the engine plans batch points with Algorithm 1 — or time-shares several jobs
with Algorithm 2 / LLF — and executes real JAX prefill batches.

C_max doubles as the straggler bound: a batch exceeding it is flagged and
re-queued (its requests are idempotent), bounding the blocking period
exactly as §4.2-4.3 requires.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    ArrivalModel,
    CostModelBase,
    DynamicQuerySpec,
    LinearCostModel,
    Query,
    Strategy,
    fit_piecewise_linear,
    schedule_dynamic,
    schedule_single,
)
from ..models import lm
from ..models.config import ModelConfig


@dataclasses.dataclass
class WindowJob:
    """A deadline-bound batch-inference job."""

    job_id: str
    prompts: np.ndarray            # (N, S) int32, arrival order
    arrival: ArrivalModel          # predicted arrival of the N prompts
    deadline: float
    results: List[np.ndarray] = dataclasses.field(default_factory=list)
    processed: int = 0

    @property
    def num_requests(self) -> int:
        return self.prompts.shape[0]


class PrefillExecutor:
    """Real prefill batches on a (reduced) model; pads to a small set of
    bucket sizes so recompilation cost is bounded and measurable."""

    def __init__(self, cfg: ModelConfig, params, buckets=(1, 2, 4, 8, 16, 32)):
        self.cfg = cfg
        self.params = params
        self.buckets = tuple(sorted(buckets))
        self._fn = jax.jit(
            lambda p, toks: lm.prefill(cfg, p, toks, toks.shape[1])[0]
        )

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def run_batch(self, prompts: np.ndarray) -> Tuple[np.ndarray, float]:
        """Returns (last-token logits (n, V), wall seconds)."""
        n = prompts.shape[0]
        b = self._bucket(n)
        padded = np.zeros((b, prompts.shape[1]), np.int32)
        padded[:n] = prompts
        t0 = time.perf_counter()
        out = np.asarray(self._fn(self.params, jnp.asarray(padded)))
        return out[:n], time.perf_counter() - t0

    def calibrate(self, seq_len: int, vocab: int) -> CostModelBase:
        """§6.2 for serving: measure per-batch cost vs batch size, fit the
        cost model the scheduler plans with."""
        rng = np.random.default_rng(0)
        samples = []
        for b in self.buckets:
            toks = rng.integers(0, vocab, (b, seq_len)).astype(np.int32)
            self.run_batch(toks)          # warmup/compile this bucket
            _, dt = self.run_batch(toks)
            samples.append((b, dt))
        return fit_piecewise_linear(samples)


def serve_single_job(job: WindowJob, executor: PrefillExecutor,
                     cost_model: CostModelBase,
                     now_fn: Optional[Callable[[], float]] = None
                     ) -> Dict[str, float]:
    """Algorithm 1 end-to-end on one job with REAL batch execution.

    Time is simulated from the arrival model (the container has no live
    traffic), but every scheduled batch runs real prefill compute; the
    executed cost is the measured wall time.
    """
    q = Query(
        query_id=job.job_id,
        wind_start=job.arrival.wind_start,
        wind_end=job.arrival.wind_end,
        deadline=job.deadline,
        num_tuples_total=job.num_requests,
        cost_model=cost_model,
        arrival=job.arrival,
    )
    plan = schedule_single(q)
    sim_now = job.arrival.wind_start
    total_exec = 0.0
    for b in plan.batches:
        sim_now = max(sim_now, b.sched_time)
        chunk = job.prompts[job.processed: job.processed + b.num_tuples]
        logits, dt = executor.run_batch(chunk)
        job.results.append(logits)
        job.processed += len(chunk)
        total_exec += dt
        sim_now += cost_model.cost(len(chunk))
    return {
        "num_batches": plan.num_batches,
        "modelled_finish": sim_now,
        "deadline": job.deadline,
        "met_modelled": sim_now <= job.deadline + 1e-9,
        "wall_exec_seconds": total_exec,
        "processed": job.processed,
    }


def serve_multi_jobs(jobs: Sequence[WindowJob], executor: PrefillExecutor,
                     cost_model: CostModelBase,
                     strategy: Strategy = Strategy.LLF,
                     delta_rsf: float = 0.5, c_max: float = 30.0
                     ) -> Dict[str, Dict]:
    """Algorithm 2 (LLF default) across concurrent jobs, executing each
    scheduled MinBatch for real via the ``on_batch`` hook."""
    by_id = {j.job_id: j for j in jobs}
    wall = {j.job_id: 0.0 for j in jobs}
    stragglers: List[str] = []

    def on_batch(ex):
        job = by_id[ex.query_id]
        if ex.kind != "batch" or ex.num_tuples == 0:
            return
        chunk = job.prompts[job.processed: job.processed + ex.num_tuples]
        logits, dt = executor.run_batch(chunk)
        job.results.append(logits)
        job.processed += len(chunk)
        wall[job.job_id] += dt
        if dt > c_max:
            stragglers.append(job.job_id)  # re-dispatch on a real pod

    specs = [
        DynamicQuerySpec(
            query=Query(
                query_id=j.job_id,
                wind_start=j.arrival.wind_start,
                wind_end=j.arrival.wind_end,
                deadline=j.deadline,
                num_tuples_total=j.num_requests,
                cost_model=cost_model,
                arrival=j.arrival,
            )
        )
        for j in jobs
    ]
    trace = schedule_dynamic(specs, strategy, delta_rsf=delta_rsf,
                             c_max=c_max, on_batch=on_batch)
    return {
        o.query_id: {
            "met_modelled": o.met_deadline,
            "completion": o.completion_time,
            "deadline": o.deadline,
            "num_batches": o.num_batches,
            "wall_exec_seconds": wall[o.query_id],
            "processed": by_id[o.query_id].processed,
            "straggler_events": stragglers.count(o.query_id),
        }
        for o in trace.outcomes
    }
