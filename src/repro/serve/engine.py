"""Deadline-aware batch serving engine: the paper's scheduler driving real
model execution (executor 3 of DESIGN.md §4).

A ``WindowJob`` is the serving analogue of the paper's intermittent query:
requests (prompts to score/prefill) arrive over a window and the aggregate
result (all logits / all scores) is due at a deadline.  Instead of running
every request eagerly (per-request dispatch overhead, the "streaming" mode),
the engine plans batch points with the ``single`` policy — or time-shares
several jobs under a ``*-dynamic`` policy — and executes real JAX prefill
batches.

``ServingExecutor`` implements the ``repro.core.api.Executor`` protocol
(``submit_batch``/``finalize``/``clock``) over a ``PrefillExecutor``, so the
engine runs on the SAME runtime loop as the discrete-event simulator and the
analytics executor.  The loop owns C_max straggler handling: a batch whose
REAL execution exceeds C_max is flagged in ``trace.stragglers`` and
re-queued once (its requests are idempotent; results are keyed by request
offset so the retry overwrites), bounding the blocking period exactly as
§4.2-4.3 requires.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    ArrivalModel,
    CostModelBase,
    DynamicQuerySpec,
    Planner,
    Query,
    Session,
    Strategy,
    fit_piecewise_linear,
)
from ..core.policies.dynamic import policy_for_strategy
from ..core.runtime import BaseExecutor, ExecutorPool, execute_plan, run
from ..models import lm
from ..models.config import ModelConfig


@dataclasses.dataclass
class WindowJob:
    """A deadline-bound batch-inference job."""

    job_id: str
    prompts: np.ndarray            # (N, S) int32, arrival order
    arrival: ArrivalModel          # predicted arrival of the N prompts
    deadline: float
    results: List[np.ndarray] = dataclasses.field(default_factory=list)
    processed: int = 0

    @property
    def num_requests(self) -> int:
        return self.prompts.shape[0]

    def as_query(self, cost_model: CostModelBase) -> Query:
        """The scheduler's view of this job (request units)."""
        return Query(
            query_id=self.job_id,
            wind_start=self.arrival.wind_start,
            wind_end=self.arrival.wind_end,
            deadline=self.deadline,
            num_tuples_total=self.num_requests,
            cost_model=cost_model,
            arrival=self.arrival,
        )


class PrefillExecutor:
    """Real prefill batches on a (reduced) model; pads to a small set of
    bucket sizes so recompilation cost is bounded and measurable."""

    def __init__(self, cfg: ModelConfig, params, buckets=(1, 2, 4, 8, 16, 32)):
        self.cfg = cfg
        self.params = params
        self.buckets = tuple(sorted(buckets))
        self._fn = jax.jit(
            lambda p, toks: lm.prefill(cfg, p, toks, toks.shape[1])[0]
        )

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def run_batch(self, prompts: np.ndarray) -> Tuple[np.ndarray, float]:
        """Returns (last-token logits (n, V), wall seconds).

        Requests beyond the largest bucket are split into bucket-sized
        sub-batches (wall times summed, logits concatenated in order) —
        ``_bucket`` clamps to the largest bucket, so a single padded buffer
        cannot hold them.
        """
        n = prompts.shape[0]
        cap = self.buckets[-1]
        if n > cap:
            outs: List[np.ndarray] = []
            total = 0.0
            for lo in range(0, n, cap):
                out, dt = self.run_batch(prompts[lo:lo + cap])
                outs.append(out)
                total += dt
            return np.concatenate(outs, axis=0), total
        b = self._bucket(n)
        padded = np.zeros((b, prompts.shape[1]), np.int32)
        padded[:n] = prompts
        t0 = time.perf_counter()
        out = np.asarray(self._fn(self.params, jnp.asarray(padded)))
        return out[:n], time.perf_counter() - t0

    def calibrate(self, seq_len: int, vocab: int) -> CostModelBase:
        """§6.2 for serving: measure per-batch cost vs batch size, fit the
        cost model the scheduler plans with."""
        rng = np.random.default_rng(0)
        samples = []
        for b in self.buckets:
            toks = rng.integers(0, vocab, (b, seq_len)).astype(np.int32)
            self.run_batch(toks)          # warmup/compile this bucket
            _, dt = self.run_batch(toks)
            samples.append((b, dt))
        return fit_piecewise_linear(samples)


class ServingExecutor(BaseExecutor):
    """``repro.core.api.Executor`` over real prefill batches.

    Time is modelled from the cost model (the container has no live
    traffic), but every submitted batch runs real prefill compute; measured
    wall time accumulates in ``wall_seconds`` and feeds the runtime loop's
    C_max straggler detection.  Logits are keyed by request offset so a
    re-queued straggler batch overwrites its own results (idempotent).
    """

    def __init__(self, prefill: PrefillExecutor, jobs: Sequence[WindowJob]):
        super().__init__()
        self.prefill = prefill
        self._jobs: Dict[str, WindowJob] = {j.job_id: j for j in jobs}
        self._logits: Dict[str, Dict[int, np.ndarray]] = {
            j.job_id: {} for j in jobs
        }

    def _execute(self, query: Query, num_tuples: int, offset: int) -> Optional[float]:
        job = self._jobs[query.query_id]
        chunk = job.prompts[offset: offset + num_tuples]
        if len(chunk) == 0:
            return None
        logits, dt = self.prefill.run_batch(chunk)
        self._logits[job.job_id][offset] = logits
        job.processed = sum(
            len(v) for v in self._logits[job.job_id].values()
        )
        return dt

    def _finalize(self, query: Query, num_batches: int) -> Optional[float]:
        job = self._jobs[query.query_id]
        job.results = [
            self._logits[job.job_id][off]
            for off in sorted(self._logits[job.job_id])
        ]
        return None


def serve_single_job(job: WindowJob, executor: PrefillExecutor,
                     cost_model: CostModelBase,
                     policy: str = "single",
                     c_max: Optional[float] = None) -> Dict[str, float]:
    """One job end-to-end: plan with a static policy, execute the plan with
    REAL batch compute through the shared runtime loop (strict mode: the
    vetted plan is replayed verbatim against fully materialized prompts).

    ``c_max`` (wall seconds) enables the loop's straggler flag/re-queue on
    this static path; static policies carry no C_max of their own."""
    q = job.as_query(cost_model)
    plan = Planner(policy=policy).schedule(q)
    serving = ServingExecutor(executor, [job])
    trace = execute_plan(q, plan, serving, strict=True, c_max=c_max)
    out = trace.outcome(job.job_id)
    return {
        "num_batches": out.num_batches,
        "modelled_finish": out.completion_time,
        "deadline": job.deadline,
        "met_modelled": out.met_deadline,
        "wall_exec_seconds": serving.wall_seconds.get(job.job_id, 0.0),
        "processed": job.processed,
        "straggler_events": trace.stragglers.count(job.job_id),
    }


def serve_session(jobs: Sequence[WindowJob], executor: PrefillExecutor,
                  cost_model: CostModelBase,
                  *,
                  policy: str = "llf-dynamic",
                  submit_times: Optional[Sequence[float]] = None,
                  calibrate: bool = False,
                  workers: Optional[int] = None,
                  c_max: Optional[float] = None,
                  run_to: Optional[float] = None,
                  **session_kw) -> Tuple[Dict[str, Dict], "Session"]:
    """Session mode over the REAL prefill backend: jobs join a CONTINUOUSLY
    running engine one by one (online admission, schedulability-gated)
    instead of being drained as one fixed workload.

    ``submit_times[i]`` delays job i's submission to that modelled instant
    (default: its window start).  Jobs whose admission pre-flight proves
    them infeasible against the live set are rejected — their report row
    carries ``admitted=False`` and they never run.  With ``calibrate=True``
    per-job cost models refit from measured prefill wall seconds.  Returns
    (per-job report, the live Session) so callers can keep submitting.
    """
    serving = ServingExecutor(executor, jobs)
    session = Session(policy=policy, executor=serving, workers=workers,
                      calibrate=calibrate, c_max=c_max, **session_kw)
    admitted: Dict[str, bool] = {}
    order = sorted(
        range(len(jobs)),
        key=lambda i: (submit_times[i] if submit_times is not None
                       else jobs[i].arrival.wind_start),
    )
    for i in order:
        job = jobs[i]
        at = (submit_times[i] if submit_times is not None
              else job.arrival.wind_start)
        session.run_until(max(at, session.now))
        q = job.as_query(cost_model)
        if at > q.submit_time:
            q = dataclasses.replace(q, submit_time=at)
        admitted[job.job_id] = bool(session.submit(q))
    trace = session.run() if run_to is None else session.run_until(run_to)
    by_id = {j.job_id: j for j in jobs}
    report: Dict[str, Dict] = {}
    for job_id, ok in admitted.items():
        if not ok:
            report[job_id] = {"admitted": False}
            continue
        row: Dict = {"admitted": True}
        try:
            o = trace.outcome(job_id)
        except KeyError:
            row["completed"] = False  # still running at ``run_to``
        else:
            row.update({
                "completed": True,
                "met_modelled": o.met_deadline,
                "completion": o.completion_time,
                "deadline": o.deadline,
                "num_batches": o.num_batches,
                "shortfall": o.shortfall,
                "wall_exec_seconds": serving.wall_seconds.get(job_id, 0.0),
                "processed": by_id[job_id].processed,
                "straggler_events": trace.stragglers.count(job_id),
            })
        report[job_id] = row
    return report, session


def serve_multi_jobs(jobs: Sequence[WindowJob], executor: PrefillExecutor,
                     cost_model: CostModelBase,
                     strategy: Strategy = Strategy.LLF,
                     delta_rsf: float = 0.5, c_max: float = 30.0,
                     workers: int = 1) -> Dict[str, Dict]:
    """Algorithm 2 (LLF default) across concurrent jobs: the ``*-dynamic``
    policy decides, the shared runtime loop drives, ``ServingExecutor``
    performs each scheduled MinBatch for real.

    ``workers=W`` time-shares the jobs across a W-way ``ExecutorPool``
    (modelled clocks; prefill compute still runs through the one
    ``PrefillExecutor``, whose buckets bound per-worker batch shapes)."""
    serving = ServingExecutor(executor, jobs)
    specs = [DynamicQuerySpec(query=j.as_query(cost_model)) for j in jobs]
    policy = policy_for_strategy(strategy, delta_rsf=delta_rsf, c_max=c_max)
    pool = ExecutorPool(backend=serving, workers=workers) if workers > 1 \
        else serving
    trace = run(policy, specs, pool)
    by_id = {j.job_id: j for j in jobs}
    return {
        o.query_id: {
            "met_modelled": o.met_deadline,
            "completion": o.completion_time,
            "deadline": o.deadline,
            "num_batches": o.num_batches,
            "wall_exec_seconds": serving.wall_seconds.get(o.query_id, 0.0),
            "processed": by_id[o.query_id].processed,
            "straggler_events": trace.stragglers.count(o.query_id),
        }
        for o in trace.outcomes
    }
