"""Real JAX analytics executor: the paper's intermittent GROUP-BY queries
running on-device (segagg kernel / jnp fallback), scheduled by repro.core.

Executor model (DESIGN.md §4, executor 2):

* a batch = concatenated record files; one ``process_batch`` call computes
  the (num_groups, V) partial aggregate on device and SPILLS it to host —
  device memory is released between batches exactly as the paper stores
  intermediate results in files between Spark jobs;
* ``finalize`` = the paper's final aggregation step: combine partials.

``AnalyticsRuntimeExecutor`` adapts this to the ``repro.core.api.Executor``
protocol (``submit_batch``/``finalize``/``clock``), so the SAME runtime loop
that drives the discrete-event simulator and the serving engine drives real
segagg batches: ``run_plan`` is now a thin wrapper over
``repro.core.runtime.execute_plan``.  Partials are keyed by tuple offset, so
a C_max straggler re-queue (the loop re-dispatching an idempotent batch)
overwrites rather than double-counts.

``measure_cost_model`` reproduces §6.2: run batches of different sizes,
time them, fit the piecewise-linear cost model the scheduler consumes.

Load shedding (``repro.core.overload``) reaches the real backend through the
query's ``ThinnedArrival``: batch offsets arrive in KEPT-tuple units, the
executor maps them to the underlying file indices (a systematic uniform
sample of the stream) and weights each sampled record by the inverse keep
rate, so the segagg partials — and therefore the final aggregates — are
unbiased scaled estimates whose error bound the scheduler reported in
``QueryOutcome.error_bound``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    CostModelBase,
    LinearCostModel,
    Planner,
    Query,
    RecurringQuerySpec,
    Schedule,
    Session,
    SessionTrace,
    ShiftedArrival,
    ThinnedArrival,
    TraceArrival,
    fit_piecewise_linear,
)
from ..core.runtime import BaseExecutor, execute_plan
from ..data.tpch import AnalyticsQuery, StreamScale
from ..dist.mesh import MeshBackend


@dataclasses.dataclass
class BatchResult:
    num_records: int
    seconds: float


@functools.partial(jax.jit, static_argnames="num_groups")
def _segagg_ref_jit(keys, values, num_groups: int):
    """Module-level jit so the compile cache is shared across ALL
    ``AnalyticsExecutor`` instances: one compile per (num_groups, batch
    shape), not one per executor.  (A per-instance ``jax.jit(lambda ...)``
    defeats the cache — every fresh lambda is a new callable, and
    ``measure_cost_model`` alone builds ~8 executors.)"""
    from ..kernels.segagg.ref import segagg_ref

    return segagg_ref(keys, values, num_groups)


class AnalyticsExecutor:
    """Executes one AnalyticsQuery in intermittent batches.

    ``backend=`` selects the segagg execution path (``"auto"`` → compiled
    kernel for the platform; ``"interpret"`` → the Pallas interpreter, the
    pre-dispatch behaviour) — see ``repro.kernels.segagg.ops``.  Only
    consulted with ``use_kernel=True``; the default path is the jnp
    reference.

    ``mesh=`` (a ``repro.dist.DeviceMesh``) routes every scan through the
    SHARDED kernel path: rows split over the mesh's data axis, one segagg
    per device, partials merged across devices.  Numerically equal to the
    single-device path (integer-valued f32 sums are exact under any
    association); ``mesh=None`` is byte-for-byte the pre-mesh behaviour."""

    def __init__(self, query: AnalyticsQuery, scale: StreamScale,
                 use_kernel: bool = False, backend: Optional[str] = None,
                 mesh=None):
        self.query = query
        self.scale = scale
        self.num_groups = query.num_groups(scale)
        self.use_kernel = use_kernel
        self.backend = backend
        self.mesh = mesh
        # Partials keyed by slot (tuple offset when driven by the runtime
        # loop): re-queued stragglers overwrite instead of double-counting.
        self.partials: Dict[int, np.ndarray] = {}
        self.batch_log: List[BatchResult] = []
        if mesh is not None:
            self._agg = lambda k, v: mesh.segagg(k, v, self.num_groups,
                                                 backend=backend)
        elif use_kernel:
            from ..kernels.segagg.ops import segagg

            self._agg = lambda k, v: segagg(k, v, self.num_groups,
                                            backend=backend)
        else:
            self._agg = lambda k, v: _segagg_ref_jit(k, v, self.num_groups)

    def process_batch(self, records: Dict[str, np.ndarray],
                      slot: Optional[int] = None,
                      weights: Optional[np.ndarray] = None) -> BatchResult:
        """Compute one partial aggregate.  ``weights`` (per-record value
        multipliers) realize sampled scans under load shedding: each kept
        record is weighted by the inverse keep rate, making the partial a
        Horvitz-Thompson estimate of the unsampled aggregate."""
        keys = np.asarray(self.query.key_fn(records), np.int32)
        vals = np.asarray(self.query.value_fn(records), np.float32)
        if weights is not None:
            vals = vals * np.asarray(weights, np.float32).reshape(-1, 1)
        t0 = time.perf_counter()
        part = self._agg(jnp.asarray(keys), jnp.asarray(vals))
        part = np.asarray(part)  # spill to host; device buffers released
        dt = time.perf_counter() - t0
        if slot is None:  # sequential mode: next free key, never clobber
            slot = len(self.partials)
            while slot in self.partials:
                slot += 1
        self.partials[slot] = part
        res = BatchResult(num_records=len(keys), seconds=dt)
        self.batch_log.append(res)
        return res

    def finalize(self) -> Tuple[np.ndarray, float]:
        """Final aggregation step (paper §2.1): combine the partials."""
        t0 = time.perf_counter()
        total = (
            np.sum(np.stack(list(self.partials.values())), axis=0)
            if self.partials
            else np.zeros((self.num_groups, 1), np.float32)
        )
        return total, time.perf_counter() - t0

    @property
    def num_batches(self) -> int:
        return len(self.partials)


def concat_files(files: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    keys = files[0].keys()
    return {k: np.concatenate([f[k] for f in files]) for k in keys}


def _is_thinned(arrival) -> bool:
    """Does the arrival chain contain a ``ThinnedArrival`` (load shedding)?"""
    while True:
        if isinstance(arrival, ThinnedArrival):
            return True
        if isinstance(arrival, ShiftedArrival):
            arrival = arrival.base
            continue
        return False


def _thinned_file_index(arrival, k: int):
    """Map kept-tuple index ``k`` (1-based) through the arrival chain to the
    underlying stream index, accumulating the inverse-keep-rate weight.
    Nested thins (a query shed more than once) compose multiplicatively."""
    w = 1.0
    while True:
        if isinstance(arrival, ShiftedArrival):
            arrival = arrival.base
            continue
        if isinstance(arrival, ThinnedArrival):
            if k > arrival.prefix and arrival.keep > 0:
                w *= arrival.tail / arrival.keep
            k = arrival.base_index(k)
            arrival = arrival.base
            continue
        return k, w


class AnalyticsRuntimeExecutor(BaseExecutor):
    """``repro.core.api.Executor`` over real segagg analytics jobs.

    ``jobs`` maps a scheduler query_id to its (AnalyticsQuery, files); batch
    tuple units are FILES (exactly the paper's setup).  The modelled clock
    advances by cost-model time; measured wall seconds are recorded per
    query (``wall_seconds``) and final results land in ``results``.
    """

    def __init__(
        self,
        jobs: Dict[str, Tuple[AnalyticsQuery, Sequence[Dict[str, np.ndarray]]]],
        scale: StreamScale,
        use_kernel: bool = False,
        backend: Optional[str] = None,
        mesh=None,
    ):
        super().__init__()
        self._jobs = {
            qid: (AnalyticsExecutor(aq, scale, use_kernel, backend, mesh),
                  files)
            for qid, (aq, files) in jobs.items()
        }
        self.results: Dict[str, np.ndarray] = {}
        self.agg_seconds: Dict[str, float] = {}

    def physical(self, query_id: str) -> AnalyticsExecutor:
        return self._jobs[query_id][0]

    def _execute(self, query: Query, num_tuples: int, offset: int) -> Optional[float]:
        ex, files = self._jobs[query.query_id]
        if _is_thinned(query.arrival):
            # Sampled scan (load shedding): offsets are in KEPT-tuple
            # units; fetch the systematically sampled files and weight
            # their records by the inverse keep rate so the partial is an
            # unbiased scaled estimate of the unsampled aggregate.
            chunk, weights = [], []
            for k in range(offset + 1, offset + num_tuples + 1):
                idx, w = _thinned_file_index(query.arrival, k)
                if 0 < idx <= len(files):
                    f = files[idx - 1]
                    chunk.append(f)
                    weights.append(
                        np.full(len(next(iter(f.values()))), w, np.float32))
            if not chunk:
                return None
            return ex.process_batch(
                concat_files(chunk), slot=offset,
                weights=np.concatenate(weights),
            ).seconds
        chunk = files[offset: offset + num_tuples]
        if not chunk:
            return None
        return ex.process_batch(concat_files(chunk), slot=offset).seconds

    def _finalize(self, query: Query, num_batches: int) -> Optional[float]:
        ex, _ = self._jobs[query.query_id]
        total, agg_s = ex.finalize()
        self.results[query.query_id] = total
        self.agg_seconds[query.query_id] = agg_s
        return agg_s


class SharedAnalyticsExecutor(BaseExecutor):
    """``Executor`` over real segagg jobs with PANE SHARING: every job is a
    window over ONE shared stream of record files, and pane partial
    aggregates are computed once, cached in the ``SharedBook``'s
    ``PaneStore``, and fanned out to every subscribed window.

    ``_execute`` decomposes a batch's global file range into full panes and
    edge fragments.  Cached panes are folded in at merge cost (a numpy add
    — no device scan); runs of uncomputed panes are scanned in ONE
    ``pane_segagg`` pass (composite pane x group keys through the same
    blocked kernel) and each pane's partial is deposited for later
    subscribers.  Fragments are scanned directly and never cached (only a
    fully covered pane is valid for reuse).  Per-query accumulators stay
    offset-keyed exactly like ``AnalyticsExecutor.partials``, so C_max
    straggler re-queues overwrite instead of double-counting, and
    ``_finalize`` combines them into ``results[query_id]`` — the fan-out
    finalize.

    The modelled clock still advances by the scheduler-visible cost models
    (``SharedCostModel`` when the workload was share-transformed); this
    class deduplicates the PHYSICAL work and records measured wall seconds,
    which is where a real backend shows the one-scan-+-k-merges win.
    """

    def __init__(
        self,
        query: AnalyticsQuery,
        stream_files: Sequence[Dict[str, np.ndarray]],
        scale: StreamScale,
        book,  # repro.core.panes.SharedBook (shared with the runtime loop)
        use_kernel: bool = False,
        backend: Optional[str] = None,
        mesh=None,
    ):
        super().__init__()
        self.aquery = query
        self.files = list(stream_files)
        self.num_groups = query.num_groups(scale)
        self.book = book
        self.use_kernel = use_kernel
        self.backend = backend
        self.mesh = mesh
        # query_id -> {local offset: partial}: straggler-idempotent, like
        # AnalyticsExecutor.partials.
        self._acc: Dict[str, Dict[int, np.ndarray]] = {}
        self.results: Dict[str, np.ndarray] = {}
        self.agg_seconds: Dict[str, float] = {}

    # -- physical helpers ------------------------------------------------
    def _scan(self, records: Dict[str, np.ndarray]) -> np.ndarray:
        from ..kernels.segagg.ops import segagg

        keys = np.asarray(self.aquery.key_fn(records), np.int32)
        vals = np.asarray(self.aquery.value_fn(records), np.float32)
        if self.mesh is not None:
            part = self.mesh.segagg(keys, vals, self.num_groups,
                                    backend=self.backend)
        elif self.use_kernel:
            part = segagg(jnp.asarray(keys), jnp.asarray(vals),
                          self.num_groups, backend=self.backend)
        else:
            part = _segagg_ref_jit(jnp.asarray(keys), jnp.asarray(vals),
                                   self.num_groups)
        return np.asarray(part)

    def _scan_panes(self, stream: str, first_pane: int, count: int,
                    width: int, by: str) -> np.ndarray:
        """Scan ``count`` contiguous panes in one ``pane_segagg`` pass,
        deposit each pane's partial, and return their sum (this caller's
        share of the batch)."""
        from ..kernels.segagg.ops import pane_segagg

        lo = first_pane * width
        chunk = self.files[lo: lo + count * width]
        records = concat_files(chunk)
        keys = np.asarray(self.aquery.key_fn(records), np.int32)
        vals = np.asarray(self.aquery.value_fn(records), np.float32)
        # Row counts straight from the record arrays (every field of a file
        # has one row per record) — running key_fn per file would pay a
        # second full key pass inside the timed region.
        sizes = [len(next(iter(f.values()))) for f in chunk]
        pane_of_file = np.repeat(
            np.arange(count, dtype=np.int32), width)[: len(chunk)]
        pane_ids = np.repeat(pane_of_file, sizes).astype(np.int32)
        # The pane pass always runs through the dispatched kernel (there is
        # no jnp ref fast path for pane partials): pre-PR-8 this hardcoded
        # the interpreter, so every shared scan paid interpreter overhead —
        # now the compiled backend does the physical work being measured.
        if self.mesh is not None:
            parts = np.asarray(self.mesh.pane_segagg(
                keys, vals, pane_ids, count, self.num_groups,
                backend=self.backend,
            ))
        else:
            parts = np.asarray(pane_segagg(
                jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(pane_ids),
                count, self.num_groups, backend=self.backend,
            ))
        for j in range(count):
            self.book.store.deposit(stream, first_pane + j, by=by,
                                    data=parts[j])
        return parts.sum(axis=0)

    # -- BaseExecutor hooks ----------------------------------------------
    def _execute(self, query: Query, num_tuples: int, offset: int) -> Optional[float]:
        if num_tuples <= 0:
            return None
        stream = query.stream
        if stream is None:
            raise ValueError(
                f"{query.query_id}: SharedAnalyticsExecutor needs stream-"
                "placed queries (Query.stream/stream_offset)"
            )
        width = self.book.widths.get(stream, max(query.num_tuples_total, 1))
        store = self.book.store
        g0 = query.stream_offset + offset
        g1 = g0 + num_tuples
        t0 = time.perf_counter()
        acc: Optional[np.ndarray] = None
        pos = g0
        pending_scan: Optional[int] = None  # first pane of an uncached run

        def fold(part: np.ndarray) -> None:
            nonlocal acc
            acc = part if acc is None else acc + part

        def flush(upto_pane: int) -> None:
            nonlocal pending_scan
            if pending_scan is not None:
                fold(self._scan_panes(stream, pending_scan,
                                      upto_pane - pending_scan, width,
                                      by=query.query_id))
                pending_scan = None

        while pos < g1:
            pane_idx = pos // width
            pane_lo, pane_hi = pane_idx * width, (pane_idx + 1) * width
            if pos == pane_lo and pane_hi <= g1:
                entry = store.entry(stream, pane_idx)
                if entry is not None and entry.computed and entry.data is not None:
                    flush(pane_idx)
                    fold(entry.data)  # cache hit: merge, no scan
                else:
                    if pending_scan is None:
                        pending_scan = pane_idx
                pos = pane_hi
            else:
                # Edge fragment (batch boundary inside a pane): scan
                # directly, never cached.
                flush(pane_idx)
                frag_hi = min(pane_hi, g1)
                fold(self._scan(concat_files(self.files[pos:frag_hi])))
                pos = frag_hi
        flush(-(-g1 // width))
        self._acc.setdefault(query.query_id, {})[offset] = (
            acc if acc is not None
            else np.zeros((self.num_groups, 1), np.float32)
        )
        return time.perf_counter() - t0

    def _finalize(self, query: Query, num_batches: int) -> Optional[float]:
        t0 = time.perf_counter()
        parts = list(self._acc.get(query.query_id, {}).values())
        total = (np.sum(np.stack(parts), axis=0) if parts
                 else np.zeros((self.num_groups, 1), np.float32))
        self.results[query.query_id] = total
        dt = time.perf_counter() - t0
        self.agg_seconds[query.query_id] = dt
        return dt


class MeshAnalyticsBackend(MeshBackend):
    """``repro.dist.mesh.MeshBackend`` over real segagg analytics jobs:
    one pool worker per mesh device, worker clocks stitched from MEASURED
    wall seconds, shard groups fused into one ``shard_map`` call.

    Usage::

        mesh = DeviceMesh(8)
        wb = MeshAnalyticsBackend(jobs, scale, mesh)
        pool = ExecutorPool(worker_backend=wb)
        run(Planner(policy="llf-dynamic", shard_across=8).policy, specs, pool)

    Dispatch-ahead invariants: a dispatch's partial aggregate is kept ON
    DEVICE (host spill deferred to ``_agg_execute``), and the sharded
    segagg donates its values buffer — so XLA may overlap the next batch's
    host→device transfer with compute, and the measured duration covers
    exactly the device work (``block_until_ready``).  Partials stay
    offset-keyed like ``AnalyticsExecutor.partials``: a straggler requeue
    of a shard group re-runs the covering range and OVERWRITES its slot.
    """

    def __init__(
        self,
        jobs: Dict[str, Tuple[AnalyticsQuery, Sequence[Dict[str, np.ndarray]]]],
        scale: StreamScale,
        mesh,  # repro.dist.DeviceMesh
        backend: Optional[str] = None,
        names: Optional[Sequence[str]] = None,
    ):
        super().__init__(mesh, names)
        self._jobs = {qid: (aq, list(files)) for qid, (aq, files) in jobs.items()}
        self._groups = {qid: aq.num_groups(scale) for qid, (aq, _) in jobs.items()}
        self._segagg_backend = backend
        # query_id -> {offset: ON-DEVICE partial} (deferred host spill).
        self._partials: Dict[str, Dict[int, jax.Array]] = {}
        self.results: Dict[str, np.ndarray] = {}

    def reset(self, t: float) -> None:
        super().reset(t)
        self._partials.clear()
        self.results.clear()

    # -- physical hooks ----------------------------------------------------
    def _run_range(self, query: Query, num_tuples: int, offset: int) -> None:
        aq, files = self._jobs[query.query_id]
        chunk = files[offset: offset + num_tuples]
        if not chunk:
            return
        records = concat_files(chunk)
        keys = np.asarray(aq.key_fn(records), np.int32)
        vals = np.asarray(aq.value_fn(records), np.float32)
        part = self.mesh.segagg(keys, vals, self._groups[query.query_id],
                                backend=self._segagg_backend)
        part.block_until_ready()  # the measured dt covers the device work
        self._partials.setdefault(query.query_id, {})[offset] = part

    def _batch_execute(self, query: Query, num_tuples: int, offset: int) -> None:
        self._run_range(query, num_tuples, offset)

    def _group_execute(
        self,
        query: Query,
        sizes: Tuple[int, ...],
        base_offset: int,
        workers: Tuple[str, ...],
    ) -> None:
        # ONE fused mesh call over the covering range: the shard split is
        # realized by the mesh's own row sharding (shard_extents match the
        # pool's batch_shard_extents), not by per-shard dispatches.
        self._run_range(query, sum(sizes), base_offset)

    def _agg_execute(self, query: Query, num_batches: int) -> None:
        parts = self._partials.get(query.query_id, {})
        if parts:
            total = np.sum(
                np.stack([np.asarray(p) for p in parts.values()]), axis=0
            )
        else:
            total = np.zeros((self._groups[query.query_id], 1), np.float32)
        self.results[query.query_id] = total

    def requeue_batch(self, query: Query, num_tuples: int, offset: int) -> None:
        """Straggler redo: re-run the covering range; the offset-keyed
        partial overwrites, so no double counting."""
        self._run_range(query, num_tuples, offset)


def _plan_query(query_id: str, num_files: int) -> Query:
    """Untimed stand-in Query for replaying a vetted plan over materialized
    files (all inputs present; modelled costs zero)."""
    return Query(
        query_id=query_id,
        wind_start=0.0,
        wind_end=0.0,
        deadline=float("inf"),
        num_tuples_total=num_files,
        cost_model=LinearCostModel(tuple_cost=0.0),
        arrival=TraceArrival(timestamps=(0.0,) * max(num_files, 1)),
    )


def run_plan(query: AnalyticsQuery, files: Sequence[Dict[str, np.ndarray]],
             plan: Schedule, scale: StreamScale,
             use_kernel: bool = False,
             backend: Optional[str] = None,
             mesh=None) -> Tuple[np.ndarray, List[BatchResult], float]:
    """Execute a scheduler plan (batch sizes in FILES) against real files
    through the shared runtime loop (strict mode: replay the plan verbatim)."""
    rex = AnalyticsRuntimeExecutor({query.query_id: (query, files)}, scale,
                                   use_kernel, backend, mesh)
    q = _plan_query(query.query_id, len(files))
    execute_plan(q, plan, rex, strict=True)
    return (
        rex.results[query.query_id],
        rex.physical(query.query_id).batch_log,
        rex.agg_seconds[query.query_id],
    )


def run_batched(query: AnalyticsQuery, files: Sequence[Dict[str, np.ndarray]],
                batch_files: int, scale: StreamScale,
                use_kernel: bool = False,
                backend: Optional[str] = None,
                mesh=None) -> Tuple[np.ndarray, float, int]:
    """Process in fixed-size batches of ``batch_files``; returns
    (result, total_seconds incl. final agg, num_batches)."""
    ex = AnalyticsExecutor(query, scale, use_kernel, backend, mesh)
    for i in range(0, len(files), batch_files):
        ex.process_batch(concat_files(files[i:i + batch_files]))
    result, agg_s = ex.finalize()
    total = sum(b.seconds for b in ex.batch_log) + agg_s
    return result, total, ex.num_batches


def run_session(
    query: AnalyticsQuery,
    windows: Sequence[Sequence[Dict[str, np.ndarray]]],
    window_timestamps: Sequence[Sequence[float]],
    scale: StreamScale,
    cost_model: CostModelBase,
    *,
    period: Optional[float] = None,
    deadline_offset: Optional[float] = None,
    policy: str = "llf-dynamic",
    calibrate: bool = True,
    use_kernel: bool = False,
    backend: Optional[str] = None,
    mesh=None,
    forecast=None,
    latency_target: Optional[float] = None,
    tenant: Optional[str] = None,
    **session_kw,
) -> Tuple[Dict[int, np.ndarray], SessionTrace]:
    """Session mode over the REAL segagg backend: the paper's continuously
    running scheduler, one recurring GROUP-BY query, one result per window.

    ``windows[w]`` are window ``w``'s files; ``window_timestamps[w]`` their
    ACTUAL arrival instants (the per-window truth — predictions come from
    window 0's trace shifted by ``period``).  Every window must carry the
    same file count (the recurring spec's shape).  With ``calibrate=True``
    the scheduler's cost model refits online from measured wall seconds
    (cost units == seconds, §1/§6.2), so a mis-measured offline model heals
    while the session runs.

    Predictive-scheduling knobs (docs/API.md "Predictive scheduling"):
    ``forecast=`` (bool or ``repro.core.ForecastConfig``) turns on arrival
    forecasting and proactive replanning over the real backend —
    per-window FILE-arrival observations feed the forecaster exactly like
    tuple arrivals in simulation; ``latency_target=`` stamps a Cameo-style
    per-query latency target (seconds past window close) onto the
    recurring query, tightening its urgency in the dynamic policies and
    reported per window via ``QueryOutcome.met_target``; ``tenant=``
    stamps the tenant identity onto the recurring query so per-window
    outcomes carry it (``QueryOutcome.tenant``) and a ``tenancy=``
    session config (forwarded via ``**session_kw``) can enforce the
    tenant's quota.

    Returns ({window_index: combined_aggregate}, SessionTrace).
    """
    if not windows:
        raise ValueError("need at least one window")
    n = len(windows[0])
    if any(len(w) != n for w in windows):
        raise ValueError("every window must carry the same file count "
                         f"(window 0 has {n})")
    if len(window_timestamps) != len(windows):
        raise ValueError("windows and window_timestamps must align")
    base_arr = TraceArrival(timestamps=tuple(window_timestamps[0]))
    if period is None:
        period = base_arr.wind_end - base_arr.wind_start or 1.0
    if deadline_offset is None:
        deadline_offset = 2.0 * cost_model.cost(n)
    base = Query(
        query_id=query.query_id,
        wind_start=base_arr.wind_start,
        wind_end=base_arr.wind_end,
        deadline=base_arr.wind_end + deadline_offset,
        num_tuples_total=n,
        cost_model=cost_model,
        arrival=base_arr,
        latency_target=latency_target,
        tenant=tenant,
    )
    truths = [TraceArrival(timestamps=tuple(ts)) for ts in window_timestamps]
    rspec = RecurringQuerySpec(
        base=base,
        period=period,
        num_windows=len(windows),
        deadline_offset=deadline_offset,
        truth_factory=lambda w: truths[w],
        num_groups=query.num_groups(scale),
    )
    jobs = {
        rspec.window_query(w).query_id: (query, list(files))
        for w, files in enumerate(windows)
    }
    executor = AnalyticsRuntimeExecutor(jobs, scale, use_kernel, backend,
                                        mesh)
    session = Session(policy=policy, executor=executor, calibrate=calibrate,
                      forecast=forecast, **session_kw)
    session.submit(rspec)
    trace = session.run()
    results = {
        w: executor.results[rspec.window_query(w).query_id]
        for w in range(len(windows))
        if rspec.window_query(w).query_id in executor.results
    }
    return results, trace


def run_shared_jobs(
    query: AnalyticsQuery,
    files: Sequence[Dict[str, np.ndarray]],
    windows: Sequence[Tuple[int, int]],
    scale: StreamScale,
    cost_model: CostModelBase,
    *,
    policy: str = "llf-dynamic",
    share: bool = True,
    pane_tuples: Optional[int] = None,
    deadline_frac: float = 3.0,
    use_kernel: bool = False,
    backend: Optional[str] = None,
    mesh=None,
    **policy_params,
):
    """Overlapping GROUP-BY windows over ONE real stream, end to end.

    ``windows[i] = (stream_offset, num_files)`` places job ``i``'s window on
    the shared stream (one file arrives per modelled time unit).  With
    ``share=True`` the workload is pane-share-transformed
    (``repro.core.panes.share_workload``) and executed on a
    ``SharedAnalyticsExecutor``: overlapping windows reuse cached pane
    partials, so shared files are scanned once.  With ``share=False`` the
    same executor class runs with an empty book — every window rescans its
    own files — which is the apples-to-apples unshared baseline.

    Returns ``({job_id: (num_groups, V) aggregate}, trace, book)``.
    """
    from ..core.panes import SharedBook, share_workload
    from ..core.runtime import run as run_loop

    stream = f"{query.query_id}-stream"
    qs = []
    for i, (off, n) in enumerate(windows):
        if off < 0 or off + n > len(files):
            raise ValueError(
                f"window {i} [{off}, {off + n}) outside the stream "
                f"(0..{len(files)})"
            )
        arr = TraceArrival(timestamps=tuple(float(t) for t in range(off, off + n)))
        qs.append(Query(
            query_id=f"{query.query_id}-w{i}",
            wind_start=arr.wind_start,
            wind_end=arr.wind_end,
            deadline=arr.wind_end + deadline_frac * cost_model.cost(n),
            num_tuples_total=n,
            cost_model=cost_model,
            arrival=arr,
            stream=stream,
            stream_offset=off,
        ))
    pol = Planner(policy=policy, **policy_params).policy
    if share:
        specs, book = share_workload(qs, pane_tuples=pane_tuples)
    else:
        specs, book = qs, SharedBook(pane_tuples=pane_tuples)
    executor = SharedAnalyticsExecutor(query, files, scale, book,
                                       use_kernel=use_kernel, backend=backend,
                                       mesh=mesh)
    trace = run_loop(pol, specs, executor,
                     sharing=book if share else None)
    if share:
        book.close()
    return executor.results, trace, book


def measure_cost_model(query: AnalyticsQuery,
                       files: Sequence[Dict[str, np.ndarray]],
                       scale: StreamScale,
                       batch_sizes: Sequence[int] = (1, 4, 16, 64),
                       use_kernel: bool = False,
                       backend: Optional[str] = None,
                       mesh=None) -> CostModelBase:
    """§6.2 calibration: measure execution time vs batch size, fit the
    piecewise-linear model (file units).  ``backend=`` picks the segagg
    path being calibrated (with ``use_kernel=True``) — cost models fitted
    here describe THAT backend's wall clock, so calibrate against the same
    backend the session will execute on."""
    samples = []
    agg_samples = [(1, 0.0)]
    for bs in batch_sizes:
        bs = min(bs, len(files))
        # warmup: first call at each padded shape compiles
        run_batched(query, files[:bs], bs, scale, use_kernel, backend, mesh)
        ex = AnalyticsExecutor(query, scale, use_kernel, backend, mesh)
        reps = max(3, min(8, len(files) // bs))
        for i in range(reps):
            lo = (i * bs) % max(len(files) - bs, 1)
            ex.process_batch(concat_files(files[lo:lo + bs]))
        secs = sorted(b.seconds for b in ex.batch_log)
        samples.append((bs, secs[len(secs) // 2]))  # median per-batch cost
    # final-agg cost vs #batches
    for nb in (2, 8, 32):
        per = max(len(files) // nb, 1)
        ex = AnalyticsExecutor(query, scale, use_kernel, backend, mesh)
        for i in range(nb):
            ex.process_batch(concat_files(files[i * per: (i + 1) * per] or
                                          files[:1]))
        _, agg_s = ex.finalize()
        agg_samples.append((nb, agg_s))
    model = fit_piecewise_linear(samples, agg_samples)
    return model
