"""Real JAX analytics executor: the paper's intermittent GROUP-BY queries
running on-device (segagg kernel / jnp fallback), scheduled by repro.core.

Executor model (DESIGN.md §4, executor 2):

* a batch = concatenated record files; one ``process_batch`` call computes
  the (num_groups, V) partial aggregate on device and SPILLS it to host —
  device memory is released between batches exactly as the paper stores
  intermediate results in files between Spark jobs;
* ``finalize`` = the paper's final aggregation step: combine partials.

``AnalyticsRuntimeExecutor`` adapts this to the ``repro.core.api.Executor``
protocol (``submit_batch``/``finalize``/``clock``), so the SAME runtime loop
that drives the discrete-event simulator and the serving engine drives real
segagg batches: ``run_plan`` is now a thin wrapper over
``repro.core.runtime.execute_plan``.  Partials are keyed by tuple offset, so
a C_max straggler re-queue (the loop re-dispatching an idempotent batch)
overwrites rather than double-counts.

``measure_cost_model`` reproduces §6.2: run batches of different sizes,
time them, fit the piecewise-linear cost model the scheduler consumes.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    CostModelBase,
    LinearCostModel,
    Query,
    RecurringQuerySpec,
    Schedule,
    Session,
    SessionTrace,
    TraceArrival,
    fit_piecewise_linear,
)
from ..core.runtime import BaseExecutor, execute_plan
from ..data.tpch import AnalyticsQuery, StreamScale


@dataclasses.dataclass
class BatchResult:
    num_records: int
    seconds: float


@functools.partial(jax.jit, static_argnames="num_groups")
def _segagg_ref_jit(keys, values, num_groups: int):
    """Module-level jit so the compile cache is shared across ALL
    ``AnalyticsExecutor`` instances: one compile per (num_groups, batch
    shape), not one per executor.  (A per-instance ``jax.jit(lambda ...)``
    defeats the cache — every fresh lambda is a new callable, and
    ``measure_cost_model`` alone builds ~8 executors.)"""
    from ..kernels.segagg.ref import segagg_ref

    return segagg_ref(keys, values, num_groups)


class AnalyticsExecutor:
    """Executes one AnalyticsQuery in intermittent batches."""

    def __init__(self, query: AnalyticsQuery, scale: StreamScale,
                 use_kernel: bool = False):
        self.query = query
        self.scale = scale
        self.num_groups = query.num_groups(scale)
        self.use_kernel = use_kernel
        # Partials keyed by slot (tuple offset when driven by the runtime
        # loop): re-queued stragglers overwrite instead of double-counting.
        self.partials: Dict[int, np.ndarray] = {}
        self.batch_log: List[BatchResult] = []
        if use_kernel:
            from ..kernels.segagg.ops import segagg

            self._agg = lambda k, v: segagg(k, v, self.num_groups, True)
        else:
            self._agg = lambda k, v: _segagg_ref_jit(k, v, self.num_groups)

    def process_batch(self, records: Dict[str, np.ndarray],
                      slot: Optional[int] = None) -> BatchResult:
        keys = np.asarray(self.query.key_fn(records), np.int32)
        vals = np.asarray(self.query.value_fn(records), np.float32)
        t0 = time.perf_counter()
        part = self._agg(jnp.asarray(keys), jnp.asarray(vals))
        part = np.asarray(part)  # spill to host; device buffers released
        dt = time.perf_counter() - t0
        if slot is None:  # sequential mode: next free key, never clobber
            slot = len(self.partials)
            while slot in self.partials:
                slot += 1
        self.partials[slot] = part
        res = BatchResult(num_records=len(keys), seconds=dt)
        self.batch_log.append(res)
        return res

    def finalize(self) -> Tuple[np.ndarray, float]:
        """Final aggregation step (paper §2.1): combine the partials."""
        t0 = time.perf_counter()
        total = (
            np.sum(np.stack(list(self.partials.values())), axis=0)
            if self.partials
            else np.zeros((self.num_groups, 1), np.float32)
        )
        return total, time.perf_counter() - t0

    @property
    def num_batches(self) -> int:
        return len(self.partials)


def concat_files(files: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    keys = files[0].keys()
    return {k: np.concatenate([f[k] for f in files]) for k in keys}


class AnalyticsRuntimeExecutor(BaseExecutor):
    """``repro.core.api.Executor`` over real segagg analytics jobs.

    ``jobs`` maps a scheduler query_id to its (AnalyticsQuery, files); batch
    tuple units are FILES (exactly the paper's setup).  The modelled clock
    advances by cost-model time; measured wall seconds are recorded per
    query (``wall_seconds``) and final results land in ``results``.
    """

    def __init__(
        self,
        jobs: Dict[str, Tuple[AnalyticsQuery, Sequence[Dict[str, np.ndarray]]]],
        scale: StreamScale,
        use_kernel: bool = False,
    ):
        super().__init__()
        self._jobs = {
            qid: (AnalyticsExecutor(aq, scale, use_kernel), files)
            for qid, (aq, files) in jobs.items()
        }
        self.results: Dict[str, np.ndarray] = {}
        self.agg_seconds: Dict[str, float] = {}

    def physical(self, query_id: str) -> AnalyticsExecutor:
        return self._jobs[query_id][0]

    def _execute(self, query: Query, num_tuples: int, offset: int) -> Optional[float]:
        ex, files = self._jobs[query.query_id]
        chunk = files[offset: offset + num_tuples]
        if not chunk:
            return None
        return ex.process_batch(concat_files(chunk), slot=offset).seconds

    def _finalize(self, query: Query, num_batches: int) -> Optional[float]:
        ex, _ = self._jobs[query.query_id]
        total, agg_s = ex.finalize()
        self.results[query.query_id] = total
        self.agg_seconds[query.query_id] = agg_s
        return agg_s


def _plan_query(query_id: str, num_files: int) -> Query:
    """Untimed stand-in Query for replaying a vetted plan over materialized
    files (all inputs present; modelled costs zero)."""
    return Query(
        query_id=query_id,
        wind_start=0.0,
        wind_end=0.0,
        deadline=float("inf"),
        num_tuples_total=num_files,
        cost_model=LinearCostModel(tuple_cost=0.0),
        arrival=TraceArrival(timestamps=(0.0,) * max(num_files, 1)),
    )


def run_plan(query: AnalyticsQuery, files: Sequence[Dict[str, np.ndarray]],
             plan: Schedule, scale: StreamScale,
             use_kernel: bool = False) -> Tuple[np.ndarray, List[BatchResult], float]:
    """Execute a scheduler plan (batch sizes in FILES) against real files
    through the shared runtime loop (strict mode: replay the plan verbatim)."""
    rex = AnalyticsRuntimeExecutor({query.query_id: (query, files)}, scale,
                                   use_kernel)
    q = _plan_query(query.query_id, len(files))
    execute_plan(q, plan, rex, strict=True)
    return (
        rex.results[query.query_id],
        rex.physical(query.query_id).batch_log,
        rex.agg_seconds[query.query_id],
    )


def run_batched(query: AnalyticsQuery, files: Sequence[Dict[str, np.ndarray]],
                batch_files: int, scale: StreamScale,
                use_kernel: bool = False) -> Tuple[np.ndarray, float, int]:
    """Process in fixed-size batches of ``batch_files``; returns
    (result, total_seconds incl. final agg, num_batches)."""
    ex = AnalyticsExecutor(query, scale, use_kernel)
    for i in range(0, len(files), batch_files):
        ex.process_batch(concat_files(files[i:i + batch_files]))
    result, agg_s = ex.finalize()
    total = sum(b.seconds for b in ex.batch_log) + agg_s
    return result, total, ex.num_batches


def run_session(
    query: AnalyticsQuery,
    windows: Sequence[Sequence[Dict[str, np.ndarray]]],
    window_timestamps: Sequence[Sequence[float]],
    scale: StreamScale,
    cost_model: CostModelBase,
    *,
    period: Optional[float] = None,
    deadline_offset: Optional[float] = None,
    policy: str = "llf-dynamic",
    calibrate: bool = True,
    use_kernel: bool = False,
    **session_kw,
) -> Tuple[Dict[int, np.ndarray], SessionTrace]:
    """Session mode over the REAL segagg backend: the paper's continuously
    running scheduler, one recurring GROUP-BY query, one result per window.

    ``windows[w]`` are window ``w``'s files; ``window_timestamps[w]`` their
    ACTUAL arrival instants (the per-window truth — predictions come from
    window 0's trace shifted by ``period``).  Every window must carry the
    same file count (the recurring spec's shape).  With ``calibrate=True``
    the scheduler's cost model refits online from measured wall seconds
    (cost units == seconds, §1/§6.2), so a mis-measured offline model heals
    while the session runs.

    Returns ({window_index: combined_aggregate}, SessionTrace).
    """
    if not windows:
        raise ValueError("need at least one window")
    n = len(windows[0])
    if any(len(w) != n for w in windows):
        raise ValueError("every window must carry the same file count "
                         f"(window 0 has {n})")
    if len(window_timestamps) != len(windows):
        raise ValueError("windows and window_timestamps must align")
    base_arr = TraceArrival(timestamps=tuple(window_timestamps[0]))
    if period is None:
        period = base_arr.wind_end - base_arr.wind_start or 1.0
    if deadline_offset is None:
        deadline_offset = 2.0 * cost_model.cost(n)
    base = Query(
        query_id=query.query_id,
        wind_start=base_arr.wind_start,
        wind_end=base_arr.wind_end,
        deadline=base_arr.wind_end + deadline_offset,
        num_tuples_total=n,
        cost_model=cost_model,
        arrival=base_arr,
    )
    truths = [TraceArrival(timestamps=tuple(ts)) for ts in window_timestamps]
    rspec = RecurringQuerySpec(
        base=base,
        period=period,
        num_windows=len(windows),
        deadline_offset=deadline_offset,
        truth_factory=lambda w: truths[w],
        num_groups=query.num_groups(scale),
    )
    jobs = {
        rspec.window_query(w).query_id: (query, list(files))
        for w, files in enumerate(windows)
    }
    executor = AnalyticsRuntimeExecutor(jobs, scale, use_kernel)
    session = Session(policy=policy, executor=executor, calibrate=calibrate,
                      **session_kw)
    session.submit(rspec)
    trace = session.run()
    results = {
        w: executor.results[rspec.window_query(w).query_id]
        for w in range(len(windows))
        if rspec.window_query(w).query_id in executor.results
    }
    return results, trace


def measure_cost_model(query: AnalyticsQuery,
                       files: Sequence[Dict[str, np.ndarray]],
                       scale: StreamScale,
                       batch_sizes: Sequence[int] = (1, 4, 16, 64),
                       use_kernel: bool = False) -> CostModelBase:
    """§6.2 calibration: measure execution time vs batch size, fit the
    piecewise-linear model (file units)."""
    samples = []
    agg_samples = [(1, 0.0)]
    for bs in batch_sizes:
        bs = min(bs, len(files))
        # warmup: first call at each padded shape compiles
        run_batched(query, files[:bs], bs, scale, use_kernel)
        ex = AnalyticsExecutor(query, scale, use_kernel)
        reps = max(3, min(8, len(files) // bs))
        for i in range(reps):
            lo = (i * bs) % max(len(files) - bs, 1)
            ex.process_batch(concat_files(files[lo:lo + bs]))
        secs = sorted(b.seconds for b in ex.batch_log)
        samples.append((bs, secs[len(secs) // 2]))  # median per-batch cost
    # final-agg cost vs #batches
    for nb in (2, 8, 32):
        per = max(len(files) // nb, 1)
        ex = AnalyticsExecutor(query, scale, use_kernel)
        for i in range(nb):
            ex.process_batch(concat_files(files[i * per: (i + 1) * per] or
                                          files[:1]))
        _, agg_s = ex.finalize()
        agg_samples.append((nb, agg_s))
    model = fit_piecewise_linear(samples, agg_samples)
    return model
