"""Real JAX analytics executor: the paper's intermittent GROUP-BY queries
running on-device (segagg kernel / jnp fallback), scheduled by repro.core.

Executor model (DESIGN.md §4, executor 2):

* a batch = concatenated record files; one ``process_batch`` call computes
  the (num_groups, V) partial aggregate on device and SPILLS it to host —
  device memory is released between batches exactly as the paper stores
  intermediate results in files between Spark jobs;
* ``finalize`` = the paper's final aggregation step: combine partials.

``measure_cost_model`` reproduces §6.2: run batches of different sizes,
time them, fit the piecewise-linear cost model the scheduler consumes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    CostModelBase,
    PiecewiseLinearCostModel,
    Query,
    Schedule,
    fit_piecewise_linear,
    schedule_single,
)
from ..data.tpch import AnalyticsQuery, StreamScale


@dataclasses.dataclass
class BatchResult:
    num_records: int
    seconds: float


class AnalyticsExecutor:
    """Executes one AnalyticsQuery in intermittent batches."""

    def __init__(self, query: AnalyticsQuery, scale: StreamScale,
                 use_kernel: bool = False):
        self.query = query
        self.scale = scale
        self.num_groups = query.num_groups(scale)
        self.use_kernel = use_kernel
        self.partials: List[np.ndarray] = []
        self.batch_log: List[BatchResult] = []
        if use_kernel:
            from ..kernels.segagg.ops import segagg

            self._agg = lambda k, v: segagg(k, v, self.num_groups, True)
        else:
            from ..kernels.segagg.ref import segagg_ref

            self._agg = jax.jit(
                lambda k, v: segagg_ref(k, v, self.num_groups))

    def process_batch(self, records: Dict[str, np.ndarray]) -> BatchResult:
        keys = np.asarray(self.query.key_fn(records), np.int32)
        vals = np.asarray(self.query.value_fn(records), np.float32)
        t0 = time.perf_counter()
        part = self._agg(jnp.asarray(keys), jnp.asarray(vals))
        part = np.asarray(part)  # spill to host; device buffers released
        dt = time.perf_counter() - t0
        self.partials.append(part)
        res = BatchResult(num_records=len(keys), seconds=dt)
        self.batch_log.append(res)
        return res

    def finalize(self) -> Tuple[np.ndarray, float]:
        """Final aggregation step (paper §2.1): combine the partials."""
        t0 = time.perf_counter()
        total = np.sum(np.stack(self.partials), axis=0) if self.partials \
            else np.zeros((self.num_groups, 1), np.float32)
        return total, time.perf_counter() - t0

    @property
    def num_batches(self) -> int:
        return len(self.partials)


def concat_files(files: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    keys = files[0].keys()
    return {k: np.concatenate([f[k] for f in files]) for k in keys}


def run_plan(query: AnalyticsQuery, files: Sequence[Dict[str, np.ndarray]],
             plan: Schedule, scale: StreamScale,
             use_kernel: bool = False) -> Tuple[np.ndarray, List[BatchResult], float]:
    """Execute a scheduler plan (batch sizes in FILES) against real files."""
    ex = AnalyticsExecutor(query, scale, use_kernel)
    idx = 0
    for b in plan.batches:
        chunk = files[idx: idx + b.num_tuples]
        idx += b.num_tuples
        if chunk:
            ex.process_batch(concat_files(chunk))
    result, agg_s = ex.finalize()
    return result, ex.batch_log, agg_s


def run_batched(query: AnalyticsQuery, files: Sequence[Dict[str, np.ndarray]],
                batch_files: int, scale: StreamScale,
                use_kernel: bool = False) -> Tuple[np.ndarray, float, int]:
    """Process in fixed-size batches of ``batch_files``; returns
    (result, total_seconds incl. final agg, num_batches)."""
    ex = AnalyticsExecutor(query, scale, use_kernel)
    for i in range(0, len(files), batch_files):
        ex.process_batch(concat_files(files[i:i + batch_files]))
    result, agg_s = ex.finalize()
    total = sum(b.seconds for b in ex.batch_log) + agg_s
    return result, total, ex.num_batches


def measure_cost_model(query: AnalyticsQuery,
                       files: Sequence[Dict[str, np.ndarray]],
                       scale: StreamScale,
                       batch_sizes: Sequence[int] = (1, 4, 16, 64),
                       use_kernel: bool = False) -> CostModelBase:
    """§6.2 calibration: measure execution time vs batch size, fit the
    piecewise-linear model (file units)."""
    samples = []
    agg_samples = [(1, 0.0)]
    for bs in batch_sizes:
        bs = min(bs, len(files))
        # warmup: first call at each padded shape compiles
        run_batched(query, files[:bs], bs, scale, use_kernel)
        ex = AnalyticsExecutor(query, scale, use_kernel)
        reps = max(3, min(8, len(files) // bs))
        for i in range(reps):
            lo = (i * bs) % max(len(files) - bs, 1)
            ex.process_batch(concat_files(files[lo:lo + bs]))
        secs = sorted(b.seconds for b in ex.batch_log)
        samples.append((bs, secs[len(secs) // 2]))  # median per-batch cost
    # final-agg cost vs #batches
    for nb in (2, 8, 32):
        per = max(len(files) // nb, 1)
        ex = AnalyticsExecutor(query, scale, use_kernel)
        for i in range(nb):
            ex.process_batch(concat_files(files[i * per: (i + 1) * per] or
                                          files[:1]))
        _, agg_s = ex.finalize()
        agg_samples.append((nb, agg_s))
    model = fit_piecewise_linear(samples, agg_samples)
    return model
