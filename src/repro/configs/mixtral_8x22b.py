"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention
(arXiv:2401.04088).  56L d_model=6144 48H (GQA kv=8) expert d_ff=16384
vocab=32768, SWA window 4096."""
from repro.models.config import ModelConfig, uniform


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32_768,
        segments=uniform("moe", 56),
        num_experts=8,
        top_k=2,
        expert_d_ff=16384,
        window=4096,
        train_microbatches=4,
        prefill_row_chunks=2,
    )
