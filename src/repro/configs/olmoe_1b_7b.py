"""olmoe-1b-7b [moe]: 64 experts top-8 (arXiv:2409.02060).
16L d_model=2048 16H (MHA kv=16) expert d_ff=1024 vocab=50304."""
from repro.models.config import ModelConfig, uniform


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50_304,
        segments=uniform("moe", 16),
        num_experts=64,
        top_k=8,
        expert_d_ff=1024,
    )
