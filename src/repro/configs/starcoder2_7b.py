"""starcoder2-7b [dense]: GQA + RoPE, LayerNorm + plain-MLP + biases
(arXiv:2402.19173).  32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.

Note: 36 heads is NOT divisible by the 16-way model axis; the sharding layer
falls back to unsharded head dims for this arch and shards attention over
sequence instead (DESIGN.md §6)."""
from repro.models.config import ModelConfig, uniform


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49_152,
        segments=uniform("attn", 32),
        norm="ln",
        act="gelu_tanh",
        mlp_gated=False,
        bias=True,
        rope_theta=1_000_000.0,
    )
