"""internvl2-76b [vlm]: InternViT + LLM backbone (arXiv:2404.16821).
Backbone only per assignment — the vision frontend is a STUB providing
precomputed patch embeddings (256 patches).  80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256."""
from repro.models.config import ModelConfig, uniform


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128_256,
        segments=uniform("attn", 80),
        frontend="vision",
        num_patches=256,
        train_microbatches=2,
        rope_theta=500_000.0,
    )
