"""yi-6b [dense]: llama-arch GQA (arXiv:2403.04652).
32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000."""
from repro.models.config import ModelConfig, uniform


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b",
        family="dense",
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64_000,
        segments=uniform("attn", 32),
        rope_theta=5_000_000.0,
    )
