"""chatglm3-6b [dense]: 2d-RoPE (half-dim rotary), GQA (arXiv:2406.12793).
28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024."""
from repro.models.config import ModelConfig, uniform


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65_024,
        segments=uniform("attn", 28),
        rotary_frac=0.5,
    )
