"""mamba2-370m [ssm]: SSD, attention-free (arXiv:2405.21060).
48L d_model=1024, ssm_state=128, head_dim 64 (32 SSD heads), d_ff=0."""
from repro.models.config import ModelConfig, uniform


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        segments=uniform("ssm", 48),
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        tie_embeddings=True,
    )
