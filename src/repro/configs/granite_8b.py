"""granite-8b [dense]: llama-arch, code (arXiv:2405.04324).
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152."""
from repro.models.config import ModelConfig, uniform


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=49_152,
        segments=uniform("attn", 36),
        rope_theta=10_000_000.0,
    )
