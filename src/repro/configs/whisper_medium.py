"""whisper-medium [audio]: encoder-decoder (arXiv:2212.04356).  Backbone
only — the conv/mel frontend is a STUB providing precomputed frame
embeddings (1500 frames).  24L enc + 24L dec, d_model=1024 16H (MHA kv=16,
head_dim 64) d_ff=4096 vocab=51865 (PADDED to 51872 = 16*3242 so the (B,S,V) f32 loss
blocks shard on the model axis; 7 dead ids, standard production practice).
LayerNorm, plain MLP, biases,
sinusoidal absolute positions (learned-positions deviation noted; published
decoder caps at 448 tokens — decode cells are exercised structurally)."""
from repro.models.config import ModelConfig, uniform


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51_872,  # padded from 51865 to divide the 16-way model axis
        segments=uniform("xattn", 24),
        encoder_segments=uniform("attn", 24),
        encoder_seq=1500,
        norm="ln",
        act="gelu",
        mlp_gated=False,
        bias=True,
        rotary_frac=0.0,
        abs_positions=True,
        frontend="audio",
        tie_embeddings=True,
    )
