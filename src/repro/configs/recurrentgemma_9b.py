"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1 attn : 2 recurrent
(arXiv:2402.19427).  38L d_model=4096 16H (MQA kv=1, head_dim 256)
d_ff=12288 vocab=256000, local window 2048, lru_width 4096."""
from repro.models.config import ModelConfig, patterned


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        segments=patterned(("rglru", "rglru", "attn"), 38),
        window=2048,
        lru_width=4096,
        act="gelu_tanh",
        rope_theta=10_000.0,
    )
