"""Step-function builders: one (arch x shape x mesh) cell -> jitted fn +
ShapeDtypeStruct example args + in/out shardings.

Used by the dry-run (lower+compile only), the trainer and the server.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist.sharding import (
    cache_pspecs,
    input_pspecs,
    param_pspecs,
    param_shardings,
)
from ..models import lm
from ..models.base import ShapeCell, input_specs as model_input_specs
from ..models.config import ModelConfig
from ..models.encdec import build_encdec_specs, encdec_loss
from ..models.params import shape_structs
from ..train.optimizer import (
    AdamWConfig,
    TrainState,
    apply_updates,
    cast_params,
    state_shape_structs,
)


@dataclasses.dataclass
class CellProgram:
    fn: Callable
    args: Tuple[Any, ...]             # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jitted().lower(*self.args)


def model_specs(cfg: ModelConfig):
    return build_encdec_specs(cfg) if cfg.family == "audio" else lm.build_specs(cfg)


def loss_fn_for(cfg: ModelConfig):
    return encdec_loss if cfg.family == "audio" else lm.lm_loss


def _replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def build_train_program(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                        adamw: AdamWConfig = AdamWConfig(),
                        remat: bool = True) -> CellProgram:
    specs = model_specs(cfg)
    pstructs = shape_structs(specs)
    state_structs = state_shape_structs(pstructs)
    in_structs = model_input_specs(cfg, cell)
    loss_fn = loss_fn_for(cfg)

    pspecs = param_pspecs(specs, mesh)
    pshard = {k: NamedSharding(mesh, s) for k, s in pspecs.items()}

    nmicro = max(cfg.train_microbatches, 1)
    if cell.global_batch % nmicro:
        nmicro = 1

    def train_step(state: TrainState, batch):
        def scalar_loss(masters, mb):
            # bf16 compute copies, explicitly pinned to the param sharding:
            # these are the scan xs, and the backward builds the stacked
            # grad accumulator with the same spec (otherwise Shardy loses it
            # through the while-loop cotangent and replicates multi-GiB
            # buffers).
            p = {k: jax.lax.with_sharding_constraint(
                    v.astype(jnp.bfloat16), pshard[k])
                 for k, v in masters.items()}
            loss, metrics = loss_fn(cfg, p, mb, remat=remat)
            return loss, metrics

        if nmicro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                scalar_loss, has_aux=True)(state.params, batch)
        else:
            # Gradient accumulation: split the global batch into nmicro
            # microbatches, scan, and average grads in f32.  This is what
            # keeps the biggest train cells (mixtral/internvl2 @ B=256,
            # S=4096) inside 16 GiB/chip.
            from ..dist.sharding import batch_spec

            # Keep the microbatch dim unsharded and re-pin the row dim to
            # the DP axes — without the constraint SPMD resolves the
            # reshape by full rematerialisation (replicate-then-reshard).
            mbs = {k: jax.lax.with_sharding_constraint(
                       v.reshape((nmicro, v.shape[0] // nmicro) + v.shape[1:]),
                       NamedSharding(mesh, P(None, *batch_spec(
                           mesh, v.shape[0] // nmicro, v.ndim))))
                   for k, v in batch.items()}

            def micro(acc, mb):
                g_acc, loss_acc = acc
                (loss, metrics), g = jax.value_and_grad(
                    scalar_loss, has_aux=True)(state.params, mb)
                g_acc = {k: g_acc[k] + g[k].astype(jnp.float32)
                         for k in g_acc}
                return (g_acc, loss_acc + loss), metrics

            g0 = {k: jnp.zeros(v.shape, jnp.float32)
                  for k, v in state.params.items()}
            g0 = {k: jax.lax.with_sharding_constraint(v, pshard[k])
                  for k, v in g0.items()}
            (grads, loss_sum), metrics = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), mbs,
                unroll=nmicro if cfg.inner_unroll else 1)
            grads = {k: g / nmicro for k, g in grads.items()}
            loss = loss_sum / nmicro
            metrics = jax.tree.map(lambda x: x[-1], metrics)

        # Pin gradient shardings to the parameter shardings BEFORE the
        # optimizer: sharding propagation loses the spec for scan-stacked
        # cotangents and otherwise materialises replicated full-size
        # weight-gradient buffers (observed: 5.8 GiB f32[32,4096,11008]
        # per chip for yi-6b).
        grads = {k: jax.lax.with_sharding_constraint(g, pshard[k])
                 for k, g in grads.items()}
        new_state, opt_metrics = apply_updates(state, grads, adamw)
        out_metrics = {"loss": loss, **{k: v for k, v in metrics.items()},
                       **opt_metrics}
        return new_state, out_metrics
    state_shard = TrainState(params=pshard, m=dict(pshard), v=dict(pshard),
                             step=_replicated(mesh))
    batch_shard = {k: NamedSharding(mesh, s)
                   for k, s in input_pspecs(in_structs, mesh).items()}
    metrics_shard = None  # compiler-chosen
    return CellProgram(
        fn=train_step,
        args=(state_structs, in_structs),
        in_shardings=(state_shard, batch_shard),
        out_shardings=(state_shard, metrics_shard),
        donate_argnums=(0,),
    )


def build_prefill_program(cfg: ModelConfig, cell: ShapeCell,
                          mesh: Mesh) -> CellProgram:
    specs = model_specs(cfg)
    pstructs = shape_structs(specs)
    in_structs = model_input_specs(cfg, cell)
    pshard = param_shardings(specs, mesh)
    batch_shard = {k: NamedSharding(mesh, s)
                   for k, s in input_pspecs(in_structs, mesh).items()}

    cache_structs = lm.cache_shape_specs(cfg, cell.global_batch, cell.seq_len)
    cache_shard = {k: NamedSharding(mesh, s)
                   for k, s in cache_pspecs(cfg, cache_structs, mesh).items()}

    if cfg.family == "audio":
        from ..models.encdec import encdec_prefill

        def prefill_step(params, batch):
            logits, cache, clen, _ = encdec_prefill(
                cfg, params, batch["frames"], batch["tokens"], cell.seq_len)
            return logits, cache, clen
    else:
        def prefill_step(params, batch):
            logits, cache, clen = lm.prefill(
                cfg, params, batch["tokens"], cell.seq_len,
                patches=batch.get("patches"))
            return logits, cache, clen

    return CellProgram(
        fn=prefill_step,
        args=(pstructs, in_structs),
        in_shardings=(pshard, batch_shard),
        out_shardings=(None, cache_shard, None),
    )


def build_decode_program(cfg: ModelConfig, cell: ShapeCell,
                         mesh: Mesh) -> CellProgram:
    """serve_step: one new token against a seq_len-deep cache."""
    specs = model_specs(cfg)
    pstructs = shape_structs(specs)
    pshard = param_shardings(specs, mesh)
    B = cell.global_batch
    cache_structs = lm.cache_shape_specs(cfg, B, cell.seq_len)
    cache_shard = {k: NamedSharding(mesh, s)
                   for k, s in cache_pspecs(cfg, cache_structs, mesh).items()}
    tok_struct = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_shard = NamedSharding(
        mesh, input_pspecs({"tokens": tok_struct}, mesh)["tokens"])
    clen_struct = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, cache_len, tokens):
        return lm.decode_step(cfg, params, cache, cache_len, tokens)

    return CellProgram(
        fn=serve_step,
        args=(pstructs, cache_structs, clen_struct, tok_struct),
        in_shardings=(pshard, cache_shard, _replicated(mesh), tok_shard),
        out_shardings=(None, cache_shard),
        donate_argnums=(1,),
    )


def build_cell_program(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                       **kw) -> CellProgram:
    if cell.kind == "train":
        return build_train_program(cfg, cell, mesh, **kw)
    if cell.kind == "prefill":
        return build_prefill_program(cfg, cell, mesh)
    if cell.kind == "decode":
        return build_decode_program(cfg, cell, mesh)
    raise ValueError(cell.kind)
