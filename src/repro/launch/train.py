"""End-to-end training driver (deliverable b: the ~100M-scale example).

Runs REAL steps on the host devices (CPU here; the same program lowers to
the production mesh via --dryrun-mesh in repro.launch.dryrun):

    python -m repro.launch.train --arch yi_6b --reduced --steps 50

Features exercised: synthetic LM data pipeline, mixed-precision AdamW,
remat + scan, checkpoint/restart (crash-safe; --resume), deadline-aware
eval scheduling (the paper's technique driving when window-eval jobs run),
straggler bound C_max (a step exceeding it is logged and re-dispatched).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.base import ShapeCell, get_config
from ..models.params import init_params, num_params, shape_structs
from ..train.checkpoint import latest_valid, restore_checkpoint, save_checkpoint
from ..train.optimizer import AdamWConfig, TrainState, init_state
from .mesh import make_host_mesh
from .steps import build_train_program, model_specs


def synthetic_batches(cfg, batch: int, seq: int, seed: int = 0
                      ) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic synthetic LM stream (zipf-ish unigram with order)."""
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, cfg.vocab_size + 1) ** 1.1
    probs /= probs.sum()
    while True:
        toks = rng.choice(cfg.vocab_size, size=(batch, seq + 1), p=probs)
        b = {"tokens": toks[:, :-1].astype(np.int32),
             "labels": toks[:, 1:].astype(np.int32)}
        if cfg.frontend == "vision":
            b["patches"] = rng.normal(
                0, 0.02, (batch, cfg.num_patches, cfg.d_model)
            ).astype(np.float32)
        if cfg.frontend == "audio":
            b["frames"] = rng.normal(
                0, 0.02, (batch, cfg.encoder_seq, cfg.d_model)
            ).astype(np.float32)
        yield b


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--c-max", type=float, default=60.0,
                    help="straggler bound: step wall-time budget (s)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        # widen a bit so the example is ~100M params rather than ~1M
        cfg = dataclasses.replace(
            cfg, d_model=512,
            num_heads=8, num_kv_heads=min(8, max(cfg.num_kv_heads, 2)),
            head_dim=64, d_ff=1536 if cfg.d_ff else 0,
            lru_width=512 if cfg.lru_width else 0,
            vocab_size=32_768,
            segments=tuple(dataclasses.replace(s, num_units=4)
                           for s in cfg.segments),
            encoder_segments=tuple(dataclasses.replace(s, num_units=4)
                                   for s in cfg.encoder_segments),
        )
    specs = model_specs(cfg)
    print(f"arch={cfg.name} params={num_params(specs)/1e6:.1f}M")

    mesh = make_host_mesh(model_parallel=1)
    cell = ShapeCell("example", "train", args.seq, args.batch)
    prog = build_train_program(cfg, cell, mesh,
                               adamw=AdamWConfig(lr=args.lr, warmup_steps=20))
    step_fn = prog.jitted()

    start_step = 0
    if args.resume:
        ckpt = latest_valid(args.ckpt_dir)
        if ckpt is not None:
            start_step, flat, _ = restore_checkpoint(ckpt)
            state = TrainState(
                params={k[len("params/"):]: v for k, v in flat.items()
                        if k.startswith("params/")},
                m={k[len("m/"):]: v for k, v in flat.items()
                   if k.startswith("m/")},
                v={k[len("v/"):]: v for k, v in flat.items()
                   if k.startswith("v/")},
                step=jnp.asarray(start_step, jnp.int32),
            )
            print(f"resumed from {ckpt} at step {start_step}")
        else:
            print("no valid checkpoint found; cold start")
            state = init_state(init_params(specs, jax.random.PRNGKey(0)))
    else:
        state = init_state(init_params(specs, jax.random.PRNGKey(0)))

    data = synthetic_batches(cfg, args.batch, args.seq)
    with mesh:
        losses = []
        for i in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if dt > args.c_max:
                print(f"[straggler] step {i} took {dt:.1f}s > C_max "
                      f"{args.c_max}s — would re-dispatch on a pod")
            losses.append(loss)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt*1e3:.0f} ms)")
            if (i + 1) % args.ckpt_every == 0 or i == args.steps - 1:
                flat = {}
                flat.update({f"params/{k}": v for k, v in state.params.items()})
                flat.update({f"m/{k}": v for k, v in state.m.items()})
                flat.update({f"v/{k}": v for k, v in state.v.items()})
                path = save_checkpoint(args.ckpt_dir, i + 1, flat,
                                       extra={"loss": loss})
                print(f"checkpoint -> {path}")
    first, last = losses[0], losses[-1]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
