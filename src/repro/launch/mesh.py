"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state.  Shapes: single pod = (data=16, model=16) — 256
chips of TPU v5e; multi-pod = (pod=2, data=16, model=16) = 512 chips, the
"pod" axis carrying inter-pod data parallelism only (gradient all-reduce).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW_PER_LINK = 50e9         # bytes/s/link (~ per direction)
HBM_BYTES = 16 * 1024**3       # 16 GiB
