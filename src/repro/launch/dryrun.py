import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture x input-shape) cell, on the single-pod (16x16) and
multi-pod (2x16x16) production meshes:

    lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(*specs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves it fits 16 GiB/chip
    print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

COMPOSITIONAL COSTING.  XLA's cost_analysis counts while-loop bodies ONCE
(verified empirically), so the depth-scanned full program under-reports
flops/bytes/collectives by ~the layer count.  Costs are therefore measured
compositionally, which is exact for scans (every trip is identical):

    cost(U1..Uk) = base + sum_s U_s * unit_s
    base        = cost(model with zero layers)         [embed+loss+optimizer]
    unit_s      = cost(model with only segment s, 1 unit) - base

The cost variants set ``inner_unroll`` so attention/SSD chunk scans are fully
unrolled (counted exactly); the PRODUCTION program (scanned, not unrolled) is
still lowered AND compiled for the memory_analysis fit proof and the
compile-coherence proof.  Both artifacts are recorded.

Results are cached as JSON under benchmarks/results/dryrun/.

Usage:
    python -m repro.launch.dryrun --arch yi_6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import numpy as np

from repro.dist.roofline import Roofline, parse_collectives
from repro.launch.mesh import (
    HBM_BYTES,
    HBM_BW,
    ICI_BW_PER_LINK,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from repro.launch.steps import build_cell_program, model_specs
from repro.models.base import ARCH_IDS, SHAPES, cell_supported, get_config
from repro.models.params import num_params

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"
ICI_LINKS = 4


def active_params(cfg) -> int:
    """Parameters touched per token (MoE experts scaled by top_k/E)."""
    specs = model_specs(cfg)
    total = 0
    for s in specs.values():
        n = int(np.prod(s.shape))
        if "experts" in s.axes and cfg.num_experts:
            n = int(n * cfg.top_k / cfg.num_experts)
        total += n
    return total


def model_flops(cfg, cell) -> float:
    """Analytic MODEL_FLOPS (param-matmul only: 6*N*D train, 2*N*D fwd)."""
    n_act = active_params(cfg)
    if cell.kind == "train":
        return 6.0 * n_act * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_act * cell.global_batch * cell.seq_len
    return 2.0 * n_act * cell.global_batch


def _segment_variants(cfg):
    """(zero-layer cfg, [(segment_index, one-unit cfg, num_units)])."""
    base = dataclasses.replace(cfg, segments=(), encoder_segments=(),
                               inner_unroll=True)
    variants = []
    for si, seg in enumerate(cfg.segments):
        one = dataclasses.replace(
            cfg, segments=(dataclasses.replace(seg, num_units=1),),
            encoder_segments=(), inner_unroll=True)
        variants.append(("dec", si, one, seg.num_units))
    for si, seg in enumerate(cfg.encoder_segments):
        one = dataclasses.replace(
            cfg, segments=(),
            encoder_segments=(dataclasses.replace(seg, num_units=1),),
            inner_unroll=True)
        variants.append(("enc", si, one, seg.num_units))
    return base, variants


def _measure(cfg, cell, mesh):
    """cost_analysis + collective stats for one variant program."""
    prog = build_cell_program(cfg, cell, mesh)
    with mesh:
        compiled = prog.jitted().lower(*prog.args).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        colls = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": colls.total_bytes,
        "coll_counts": colls.counts,
    }


def _combine(base, units):
    """base + sum U_s * (unit_s - base), element-wise on cost dicts."""
    out = {
        "flops": base["flops"],
        "bytes": base["bytes"],
        "coll_bytes": base["coll_bytes"],
        "coll_counts": dict(base["coll_counts"]),
    }
    for meas, U in units:
        for key in ("flops", "bytes", "coll_bytes"):
            out[key] += U * max(meas[key] - base[key], 0.0)
        for k, c in meas["coll_counts"].items():
            delta = c - base["coll_counts"].get(k, 0)
            if delta > 0:
                out["coll_counts"][k] = out["coll_counts"].get(k, 0) + U * delta
    return out


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    skip = cell_supported(cfg, cell)
    if skip:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    nchips = int(np.prod(list(mesh.shape.values())))

    # ---- production program: compile-coherence + memory-fit proof ---------
    t0 = time.time()
    prog = build_cell_program(cfg, cell, mesh)
    with mesh:
        lowered = prog.jitted().lower(*prog.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        ma = compiled.memory_analysis()
        print(ma)
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print({k: ca.get(k) for k in ("flops", "bytes accessed")})

    # ---- compositional costing (unrolled variants) -------------------------
    base_cfg, variants = _segment_variants(cfg)
    base = _measure(base_cfg, cell, mesh)
    units = [(_measure(vcfg, cell, mesh), U) for _, _, vcfg, U in variants]
    cost = _combine(base, units)

    roof = Roofline(
        compute_s=cost["flops"] / PEAK_FLOPS_BF16,
        memory_s=cost["bytes"] / HBM_BW,
        collective_s=cost["coll_bytes"] / (ICI_BW_PER_LINK * ICI_LINKS),
        flops_per_chip=cost["flops"],
        bytes_per_chip=cost["bytes"],
        collective_bytes_per_chip=cost["coll_bytes"],
        collective_counts=cost["coll_counts"],
    )

    mf = model_flops(cfg, cell)
    hlo_flops_total = roof.flops_per_chip * nchips
    peak_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                  + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return {
        "arch": arch,
        "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "chips": nchips,
        "kind": cell.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "params": num_params(model_specs(cfg)),
        "active_params": active_params(cfg),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_chip": peak_bytes,
            "fits_hbm": bool(peak_bytes < HBM_BYTES),
        },
        "roofline": roof.as_dict(),
        "model_flops_total": mf,
        "hlo_flops_total": hlo_flops_total,
        "useful_flops_ratio": mf / hlo_flops_total if hlo_flops_total else None,
        "mfu_bound": mf / (nchips * PEAK_FLOPS_BF16 * roof.step_seconds)
        if roof.step_seconds else None,
    }


def cell_path(arch: str, shape: str, mesh: str) -> pathlib.Path:
    return RESULTS_DIR / f"{arch}__{shape}__{mesh}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                out = cell_path(arch, shape, mesh_name)
                if out.exists() and not args.force:
                    prev = json.loads(out.read_text())
                    if prev.get("status") != "error":
                        print(f"[cached] {arch} x {shape} x {mesh_name}")
                        continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...",
                      flush=True)
                try:
                    rec = run_cell(arch, shape, multi)
                except Exception as e:  # record failures — they are bugs
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                out.write_text(json.dumps(rec, indent=2))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" step={r['step_seconds']:.4f}s"
                             f" mem={rec['memory']['peak_bytes_per_chip']/2**30:.2f}GiB"
                             f" fits={rec['memory']['fits_hbm']}"
                             f" mfu_bound={rec['mfu_bound']:.3f}")
                print(f"[{status}] {arch} x {shape} x {mesh_name}{extra}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
