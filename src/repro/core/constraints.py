"""Legacy constraint-based entry points (paper §3.2, Eqs. 5-8).

The solver moved to ``repro.core.policies.constraint`` (registered as the
``constraints`` and ``brute-force`` policies); the functions below are thin
deprecation shims kept for the pre-Planner API.  ``feasible_assignment`` is
re-exported unchanged (it is the fixed-n feasibility primitive, not a
scheduling scheme).

Migration:

    schedule_via_constraints(q)  -> Planner(policy="constraints").schedule(q)
    brute_force_optimal(q)       -> Planner(policy="brute-force").schedule(q)
                                    (or policies.constraint.brute_force_search
                                    for the raw (n, sizes) tuple)
"""
from __future__ import annotations

from typing import Optional, Tuple

from ._deprecation import warn_deprecated
from .policies.constraint import (  # canonical implementations
    brute_force_search,
    feasible_assignment,
    plan_via_constraints,
)
from .types import Query, Schedule

__all__ = [
    "brute_force_optimal",
    "feasible_assignment",
    "schedule_via_constraints",
]


def schedule_via_constraints(query: Query, max_batches: int = 512) -> Schedule:
    """Deprecated shim for the ``constraints`` policy."""
    warn_deprecated(
        "schedule_via_constraints()", 'Planner(policy="constraints")'
    )
    return plan_via_constraints(query, max_batches)


def brute_force_optimal(
    query: Query, max_batches: int = 4
) -> Optional[Tuple[int, Tuple[int, ...]]]:
    """Deprecated shim for the ``brute-force`` policy / search."""
    warn_deprecated(
        "brute_force_optimal()",
        'Planner(policy="brute-force") or policies.constraint.brute_force_search()',
    )
    return brute_force_search(query, max_batches)
