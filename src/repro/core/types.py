"""Core datatypes for intermittent-query scheduling.

Mirrors Table 1 of the paper (notation for query attributes). Times are floats
in *cost-model units* (the paper's experiments equate cost and time: "cost
refers to the total time required for processing the query", §1). Tuple counts
are ints.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple


class InfeasibleDeadline(Exception):
    """Raised when no batch schedule can meet the query deadline (§3.1)."""


class Strategy(enum.Enum):
    """Multi-query dispatch strategies (§4.2)."""

    LLF = "llf"
    EDF = "edf"
    SJF = "sjf"
    RR = "rr"


@dataclasses.dataclass(frozen=True)
class Batch:
    """One scheduled batch: process ``num_tuples`` starting at ``sched_time``."""

    sched_time: float
    num_tuples: int

    def __post_init__(self) -> None:
        if self.num_tuples < 0:
            raise ValueError(f"negative batch size {self.num_tuples}")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Output of the single-query planners: Algorithm 1's (schPoints, schTuples)."""

    batches: Tuple[Batch, ...]

    @property
    def sch_points(self) -> List[float]:
        return [b.sched_time for b in self.batches]

    @property
    def sch_tuples(self) -> List[int]:
        return [b.num_tuples for b in self.batches]

    @property
    def num_batches(self) -> int:
        return len(self.batches)

    @property
    def total_tuples(self) -> int:
        return sum(b.num_tuples for b in self.batches)


@dataclasses.dataclass
class Query:
    """A deadline-bound intermittent query (Table 1).

    ``cost_model`` maps tuples->processing cost for one batch;
    ``arrival`` models the input stream rate (InputTime / tuples_available).
    """

    query_id: str
    wind_start: float
    wind_end: float
    deadline: float
    num_tuples_total: int
    cost_model: "CostModelBase"  # noqa: F821  (cost_model.py)
    arrival: "ArrivalModel"  # noqa: F821  (arrivals.py)
    # Optional distinct final-aggregation model; defaults to cost_model.agg_cost.
    submit_time: Optional[float] = None  # when the query enters the system (§4)

    def __post_init__(self) -> None:
        if self.wind_end < self.wind_start:
            raise ValueError("wind_end < wind_start")
        if self.submit_time is None:
            self.submit_time = self.wind_start

    @property
    def min_comp_cost(self) -> float:
        """minCompCost: cost of processing all tuples in a single batch (Table 1)."""
        return self.cost_model.cost(self.num_tuples_total)

    @property
    def slack_time(self) -> float:
        """Eq. (2): slackTime = deadline - windEndTime - minCompCost."""
        return self.deadline - self.wind_end - self.min_comp_cost


@dataclasses.dataclass(frozen=True)
class Plan:
    """Output of ``SchedulingPolicy.plan``: one static Schedule per query.

    For static policies this is the Algorithm-1/constraint plan verbatim; for
    dynamic policies it is the REALIZED batch sequence of a simulated run
    (dynamic scheduling decides at runtime — the Plan is its deterministic
    projection under the predicted arrival model).
    """

    schedules: Dict[str, Schedule]
    policy: str = ""

    def __getitem__(self, query_id: str) -> Schedule:
        return self.schedules[query_id]

    def __contains__(self, query_id: str) -> bool:
        return query_id in self.schedules

    @property
    def query_ids(self) -> List[str]:
        return list(self.schedules)

    @property
    def num_batches(self) -> int:
        return sum(s.num_batches for s in self.schedules.values())


@dataclasses.dataclass(frozen=True)
class BatchShard:
    """One shard of a logical batch, bound for one pool worker.

    ``worker`` names the target worker; ``None`` means "next earliest-free
    worker not yet claimed by an earlier shard of the same decision".
    """

    num_tuples: int
    worker: Optional[str] = None

    def __post_init__(self) -> None:
        if self.num_tuples <= 0:
            raise ValueError(f"shard size must be positive, got {self.num_tuples}")


@dataclasses.dataclass(frozen=True)
class PolicyDecision:
    """One dispatch decision of a dynamic policy (Algorithm 2's winner).

    Exactly one of the three forms:

    * run   — ``query_id`` set: run ``num_tuples`` of that query now;
    * wait  — ``wake_at`` set: nothing ready, idle until that instant;
    * stop  — neither set: no admissible work will ever become ready.

    Pool extensions (ignored by single-executor runs):

    * ``worker`` — dispatch the batch to this named ``ExecutorPool`` worker
      instead of the earliest-free one;
    * ``shards`` — split the logical batch into per-worker shards (sizes must
      sum to ``num_tuples``); each shard lands on its own worker and becomes
      its own offset-keyed partial, combined in ``finalize`` exactly like
      segagg partials.  Use ``repro.dist.sharding.batch_shard_extents`` to
      derive balanced shard sizes.
    """

    query_id: Optional[str] = None
    num_tuples: int = 0
    wake_at: Optional[float] = None
    worker: Optional[str] = None
    shards: Optional[Tuple[BatchShard, ...]] = None

    def __post_init__(self) -> None:
        if self.shards is not None:
            if self.worker is not None:
                raise ValueError("worker= and shards= are mutually exclusive")
            total = sum(s.num_tuples for s in self.shards)
            if total != self.num_tuples:
                raise ValueError(
                    f"shards sum to {total}, decision num_tuples is "
                    f"{self.num_tuples}"
                )

    @property
    def is_run(self) -> bool:
        return self.query_id is not None

    @property
    def is_wait(self) -> bool:
        return self.query_id is None and self.wake_at is not None

    @property
    def is_stop(self) -> bool:
        return self.query_id is None and self.wake_at is None


@dataclasses.dataclass(frozen=True)
class BatchExecution:
    """One executed batch in a trace (simulator / real executor).

    ``worker`` is the pool worker that ran the batch ("" outside a pool).
    It is excluded from equality: worker placement is an execution detail,
    so single-executor traces and W=1 pool traces compare identical.
    """

    query_id: str
    start: float
    end: float
    num_tuples: int
    kind: str = "batch"  # "batch" | "final_agg"
    worker: str = dataclasses.field(default="", compare=False)


@dataclasses.dataclass
class QueryOutcome:
    query_id: str
    completion_time: float
    deadline: float
    total_cost: float
    num_batches: int

    @property
    def met_deadline(self) -> bool:
        # Allow tiny float slop from accumulated arithmetic.
        return self.completion_time <= self.deadline + 1e-9


@dataclasses.dataclass
class ExecutionTrace:
    executions: List[BatchExecution] = dataclasses.field(default_factory=list)
    outcomes: List[QueryOutcome] = dataclasses.field(default_factory=list)
    # query_ids of batches whose REAL execution exceeded C_max (straggler
    # re-queue events recorded by the shared runtime loop; empty in pure
    # simulation, where modelled batch costs respect C_max by construction).
    stragglers: List[str] = dataclasses.field(default_factory=list)

    @property
    def total_cost(self) -> float:
        return sum(e.end - e.start for e in self.executions)

    def outcome(self, query_id: str) -> QueryOutcome:
        for o in self.outcomes:
            if o.query_id == query_id:
                return o
        raise KeyError(query_id)

    @property
    def all_met(self) -> bool:
        return all(o.met_deadline for o in self.outcomes)
