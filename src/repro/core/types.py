"""Core datatypes for intermittent-query scheduling.

Mirrors Table 1 of the paper (notation for query attributes). Times are floats
in *cost-model units* (the paper's experiments equate cost and time: "cost
refers to the total time required for processing the query", §1). Tuple counts
are ints.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# The ONE comparison tolerance for modelled time and tuple counts.
#
# Cost units == time units (§1) and both are O(1)-O(1e4) in every scenario the
# paper and the benchmarks exercise, so a single absolute epsilon serves all
# three historic uses: count-scale slop when inverting arrival rates
# (``ConstantRateArrival.tuples_available``), time-scale slop when bisecting
# arrival instants (``TraceArrival``), and decision-instant comparisons in the
# runtime loop.  A tuple that arrives exactly at instant t must count as
# available AT t: every comparison uses ``t + EPS`` / ``t - EPS`` in the
# direction that makes the boundary inclusive.
EPS = 1e-9

WINDOW_ID_SEP = "#w"  # per-window query ids: "<base_id>#w<index>"


class InfeasibleDeadline(Exception):
    """Raised when no batch schedule can meet the query deadline (§3.1)."""


class Strategy(enum.Enum):
    """Multi-query dispatch strategies (§4.2)."""

    LLF = "llf"
    EDF = "edf"
    SJF = "sjf"
    RR = "rr"


@dataclasses.dataclass(frozen=True)
class Batch:
    """One scheduled batch: process ``num_tuples`` starting at ``sched_time``."""

    sched_time: float
    num_tuples: int

    def __post_init__(self) -> None:
        if self.num_tuples < 0:
            raise ValueError(f"negative batch size {self.num_tuples}")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Output of the single-query planners: Algorithm 1's (schPoints, schTuples)."""

    batches: Tuple[Batch, ...]

    @property
    def sch_points(self) -> List[float]:
        return [b.sched_time for b in self.batches]

    @property
    def sch_tuples(self) -> List[int]:
        return [b.num_tuples for b in self.batches]

    @property
    def num_batches(self) -> int:
        return len(self.batches)

    @property
    def total_tuples(self) -> int:
        return sum(b.num_tuples for b in self.batches)


@dataclasses.dataclass
class Query:
    """A deadline-bound intermittent query (Table 1).

    ``cost_model`` maps tuples->processing cost for one batch;
    ``arrival`` models the input stream rate (InputTime / tuples_available).

    ``stream``/``stream_offset`` place the query's window on a SHARED input
    stream: tuple ``i`` of this query is tuple ``stream_offset + i`` of
    stream ``stream``.  They are pure metadata until pane sharing is enabled
    (``repro.core.panes``): queries naming the same stream can then share
    pane partial aggregates across overlapping windows.  ``stream=None``
    (the default) means "private stream" — never shared.

    ``tier``/``shed`` are the overload-control knobs (``repro.core.overload``):
    ``tier`` is a STRICT priority tier (0 = highest) that the dynamic
    policies ALWAYS honor — they never run a ready tier-k query while a
    ready query with a smaller tier number exists; with every query on the
    default tier 0 (all ties) the ordering is byte-identical to the
    tierless runtime.  ``shed`` says whether this query's answer may be
    degraded to a uniformly sampled, scaled estimate under overload
    (``shed=False`` routes infeasible admissions to deadline renegotiation
    instead); it is inert until a session enables overload control AND the
    workload is actually infeasible.

    ``latency_target`` is a Cameo-style per-query RESPONSE latency target:
    the submitter wants the answer within ``latency_target`` time units of
    window close, possibly much tighter than the hard ``deadline``.  It is
    advisory, not a feasibility bound — dynamic policies order by the
    EFFECTIVE target instant ``target_time = min(deadline, wind_end +
    latency_target)`` within a tier (so a tight-target query wins ties
    against an equal-deadline one), and ``QueryOutcome`` reports whether
    the target was met.  With the default ``None`` the target instant IS
    the deadline and every ordering — and trace — is byte-identical to the
    targetless runtime.

    ``tenant`` names the principal the query belongs to
    (``repro.core.tenancy``): sessions configured with a ``TenancyConfig``
    arbitrate capacity ACROSS tenants with weighted max-min fairness and
    per-tenant quotas, sitting *above* the strict tiers — fairness decides
    how much capacity each tenant gets, tiers order queries within the
    tenant's share.  ``tenant=None`` (the default) keeps the query in the
    single-principal world of the paper: no tenancy machinery runs and
    every trace is byte-identical to the tenantless runtime.

    ``upstream`` declares a CASCADE dependency for session windows: the
    base id of another recurring spec in the same session whose windows
    produce this query's input (bronze→silver→gold rollups).  A session
    defers instantiating a window of this query until every upstream
    window covering its span has closed, and — when both name the same
    ``stream`` with pane sharing enabled — pre-subscribes the window's
    panes so the upstream windows' partials survive in the PaneStore for
    reuse.  Pure metadata outside sessions.
    """

    query_id: str
    wind_start: float
    wind_end: float
    deadline: float
    num_tuples_total: int
    cost_model: "CostModelBase"  # noqa: F821  (cost_model.py)
    arrival: "ArrivalModel"  # noqa: F821  (arrivals.py)
    # Optional distinct final-aggregation model; defaults to cost_model.agg_cost.
    submit_time: Optional[float] = None  # when the query enters the system (§4)
    stream: Optional[str] = None  # shared-stream name (pane sharing)
    stream_offset: int = 0  # window start as a global stream tuple index
    tier: int = 0  # strict priority tier (overload control; 0 = highest)
    shed: bool = True  # may this answer degrade to a sampled estimate?
    latency_target: Optional[float] = None  # desired answer latency past wind_end
    tenant: Optional[str] = None  # owning principal (multi-tenant arbitration)
    upstream: Optional[str] = None  # cascade: base id of the producing spec

    def __post_init__(self) -> None:
        if self.wind_end < self.wind_start:
            raise ValueError("wind_end < wind_start")
        if self.tier < 0:
            raise ValueError(f"tier must be >= 0, got {self.tier}")
        if self.latency_target is not None and self.latency_target < 0:
            raise ValueError(
                f"latency_target must be >= 0, got {self.latency_target}")
        if self.submit_time is None:
            self.submit_time = self.wind_start

    @property
    def min_comp_cost(self) -> float:
        """minCompCost: cost of processing all tuples in a single batch (Table 1)."""
        return self.cost_model.cost(self.num_tuples_total)

    @property
    def slack_time(self) -> float:
        """Eq. (2): slackTime = deadline - windEndTime - minCompCost."""
        return self.deadline - self.wind_end - self.min_comp_cost

    @property
    def target_time(self) -> float:
        """The instant the answer is WANTED by: ``wind_end +
        latency_target``, never later than the hard deadline; the deadline
        itself when no latency target is set."""
        if self.latency_target is None:
            return self.deadline
        return min(self.deadline, self.wind_end + self.latency_target)


@dataclasses.dataclass(frozen=True)
class Plan:
    """Output of ``SchedulingPolicy.plan``: one static Schedule per query.

    For static policies this is the Algorithm-1/constraint plan verbatim; for
    dynamic policies it is the REALIZED batch sequence of a simulated run
    (dynamic scheduling decides at runtime — the Plan is its deterministic
    projection under the predicted arrival model).
    """

    schedules: Dict[str, Schedule]
    policy: str = ""

    def __getitem__(self, query_id: str) -> Schedule:
        return self.schedules[query_id]

    def __contains__(self, query_id: str) -> bool:
        return query_id in self.schedules

    @property
    def query_ids(self) -> List[str]:
        return list(self.schedules)

    @property
    def num_batches(self) -> int:
        return sum(s.num_batches for s in self.schedules.values())


@dataclasses.dataclass(frozen=True)
class PaneSpec:
    """One pane of a shared stream (pane/slice sharing for overlapping
    windows, after Li et al.'s panes and Cutty/Scotty slices).

    Streams are decomposed into fixed-width contiguous panes of
    ``num_tuples`` tuples; pane ``index`` covers global stream tuples
    ``[offset, offset + num_tuples)``.  When the pane width is the GCD of
    every subscribed query's window range and slide (in tuples), each
    query's window is an exact union of panes, so one pane partial
    aggregate — computed ONCE — serves every overlapping window at merge
    cost instead of scan cost (``repro.core.panes``).
    """

    stream: str
    index: int
    offset: int
    num_tuples: int

    def __post_init__(self) -> None:
        if self.num_tuples <= 0:
            raise ValueError(f"pane width must be positive, got {self.num_tuples}")
        if self.index < 0 or self.offset < 0:
            raise ValueError("pane index/offset must be non-negative")

    @property
    def end(self) -> int:
        """Global stream tuple index one past the pane's last tuple."""
        return self.offset + self.num_tuples

    @property
    def key(self) -> Tuple[str, int]:
        """Store key: (stream, pane index)."""
        return (self.stream, self.index)


@dataclasses.dataclass(frozen=True)
class BatchShard:
    """One shard of a logical batch, bound for one pool worker.

    ``worker`` names the target worker; ``None`` means "next earliest-free
    worker not yet claimed by an earlier shard of the same decision".
    """

    num_tuples: int
    worker: Optional[str] = None

    def __post_init__(self) -> None:
        if self.num_tuples <= 0:
            raise ValueError(f"shard size must be positive, got {self.num_tuples}")


@dataclasses.dataclass(frozen=True)
class PolicyDecision:
    """One dispatch decision of a dynamic policy (Algorithm 2's winner).

    Exactly one of the three forms:

    * run   — ``query_id`` set: run ``num_tuples`` of that query now;
    * wait  — ``wake_at`` set: nothing ready, idle until that instant;
    * stop  — neither set: no admissible work will ever become ready.

    Pool extensions (ignored by single-executor runs):

    * ``worker`` — dispatch the batch to this named ``ExecutorPool`` worker
      instead of the earliest-free one;
    * ``shards`` — split the logical batch into per-worker shards (sizes must
      sum to ``num_tuples``); each shard lands on its own worker and becomes
      its own offset-keyed partial, combined in ``finalize`` exactly like
      segagg partials.  Use ``repro.dist.sharding.batch_shard_extents`` to
      derive balanced shard sizes.
    """

    query_id: Optional[str] = None
    num_tuples: int = 0
    wake_at: Optional[float] = None
    worker: Optional[str] = None
    shards: Optional[Tuple[BatchShard, ...]] = None

    def __post_init__(self) -> None:
        if self.shards is not None:
            if self.worker is not None:
                raise ValueError("worker= and shards= are mutually exclusive")
            total = sum(s.num_tuples for s in self.shards)
            if total != self.num_tuples:
                raise ValueError(
                    f"shards sum to {total}, decision num_tuples is "
                    f"{self.num_tuples}"
                )

    @property
    def is_run(self) -> bool:
        return self.query_id is not None

    @property
    def is_wait(self) -> bool:
        return self.query_id is None and self.wake_at is not None

    @property
    def is_stop(self) -> bool:
        return self.query_id is None and self.wake_at is None


@dataclasses.dataclass(frozen=True)
class BatchExecution:
    """One executed batch in a trace (simulator / real executor).

    ``worker`` is the pool worker that ran the batch ("" outside a pool).
    It is excluded from equality: worker placement is an execution detail,
    so single-executor traces and W=1 pool traces compare identical.
    """

    query_id: str
    start: float
    end: float
    num_tuples: int
    kind: str = "batch"  # "batch" | "final_agg"
    worker: str = dataclasses.field(default="", compare=False)


@dataclasses.dataclass
class QueryOutcome:
    """Per-query result row.

    ``tuples_processed`` vs ``num_tuples_total`` records delivery: a truth
    arrival stream that under-delivers against the planned total leaves a
    shortfall, which used to be silently recorded as a normal completion.
    ``num_tuples_total < 0`` means "not recorded" (hand-built outcomes in the
    comparison harness); such outcomes report ``complete == True``.

    ``shed_fraction``/``error_bound`` record DELIBERATE degradation under
    overload control (``repro.core.overload``): the fraction of the window's
    tuples dropped by load shedding, and the reported relative error bound
    of the resulting scaled-sample aggregate estimate.  Both stay 0.0 — and
    the answer exact — whenever overload control never shed this query.
    Shed tuples are not a shortfall: the query completed, by design, on a
    uniform sample.

    ``latency_target``/``target_time`` mirror the query's Cameo-style
    response-latency target (``Query.latency_target``): ``target_time`` is
    the absolute instant the answer was wanted by and ``met_target`` the
    verdict against it.  Both stay ``None`` — and ``met_target`` reports
    the plain deadline verdict — for queries without a target.

    ``tenant`` carries the owning principal through to the trace so
    per-tenant SLO rollups (``repro.core.tenancy.tenant_summary``) need no
    side table; ``None`` for single-principal queries.
    """

    query_id: str
    completion_time: float
    deadline: float
    total_cost: float
    num_batches: int
    tuples_processed: int = -1
    num_tuples_total: int = -1
    shed_fraction: float = 0.0
    error_bound: float = 0.0
    latency_target: Optional[float] = None
    target_time: Optional[float] = None
    tenant: Optional[str] = None

    @property
    def met_deadline(self) -> bool:
        # Allow tiny float slop from accumulated arithmetic.
        return self.completion_time <= self.deadline + EPS

    @property
    def met_target(self) -> bool:
        """Completion against the latency-target instant (the deadline
        verdict when the query carried no target)."""
        if self.target_time is None:
            return self.met_deadline
        return self.completion_time <= self.target_time + EPS

    @property
    def shortfall(self) -> int:
        """Planned tuples that never arrived/processed (0 when complete)."""
        if self.num_tuples_total < 0 or self.tuples_processed < 0:
            return 0
        return max(self.num_tuples_total - self.tuples_processed, 0)

    @property
    def complete(self) -> bool:
        return self.shortfall == 0


@dataclasses.dataclass
class ExecutionTrace:
    executions: List[BatchExecution] = dataclasses.field(default_factory=list)
    outcomes: List[QueryOutcome] = dataclasses.field(default_factory=list)
    # query_ids of batches whose REAL execution exceeded C_max (straggler
    # re-queue events recorded by the shared runtime loop; empty in pure
    # simulation, where modelled batch costs respect C_max by construction).
    stragglers: List[str] = dataclasses.field(default_factory=list)
    # Pane-sharing bookkeeping (repro.core.panes.SharedBook) when the run
    # had sharing enabled; None otherwise.  Excluded from equality so shared
    # and unshared traces compare on the executions/outcomes alone.
    pane_book: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False
    )

    @property
    def total_cost(self) -> float:
        return sum(e.end - e.start for e in self.executions)

    def outcome(self, query_id: str) -> QueryOutcome:
        for o in self.outcomes:
            if o.query_id == query_id:
                return o
        raise KeyError(query_id)

    @property
    def all_met(self) -> bool:
        return all(o.met_deadline for o in self.outcomes)


class QueryTable:
    """Struct-of-arrays snapshot of per-query scheduling quantities.

    Packs the fields the dynamic policies' priority math reads — tier,
    rr ticket, deadline, effective target instant, MinBatch, progress and
    the linear cost-model coefficients — into parallel numpy arrays so
    laxity / target-laxity / remaining-cost evaluate vectorized over the
    whole ready set at once (Eq. 9/10 math, one ufunc chain instead of
    n Python attribute walks).

    Packing is only defined for rows whose cost model is a plain
    ``LinearCostModel`` with a known total (``spec.total_known``);
    ``pack`` returns None otherwise and callers fall back to the
    per-query Python path.  Every arithmetic step mirrors
    ``QueryRuntime.remaining_cost``/``laxity``/``target_laxity``
    operation-for-operation, so the packed floats are bit-identical to the
    scalar ones — the property the heap/scan trace-parity gate rests on.
    """

    __slots__ = (
        "n", "tier", "rr_seq", "deadline", "target_time", "min_batch",
        "processed", "batches_done", "total", "tuple_cost", "overhead",
        "agg_per_batch", "agg_overhead",
    )

    @classmethod
    def pack(cls, runtimes: Sequence[object]) -> Optional["QueryTable"]:
        """SoA over ``runtimes`` (``QueryRuntime`` rows), or None when any
        row is ineligible for the vectorized path."""
        from .cost_model import LinearCostModel

        import numpy as np

        # ONE attribute walk per row (``rt.q`` is a property — touching it
        # 12 times per row dominated the packing cost), eligibility checked
        # in the same pass.  All values are exact in float64 (counts are
        # far below 2**53), so one 2-D conversion + int casts of the count
        # columns reproduces the per-field arrays bit for bit.
        rows = []
        for rt in runtimes:
            q = rt.q
            cm = q.cost_model
            if type(cm) is not LinearCostModel or not rt.spec.total_known:
                return None
            rows.append((
                q.tier, rt.rr_seq, q.deadline, q.target_time, rt.min_batch,
                rt.processed, rt.batches_done, q.num_tuples_total,
                cm.tuple_cost, cm.overhead, cm.agg_per_batch, cm.agg_overhead,
            ))
        t = cls()
        t.n = len(rows)
        arr = np.array(rows, dtype=np.float64).reshape(t.n, 12)
        t.tier = arr[:, 0].astype(np.int64)
        t.rr_seq = arr[:, 1].astype(np.int64)
        t.deadline = arr[:, 2]
        t.target_time = arr[:, 3]
        t.min_batch = arr[:, 4].astype(np.int64)
        t.processed = arr[:, 5].astype(np.int64)
        t.batches_done = arr[:, 6].astype(np.int64)
        t.total = arr[:, 7].astype(np.int64)
        t.tuple_cost = arr[:, 8]
        t.overhead = arr[:, 9]
        t.agg_per_batch = arr[:, 10]
        t.agg_overhead = arr[:, 11]
        return t

    def remaining_cost(self, now: float):
        """Vector twin of ``QueryRuntime.remaining_cost`` (FindMinCompCost):
        pending tuples in MinBatch chunks + final aggregation."""
        import numpy as np

        pend = np.maximum(self.total - self.processed, 0)
        mb = np.maximum(self.min_batch, 1)
        full = pend // mb
        rem = pend - full * mb
        # LinearCostModel.cost, with its n<=0 branches, evaluated elementwise
        cost_mb = np.where(
            self.min_batch > 0,
            self.min_batch * self.tuple_cost + self.overhead,
            np.where(self.min_batch == 0, self.overhead, 0.0),
        )
        c = full * cost_mb + np.where(
            rem > 0, rem * self.tuple_cost + self.overhead, 0.0)
        total_batches = self.batches_done + full + (rem > 0)
        agg = np.where(
            total_batches > 1,
            total_batches * self.agg_per_batch + self.agg_overhead, 0.0)
        return np.where(pend == 0, 0.0, c + agg)

    def laxity(self, now: float):
        """Eq. (10): deadline - now - remaining cost (vectorized)."""
        return self.deadline - now - self.remaining_cost(now)

    def target_laxity(self, now: float):
        """Laxity against the effective target instant (``target_time``)."""
        return self.laxity(now) - (self.deadline - self.target_time)


# ---------------------------------------------------------------------------
# Continuous sessions: recurring windows (the paper's Custom Query Scheduler
# runs continuously; each registered query's window RECURS with some period)
# ---------------------------------------------------------------------------


def window_query_id(base_id: str, window: int) -> str:
    """Id of window ``window`` of recurring query ``base_id``."""
    return f"{base_id}{WINDOW_ID_SEP}{window}"


def split_window_id(query_id: str) -> Tuple[str, Optional[int]]:
    """Inverse of ``window_query_id``; (query_id, None) for one-shot ids."""
    base, sep, tail = query_id.rpartition(WINDOW_ID_SEP)
    if sep and tail.isdigit():
        return base, int(tail)
    return query_id, None


@dataclasses.dataclass
class RecurringQuerySpec:
    """A recurring intermittent query: ``base``'s window repeated every
    ``period`` time units.

    ``base`` is window 0 verbatim (its window, arrival shape, cost model and
    deadline).  Window ``w`` covers ``[wind_start + w*period, wind_end +
    w*period)`` with the base arrival model time-shifted by ``w*period`` and
    deadline ``wind_end(w) + deadline_offset`` (defaulting to the base
    query's own deadline-to-window-end gap).  ``num_windows=None`` recurs
    open-endedly — the session instantiates windows lazily, so open-ended
    specs require a run horizon (``Session.run_until``).

    ``truth_factory(w)`` supplies the ACTUAL arrival process of window ``w``
    (already shifted to the window's absolute time frame); default: predicted
    == true.  ``true_cost_model`` injects cost drift in simulation: the
    executor charges it for this query's batches while planners keep seeing
    the (possibly calibrating) ``base.cost_model``.  ``delete_time`` /
    ``total_known`` carry the ``DynamicQuerySpec`` semantics through to every
    instantiated window (a scheduled deletion at an absolute instant; §4.4's
    unknown-total estimation).

    ``slide_tuples`` is the recurrence expressed in STREAM tuples: window
    ``w`` starts ``w * slide_tuples`` tuples after the base window on the
    shared stream named by ``base.stream`` (defaults to
    ``base.num_tuples_total``, i.e. tumbling windows).  A slide smaller than
    the window range makes consecutive windows overlap, which is exactly
    what pane sharing (``repro.core.panes``) exploits: pane partials
    computed for window ``w`` carry over to window ``w+1``.

    ``tenant`` is a convenience mirror of ``base.tenant`` (multi-tenant
    arbitration, ``repro.core.tenancy``): setting either stamps both, so
    every instantiated window carries the owning principal.  Conflicting
    non-None values raise.
    """

    base: Query
    period: float
    num_windows: Optional[int] = None
    deadline_offset: Optional[float] = None
    truth_factory: Optional[Callable[[int], "ArrivalModel"]] = None  # noqa: F821
    true_cost_model: Optional["CostModelBase"] = None  # noqa: F821
    num_groups: int = 0
    delete_time: Optional[float] = None
    total_known: bool = True
    slide_tuples: Optional[int] = None
    tenant: Optional[str] = None

    def __post_init__(self) -> None:
        if self.tenant is None:
            self.tenant = self.base.tenant
        elif self.base.tenant is None:
            self.base = dataclasses.replace(self.base, tenant=self.tenant)
        elif self.base.tenant != self.tenant:
            raise ValueError(
                f"{self.base.query_id}: spec tenant {self.tenant!r} conflicts "
                f"with base query tenant {self.base.tenant!r}")
        if self.period <= 0:
            raise ValueError(f"period must be positive, got {self.period}")
        if self.num_windows is not None and self.num_windows < 1:
            raise ValueError(f"num_windows must be >= 1, got {self.num_windows}")
        if self.deadline_offset is None:
            self.deadline_offset = self.base.deadline - self.base.wind_end
        if self.deadline_offset < 0:
            raise ValueError("deadline_offset must be >= 0 (deadline before "
                             "window end is never schedulable)")
        if self.slide_tuples is None:
            self.slide_tuples = self.base.num_tuples_total
        if self.slide_tuples < 0:
            raise ValueError("slide_tuples must be >= 0")

    @property
    def base_id(self) -> str:
        return self.base.query_id

    def window_start(self, window: int) -> float:
        return self.base.wind_start + window * self.period

    def window_query(self, window: int,
                     cost_model: Optional["CostModelBase"] = None) -> Query:  # noqa: F821
        """Instantiate window ``window`` as a one-shot Query (shifted arrival,
        per-window deadline, optional cost-model override)."""
        from .arrivals import ShiftedArrival  # lazy: arrivals is a sibling

        if self.num_windows is not None and window >= self.num_windows:
            raise IndexError(
                f"{self.base_id}: window {window} >= num_windows {self.num_windows}"
            )
        shift = window * self.period
        arr = self.base.arrival if shift == 0 else ShiftedArrival(
            base=self.base.arrival, shift=shift)
        # A single-window spec IS its base query: keep the base id, so a
        # session over one-shot submissions is trace-identical to the
        # one-shot runtime.  Recurring specs suffix every window.
        qid = (self.base_id if self.num_windows == 1
               else window_query_id(self.base_id, window))
        submit = (None if self.base.submit_time is None
                  else self.base.submit_time + shift)
        return Query(
            query_id=qid,
            wind_start=self.base.wind_start + shift,
            wind_end=self.base.wind_end + shift,
            deadline=self.base.wind_end + shift + self.deadline_offset,
            num_tuples_total=self.base.num_tuples_total,
            cost_model=self.base.cost_model if cost_model is None else cost_model,
            arrival=arr,
            submit_time=submit,
            stream=self.base.stream,
            stream_offset=self.base.stream_offset + window * self.slide_tuples,
            tier=self.base.tier,
            shed=self.base.shed,
            latency_target=self.base.latency_target,
            tenant=self.base.tenant,
            upstream=self.base.upstream,
        )

    def window_truth(self, window: int) -> Optional["ArrivalModel"]:  # noqa: F821
        return None if self.truth_factory is None else self.truth_factory(window)


@dataclasses.dataclass(frozen=True)
class SessionEvent:
    """One lifecycle event of a long-running session (admissions, window
    roll-overs, recalibrations) — the session-level analogue of a
    ``BatchExecution`` row."""

    kind: str   # "submit" | "reject" | "withdraw" | "window_open" |
    #             "window_close" | "recalibrate" | "shed" | "renegotiate" |
    #             "pane_incompatible" | "window_infeasible" |
    #             "forecast_shed" | "forecast_refund" | "pane_prewarm" |
    #             "quota" | "cascade_defer"
    time: float
    query_id: str = ""
    detail: str = ""


@dataclasses.dataclass
class SessionTrace(ExecutionTrace):
    """ExecutionTrace plus the session's own event log.  Per-window outcomes
    of one recurring query form a series (``outcome_series``)."""

    events: List[SessionEvent] = dataclasses.field(default_factory=list)

    def log(self, kind: str, time: float, query_id: str = "",
            detail: str = "") -> None:
        self.events.append(SessionEvent(kind, time, query_id, detail))

    def outcome_series(self, base_id: str) -> List[QueryOutcome]:
        """Outcomes of every window of ``base_id``, in window order."""
        rows = []
        for o in self.outcomes:
            base, w = split_window_id(o.query_id)
            if base == base_id:
                rows.append((0 if w is None else w, o))
        return [o for _, o in sorted(rows, key=lambda p: p[0])]

    def events_for(self, kind: str) -> List[SessionEvent]:
        return [e for e in self.events if e.kind == kind]
