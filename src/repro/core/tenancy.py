"""Multi-tenant arbitration: per-tenant quotas, weighted max-min fairness
and Zipf-skewed traffic (the production regime the paper abstracts away).

The paper's multi-query scheduler assumes every query belongs to one
principal; a shared serving deployment has thousands of tenants on one
stream, where one tenant's burst must not shed another tenant's workload.
This module supplies the cross-tenant layer, sitting ABOVE the strict
priority tiers of ``repro.core.overload``:

* fairness decides how much executor capacity each tenant is entitled to
  (``fair_shares``: weighted max-min / water-filling over per-tenant
  demand, bounded by each tenant's ``TenantQuota``);
* tiers keep ordering queries WITHIN a tenant's share exactly as before
  (dispatch selection is untouched — arbitration acts only through the
  shedding planner and the admission gate, which is what keeps
  ``tenant=None`` traces byte-identical to the single-principal runtime).

``tenant_quota_condition`` is the admission-side check: a NECESSARY
per-tenant condition in the style of ``work_demand_condition``, evaluated
against each tenant's quota-scaled capacity slice.  ``plan_shedding``
(``repro.core.overload``) consumes the same config to shed an over-quota
tenant against its OWN share before touching anyone else's queries.

Nothing here imports the overload or session machinery — pure math over
``Query`` rows, so it is usable from planners, ledgers and benchmarks
alike.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence

from .schedulability import FeasibilityReport, edf_order
from .types import EPS, Query, QueryOutcome

__all__ = [
    "TenantQuota",
    "TenancyConfig",
    "fair_shares",
    "demand_by_tenant",
    "tenant_quota_condition",
    "zipf_shares",
    "zipf_counts",
    "zipf_traffic",
    "tenant_summary",
]


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """One tenant's entitlement.

    ``weight`` is the tenant's weight in max-min fair capacity division
    (relative to every other tenant's weight; the config default applies
    to tenants without an explicit quota).  ``capacity`` caps the
    tenant's share as a FRACTION of one executor's capacity (0.25 = "at
    most a quarter of the machine over any deadline horizon"); ``rate``
    caps the tenant's aggregate offered tuple rate.  ``None`` leaves a
    dimension uncapped.
    """

    weight: float = 1.0
    capacity: Optional[float] = None
    rate: Optional[float] = None

    def __post_init__(self) -> None:
        if self.weight < 0:
            raise ValueError(f"weight must be >= 0, got {self.weight}")
        if self.capacity is not None and self.capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {self.capacity}")
        if self.rate is not None and self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")


@dataclasses.dataclass
class TenancyConfig:
    """Session-level tenancy knob: per-tenant quotas + the default weight
    for tenants submitting without one.  Mutable on purpose — sessions
    renegotiate quotas at runtime (``Session.set_quota``)."""

    quotas: Dict[str, TenantQuota] = dataclasses.field(default_factory=dict)
    default_weight: float = 1.0

    def quota(self, tenant: Optional[str]) -> Optional[TenantQuota]:
        return None if tenant is None else self.quotas.get(tenant)

    def weight(self, tenant: Optional[str]) -> float:
        q = self.quota(tenant)
        return self.default_weight if q is None else q.weight


def demand_by_tenant(queries: Sequence[Query]) -> Dict[Optional[str], float]:
    """Total minimum work (``min_comp_cost``) keyed by tenant, in first-
    appearance order (deterministic for the fairness math downstream)."""
    demand: Dict[Optional[str], float] = {}
    for q in queries:
        demand[q.tenant] = demand.get(q.tenant, 0.0) + q.min_comp_cost
    return demand


def fair_shares(
    demand: Dict[Optional[str], float],
    weights: Optional[Dict[Optional[str], float]] = None,
    capacity: float = 0.0,
) -> Dict[Optional[str], float]:
    """Weighted max-min fair division (progressive filling / water-filling).

    Divide ``capacity`` across tenants in proportion to ``weights``
    (uniform when ``None``); a tenant never receives more than its
    ``demand``, and capacity a saturated tenant leaves on the table is
    re-divided among the still-unsatisfied ones by the same weights.
    Deterministic: saturation resolves in rounds, no ordering choices.
    """
    share = {t: 0.0 for t in demand}
    if capacity <= 0:
        return share

    def w(t) -> float:
        return 1.0 if weights is None else weights.get(t, 0.0)

    active = {t for t, d in demand.items() if d > EPS and w(t) > 0}
    remaining = {t: demand[t] for t in active}
    cap = capacity
    while active and cap > EPS:
        wsum = sum(w(t) for t in active)
        if wsum <= 0:
            break
        alloc = {t: cap * w(t) / wsum for t in active}
        saturated = [t for t in active if alloc[t] >= remaining[t] - 1e-12]
        if not saturated:
            for t in active:
                share[t] += alloc[t]
            break
        for t in saturated:
            share[t] += remaining[t]
            cap -= remaining[t]
            active.discard(t)
            del remaining[t]
    return share


def tenant_quota_condition(
    queries: Sequence[Query],
    config: TenancyConfig,
    now: Optional[float] = None,
) -> FeasibilityReport:
    """Per-tenant quota check: NECESSARY conditions against each tenant's
    quota-scaled slice of the executor.

    For every tenant with a ``capacity`` quota, walk that tenant's rows in
    stable EDF order (the shared ``edf_order`` helper, exactly like
    ``work_demand_condition``): each deadline-prefix's total minimum work
    must fit inside ``capacity`` × the prefix's time budget (deadline
    minus the earliest work-start instant, floored at ``now``).  For every
    tenant with a ``rate`` quota, the aggregate window-average tuple rate
    of its rows must not exceed the quota.

    Tenantless rows (``tenant=None``) and tenants without a quota are
    never flagged — the check degenerates to always-feasible for
    single-principal workloads, which is what keeps ``tenant=None``
    sessions byte-identical to the pre-tenancy runtime.  Reasons are
    reported in sorted-tenant order and are deterministic given the row
    order, so the incremental ledger path (``DemandLedger.tenant_check``)
    reproduces them byte for byte.
    """
    by_tenant: Dict[str, List[Query]] = {}
    for q in queries:
        if q.tenant is not None:
            by_tenant.setdefault(q.tenant, []).append(q)
    reasons: List[str] = []
    for tenant in sorted(by_tenant):
        quota = config.quotas.get(tenant)
        if quota is None:
            continue
        rows = edf_order(by_tenant[tenant])
        if quota.rate is not None:
            offered = sum(
                q.num_tuples_total / max(q.wind_end - q.wind_start, EPS)
                for q in rows)
            if offered > quota.rate + 1e-9:
                reasons.append(
                    f"tenant {tenant}: offered rate {offered:.4g} exceeds "
                    f"rate quota {quota.rate:.4g}")
        if quota.capacity is not None:
            cumw = 0.0
            start = float("inf")
            for q in rows:
                cumw += q.min_comp_cost
                start = min(start, q.arrival.input_time(1))
                anchor = start if now is None else max(start, now)
                budget = (q.deadline - anchor) * quota.capacity
                if cumw > budget + 1e-9:
                    reasons.append(
                        f"tenant {tenant} deadline-prefix through "
                        f"{q.query_id}: work {cumw:.4g} exceeds capacity "
                        f"share {budget:.4g} (quota {quota.capacity:.4g} of "
                        f"budget {q.deadline - anchor:.4g})")
    return FeasibilityReport(feasible=not reasons, reasons=tuple(reasons))


# ---------------------------------------------------------------------------
# Zipf-skewed multi-tenant traffic
# ---------------------------------------------------------------------------


def zipf_shares(num_tenants: int, skew: float = 1.0) -> List[float]:
    """Normalized Zipf popularity: tenant k (1-based) gets weight
    ``1 / k**skew``.  ``skew=0`` is uniform."""
    if num_tenants < 1:
        raise ValueError(f"num_tenants must be >= 1, got {num_tenants}")
    raw = [1.0 / (k ** skew) for k in range(1, num_tenants + 1)]
    total = sum(raw)
    return [r / total for r in raw]


def zipf_counts(total: int, num_tenants: int, skew: float = 1.0,
                min_each: int = 0) -> List[int]:
    """Split ``total`` items across tenants by Zipf shares, deterministically
    (largest-remainder rounding; ties break toward the more popular
    tenant).  ``min_each`` floors every tenant's count first."""
    if total < num_tenants * min_each:
        raise ValueError(
            f"total {total} cannot give {num_tenants} tenants {min_each} each")
    shares = zipf_shares(num_tenants, skew)
    spare = total - num_tenants * min_each
    exact = [s * spare for s in shares]
    counts = [int(e) for e in exact]
    remainder = spare - sum(counts)
    order = sorted(range(num_tenants),
                   key=lambda i: (-(exact[i] - counts[i]), i))
    for i in order[:remainder]:
        counts[i] += 1
    return [c + min_each for c in counts]


def zipf_traffic(
    total_queries: int,
    tenants: Sequence[str],
    query_factory: Callable[[str, int, int], Query],
    skew: float = 1.0,
) -> List[Query]:
    """Zipf-skewed multi-tenant workload: ``total_queries`` queries divided
    across ``tenants`` by ``zipf_counts`` and built via
    ``query_factory(tenant, index_within_tenant, global_index)``.  The
    factory's ``tenant`` field is stamped if it left it unset.  Queries
    are emitted round-robin across tenants (heavy tenants keep emitting
    after light ones run dry) so a time-indexed consumer sees tenants
    interleaved, not blocked — deterministic, no RNG.
    """
    counts = zipf_counts(total_queries, len(tenants), skew)
    emitted = [0] * len(tenants)
    out: List[Query] = []
    g = 0
    while g < total_queries:
        for i, tenant in enumerate(tenants):
            if emitted[i] >= counts[i] or g >= total_queries:
                continue
            q = query_factory(tenant, emitted[i], g)
            if q.tenant is None:
                q = dataclasses.replace(q, tenant=tenant)
            elif q.tenant != tenant:
                raise ValueError(
                    f"query_factory stamped tenant {q.tenant!r}, "
                    f"expected {tenant!r}")
            out.append(q)
            emitted[i] += 1
            g += 1
    return out


def tenant_summary(
    outcomes: Iterable[QueryOutcome],
) -> Dict[Optional[str], Dict[str, float]]:
    """Per-tenant SLO rollup over trace outcomes: window count, deadline-
    met count/rate, exact-answer (never shed) count, and the worst
    reported error bound.  Keys are ``QueryOutcome.tenant`` values."""
    out: Dict[Optional[str], Dict[str, float]] = {}
    for o in outcomes:
        row = out.setdefault(o.tenant, {
            "windows": 0, "met": 0, "exact": 0, "max_error_bound": 0.0,
        })
        row["windows"] += 1
        row["met"] += 1 if o.met_deadline else 0
        row["exact"] += 1 if o.shed_fraction == 0.0 else 0
        row["max_error_bound"] = max(row["max_error_bound"], o.error_bound)
    for row in out.values():
        row["met_rate"] = row["met"] / row["windows"] if row["windows"] else 1.0
    return out
