"""Single-query scheduling under static scenarios (paper §3, Algorithm 1).

Plan construction (``schedule_single``) is separated from plan execution
(``execute_single`` — Algorithm 1's while-loop, which triggers each batch when
its tuple count is ready OR its scheduled time has passed, absorbing
input-rate mispredictions).

Backward construction (function ``ScheduleWithoutAggCost`` in the paper):

    last batch:   fills [windEnd, deadline'] — capacity there decides how many
                  tuples can wait for the end of the window.
    earlier ones: pending tuples get deadline = start of the batch scheduled
                  after them; input availability (InputTime) lower-bounds each
                  batch's start; recurse until all tuples are placed.

``ScheduleWithAggCost`` iterates the assumed batch count until the final-
aggregation allowance is consistent with the produced plan (Eq. (4)).

Works for ANY monotone cost model (closing remark of §3.1) — only
``cost``/``tuples_processable``/``agg_cost`` are used.
"""
from __future__ import annotations

from typing import List, Tuple

from .types import Batch, InfeasibleDeadline, Query, Schedule

_MAX_BATCHES = 10_000  # guard against degenerate cost models
_EPS = 1e-9


def schedule_without_agg_cost(query: Query, deadline: float) -> Schedule:
    """Backward-greedy optimal plan ignoring final-aggregation cost.

    Returns batches sorted by sched_time (earliest first).
    Raises InfeasibleDeadline if no plan exists under the cost/arrival models.
    """
    cm, arr = query.cost_model, query.arrival
    total = query.num_tuples_total
    if total == 0:
        return Schedule(batches=())

    # Uniform backward recursion.  The first iteration is the paper's "last
    # batch" (its availability bound input_time(N) IS the window end); later
    # iterations are the pre-window batches.  One deliberate repair over the
    # paper's §3.1 prose: every batch — including the last — starts AS LATE AS
    # POSSIBLE (time_pt - cost(k)), the same principle as the paper's Eq. (3)
    # for the single-batch case.  Anchoring the last batch at windowEnd, as
    # the prose states, discards the slack between windEnd + cost(k_last) and
    # the deadline; with per-batch overheads that slack can buy the
    # predecessor batch more room, and hypothesis found instances where the
    # as-stated greedy needs one batch more than the paper's own §3.2
    # constraint solver.  With late starts the two methods agree everywhere
    # we test (as the paper reports for its experiments).  The paper's worked
    # Cases 1-4 are unchanged: their last-batch capacity binds exactly.
    batches_rev: List[Batch] = []
    pending = total
    time_pt = deadline
    while pending > 0:
        if len(batches_rev) >= _MAX_BATCHES:
            raise InfeasibleDeadline(
                f"{query.query_id}: exceeded {_MAX_BATCHES} batches"
            )
        ip_avail = arr.input_time(pending)  # when the last pending tuple lands
        dur = time_pt - ip_avail
        n_proc = min(cm.tuples_processable(dur), pending)
        if n_proc <= 0:
            raise InfeasibleDeadline(
                f"{query.query_id}: cannot place {pending} tuples before "
                f"t={time_pt:.6g} (available only from t={ip_avail:.6g})"
            )
        # Run as late as possible: start = time_pt - cost(n_proc) >= ip_avail.
        start = time_pt - cm.cost(n_proc)
        batches_rev.append(Batch(sched_time=start, num_tuples=n_proc))
        pending -= n_proc
        time_pt = start

    return Schedule(batches=tuple(reversed(batches_rev)))


def schedule_with_agg_cost(query: Query) -> Schedule:
    """Fix the (#batches <-> agg-cost) circularity (paper function
    ScheduleWithAggCost, Eq. (4)).

    Assume ``i`` batches, shift the effective deadline earlier by
    ``agg_cost(i)``, plan, and repeat with a larger allowance while the plan
    needs more batches than assumed.
    """
    cm = query.cost_model
    i = 1
    while i <= _MAX_BATCHES:
        eff_deadline = query.deadline - cm.agg_cost(i)
        plan = schedule_without_agg_cost(query, eff_deadline)
        if plan.num_batches <= i:
            if plan.num_batches < i:
                # Tighten: fewer batches need less agg allowance; replanning
                # with the exact count can only extend the last-batch window.
                tight = schedule_without_agg_cost(
                    query, query.deadline - cm.agg_cost(plan.num_batches)
                )
                if tight.num_batches <= plan.num_batches:
                    return tight
            return plan
        i = max(i + 1, plan.num_batches)
    raise InfeasibleDeadline(f"{query.query_id}: agg-cost iteration diverged")


def schedule_single(query: Query) -> Schedule:
    """Algorithm 1's planning phase (ScheduleSingleMain, lines 1-8)."""
    if query.slack_time >= -_EPS:
        # Cases 1-2: one batch, started as late as completion-by-deadline allows.
        return Schedule(
            batches=(
                Batch(
                    sched_time=query.deadline - query.min_comp_cost,
                    num_tuples=query.num_tuples_total,
                ),
            )
        )
    return schedule_with_agg_cost(query)


def plan_cost(query: Query, plan: Schedule) -> float:
    """Total computation cost of a plan = batch costs + final agg (Eq. 1/4)."""
    cm = query.cost_model
    c = sum(cm.cost(b.num_tuples) for b in plan.batches)
    if plan.num_batches > 1:
        c += cm.agg_cost(plan.num_batches)
    return c


def validate_schedule(query: Query, plan: Schedule) -> None:
    """Assert the plan's invariants (used by tests and before execution):

    * covers all tuples exactly once,
    * batch k starts only after its tuples have arrived,
    * batches do not overlap in time,
    * last batch (+ final agg) completes by the deadline.
    """
    cm, arr = query.cost_model, query.arrival
    if plan.total_tuples != query.num_tuples_total:
        raise AssertionError(
            f"plan covers {plan.total_tuples} != {query.num_tuples_total}"
        )
    done = 0
    prev_end = float("-inf")
    for b in plan.batches:
        done += b.num_tuples
        avail = arr.input_time(done)
        if b.sched_time < avail - _EPS:
            raise AssertionError(
                f"batch at {b.sched_time} needs tuple #{done} available {avail}"
            )
        if b.sched_time < prev_end - _EPS:
            raise AssertionError("overlapping batches")
        prev_end = b.sched_time + cm.cost(b.num_tuples)
    finish = prev_end + (cm.agg_cost(plan.num_batches) if plan.num_batches > 1 else 0.0)
    if finish > query.deadline + 1e-6:
        raise AssertionError(f"finish {finish} > deadline {query.deadline}")


def execute_single(query: Query, plan: Schedule, truth: "ArrivalModel" = None):
    """Algorithm 1's execution loop against a (possibly divergent) true
    arrival process: trigger a batch when EITHER its planned tuple count is
    available OR its planned time point is reached (process what is there).

    Returns an ExecutionTrace.  ``truth`` defaults to the planning model.
    """
    from .types import BatchExecution, ExecutionTrace, QueryOutcome

    arr = truth if truth is not None else query.arrival
    cm = query.cost_model
    trace = ExecutionTrace()
    now = query.submit_time
    pending = query.num_tuples_total
    processed = 0
    ptr = 0
    required = plan.batches[0].num_tuples if plan.batches else 0
    n_batches = 0
    while pending > 0:
        avail = arr.tuples_available(now) - processed
        point = plan.batches[min(ptr, plan.num_batches - 1)].sched_time
        # Algorithm 1 trigger: enough tuples ready, OR the planned instant
        # passed (then "Process the Available Tuples" — whatever is there).
        if (avail >= required or now >= point - _EPS) and avail > 0:
            take = min(avail, pending)
            c = cm.cost(take)
            trace.executions.append(
                BatchExecution(query.query_id, now, now + c, take)
            )
            now += c
            processed += take
            pending -= take
            n_batches += 1
            required -= take
            if ptr < plan.num_batches - 1 and required <= 0:
                ptr += 1
                required += plan.batches[ptr].num_tuples
            required = max(required, 0)
        else:
            # Discrete-event jump: earliest instant at which the trigger can
            # fire — the `required`-th outstanding tuple arriving, or the
            # planned time point (if a tuple exists then), whichever first.
            want = processed + max(required, 1)
            next_arrival = (
                arr.input_time(want)
                if want <= arr.num_tuples_total
                else arr.input_time(arr.num_tuples_total)
            )
            nxt = min(next_arrival, max(point, arr.input_time(processed + 1)))
            if nxt <= now + _EPS:  # nothing will ever arrive: stream exhausted
                break
            now = nxt
    agg = cm.agg_cost(n_batches) if n_batches > 1 else 0.0
    if agg:
        trace.executions.append(
            BatchExecution(query.query_id, now, now + agg, 0, kind="final_agg")
        )
        now += agg
    trace.outcomes.append(
        QueryOutcome(
            query_id=query.query_id,
            completion_time=now,
            deadline=query.deadline,
            total_cost=trace.total_cost,
            num_batches=n_batches,
        )
    )
    return trace
