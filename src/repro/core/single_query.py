"""Legacy single-query entry points (paper §3, Algorithm 1).

The algorithms moved to ``repro.core.policies.single`` (registered as the
``single`` / ``single-no-agg`` / ``single-agg`` policies) and the execution
loop to ``repro.core.runtime.execute_plan``; the ``schedule_*`` /
``execute_single`` functions below are thin deprecation shims kept for the
pre-Planner API.  ``plan_cost`` and ``validate_schedule`` remain canonical
here (they are plan utilities, not scheduling schemes).

Migration:

    schedule_single(q)            -> Planner(policy="single").schedule(q)
    schedule_with_agg_cost(q)     -> Planner(policy="single-agg").schedule(q)
    schedule_without_agg_cost(q,d)-> Planner(policy="single-no-agg",
                                             deadline=d).schedule(q)
    execute_single(q, plan, truth)-> runtime.execute_plan(q, plan, truth=truth)
"""
from __future__ import annotations

from typing import Optional

from ._deprecation import warn_deprecated
from .policies.single import (  # canonical implementations
    plan_single,
    plan_with_agg_cost,
    plan_without_agg_cost,
)
from .types import ExecutionTrace, Query, Schedule

_EPS = 1e-9


def schedule_without_agg_cost(query: Query, deadline: float) -> Schedule:
    """Deprecated shim for the ``single-no-agg`` policy."""
    warn_deprecated(
        "schedule_without_agg_cost()", 'Planner(policy="single-no-agg")'
    )
    return plan_without_agg_cost(query, deadline)


def schedule_with_agg_cost(query: Query) -> Schedule:
    """Deprecated shim for the ``single-agg`` policy."""
    warn_deprecated("schedule_with_agg_cost()", 'Planner(policy="single-agg")')
    return plan_with_agg_cost(query)


def schedule_single(query: Query) -> Schedule:
    """Deprecated shim for the ``single`` policy (Algorithm 1)."""
    warn_deprecated("schedule_single()", 'Planner(policy="single")')
    return plan_single(query)


def plan_cost(query: Query, plan: Schedule) -> float:
    """Total computation cost of a plan = batch costs + final agg (Eq. 1/4)."""
    cm = query.cost_model
    c = sum(cm.cost(b.num_tuples) for b in plan.batches)
    if plan.num_batches > 1:
        c += cm.agg_cost(plan.num_batches)
    return c


def validate_schedule(query: Query, plan: Schedule) -> None:
    """Assert the plan's invariants (used by tests and before execution):

    * covers all tuples exactly once,
    * batch k starts only after its tuples have arrived,
    * batches do not overlap in time,
    * last batch (+ final agg) completes by the deadline.
    """
    cm, arr = query.cost_model, query.arrival
    if plan.total_tuples != query.num_tuples_total:
        raise AssertionError(
            f"plan covers {plan.total_tuples} != {query.num_tuples_total}"
        )
    done = 0
    prev_end = float("-inf")
    for b in plan.batches:
        done += b.num_tuples
        avail = arr.input_time(done)
        if b.sched_time < avail - _EPS:
            raise AssertionError(
                f"batch at {b.sched_time} needs tuple #{done} available {avail}"
            )
        if b.sched_time < prev_end - _EPS:
            raise AssertionError("overlapping batches")
        prev_end = b.sched_time + cm.cost(b.num_tuples)
    finish = prev_end + (cm.agg_cost(plan.num_batches) if plan.num_batches > 1 else 0.0)
    if finish > query.deadline + 1e-6:
        raise AssertionError(f"finish {finish} > deadline {query.deadline}")


def execute_single(
    query: Query, plan: Schedule, truth: Optional["ArrivalModel"] = None  # noqa: F821
) -> ExecutionTrace:
    """Deprecated shim for ``repro.core.runtime.execute_plan`` (Algorithm 1's
    execution loop, now shared by every executor)."""
    warn_deprecated("execute_single()", "repro.core.runtime.execute_plan()")
    from .runtime import execute_plan

    return execute_plan(query, plan, truth=truth)
