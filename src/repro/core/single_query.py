"""Legacy single-query entry points (paper §3, Algorithm 1).

The algorithms moved to ``repro.core.policies.single`` (registered as the
``single`` / ``single-no-agg`` / ``single-agg`` policies) and the execution
loop to ``repro.core.runtime.execute_plan``; the ``schedule_*`` /
``execute_single`` functions below are thin deprecation shims kept for the
pre-Planner API.  ``plan_cost`` and ``validate_schedule`` moved to their
canonical home ``repro.core.plans`` and are re-exported here unchanged.

Migration:

    schedule_single(q)            -> Planner(policy="single").schedule(q)
    schedule_with_agg_cost(q)     -> Planner(policy="single-agg").schedule(q)
    schedule_without_agg_cost(q,d)-> Planner(policy="single-no-agg",
                                             deadline=d).schedule(q)
    execute_single(q, plan, truth)-> runtime.execute_plan(q, plan, truth=truth)
"""
from __future__ import annotations

from typing import Optional

from ._deprecation import warn_deprecated
from .plans import plan_cost, validate_schedule  # noqa: F401  (re-export)
from .policies.single import (  # canonical implementations
    plan_single,
    plan_with_agg_cost,
    plan_without_agg_cost,
)
from .types import ExecutionTrace, Query, Schedule


def schedule_without_agg_cost(query: Query, deadline: float) -> Schedule:
    """Deprecated shim for the ``single-no-agg`` policy."""
    warn_deprecated(
        "schedule_without_agg_cost()", 'Planner(policy="single-no-agg")'
    )
    return plan_without_agg_cost(query, deadline)


def schedule_with_agg_cost(query: Query) -> Schedule:
    """Deprecated shim for the ``single-agg`` policy."""
    warn_deprecated("schedule_with_agg_cost()", 'Planner(policy="single-agg")')
    return plan_with_agg_cost(query)


def schedule_single(query: Query) -> Schedule:
    """Deprecated shim for the ``single`` policy (Algorithm 1)."""
    warn_deprecated("schedule_single()", 'Planner(policy="single")')
    return plan_single(query)


def execute_single(
    query: Query, plan: Schedule, truth: Optional["ArrivalModel"] = None  # noqa: F821
) -> ExecutionTrace:
    """Deprecated shim for ``repro.core.runtime.execute_plan`` (Algorithm 1's
    execution loop, now shared by every executor)."""
    warn_deprecated("execute_single()", "repro.core.runtime.execute_plan()")
    from .runtime import execute_plan

    return execute_plan(query, plan, truth=truth)
