"""Schedulability analysis for the dynamic NINP scheduler (paper §4.3, §7.4).

Exact schedulability of non-preemptive task sets is NP-complete (Georges et
al., paper ref [21]), so — like the paper — we provide *necessary* conditions
used as a pre-flight check and in experiments to explain infeasible cases
(the paper's §7.4 "sum of last-batch costs was ~105, so the largest deadline
must be >= windowEnd + 105" analysis is exactly `post_window_condition`).

Every check takes an optional ``now``: the instant the verdict is being made
(an online admission).  Work cannot be scheduled in the past, so prewindow
capacity before ``now`` — a "phantom prefix" that previously let mid-session
admissions credit processing time that had already elapsed — does not count,
and neither does post-window budget before ``now``.  ``now=None`` (the
default) is the offline pre-run case: the whole timeline is still ahead.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from .policies.single import plan_single, plan_without_agg_cost
from .types import EPS, InfeasibleDeadline, Query


@dataclasses.dataclass(frozen=True)
class FeasibilityReport:
    feasible: bool  # False == a NECESSARY condition failed (definitely infeasible)
    reasons: Tuple[str, ...]

    def __bool__(self) -> bool:
        return self.feasible


def edf_order(queries: Sequence[Query]) -> List[Query]:
    """Deadline-ascending (EDF) order — stable, so equal deadlines keep
    their submission order.  THE shared helper behind every deadline-prefix
    walk (``post_window_condition``, ``work_demand_condition``, the tiered
    overload variant and the incremental ``DemandLedger``), which used to
    be four private copies of the same ``sorted(..., key=deadline)``."""
    return sorted(queries, key=lambda q: q.deadline)


def max_prewindow_tuples(q: Query, now: Optional[float] = None) -> int:
    """Largest stream prefix a dedicated executor could finish strictly by
    q's window end (in-order batches, arrivals respected, nothing scheduled
    before ``now``).  Monotone in k, so binary-searchable via the backward
    planner on the k-tuple prefix."""
    import dataclasses as _dc

    floor = -math.inf if now is None else now

    def feasible(k: int) -> bool:
        if k == 0:
            return True
        # The k-prefix as its own query.  ``wind_end`` is inert to the
        # backward planner (it plans against the explicit deadline below)
        # but must satisfy the Query invariant wind_end >= wind_start even
        # for arrival models whose early instants precede the declared
        # window start (session remaining-work snapshots, ShiftedArrival
        # windows) — clamp instead of crashing the admission path.
        qk = _dc.replace(
            q,
            num_tuples_total=k,
            wind_end=max(q.arrival.input_time(k), q.wind_start),
            deadline=q.wind_end,
        )
        try:
            plan = plan_without_agg_cost(qk, q.wind_end)
        except InfeasibleDeadline:
            return False
        # No phantom prefix: the plan must be executable from ``now`` on.
        return not plan.batches or plan.batches[0].sched_time >= floor - EPS

    lo, hi = 0, q.num_tuples_total
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if feasible(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


def min_post_window_work(q: Query, now: Optional[float] = None) -> float:
    """Lower bound on the work that MUST run after q's window end: even if a
    dedicated executor maximally front-loads the stream prefix (from ``now``
    on), the remaining tuples still cost at least one batch after the window
    (final-aggregation cost excluded to keep the bound valid for
    single-batch completions)."""
    k = max_prewindow_tuples(q, now)
    rest = q.num_tuples_total - k
    return q.cost_model.cost(rest) if rest > 0 else 0.0


class DemandLedger:
    """Maintained per-deadline demand structure for INCREMENTAL admission.

    One row per live query, kept in EDF (deadline-ascending, stable) order
    in a sorted container; each row caches the quantities the deadline-
    prefix conditions read — minimum work (``min_comp_cost``), first-tuple
    arrival instant, window end, and (lazily) the minimum post-window work.
    ``add``/``discard``/``update`` apply single-row deltas — an O(n)
    memmove in the row lists but NO cost-model or planner calls for the
    untouched rows — instead of rebuilding the whole snapshot, and the
    checks evaluate every deadline prefix at once as numpy prefix sums.

    ``work_demand`` is byte-identical to ``work_demand_condition`` over the
    same rows (``np.cumsum`` accumulates left-to-right exactly like the
    scalar loop; the parity tests pin this).  ``post_window`` matches
    ``post_window_condition`` when the cached post-window work is fresh;
    rows cached at an earlier ``now`` UNDERSTATE the pinned work
    (``min_post_window_work`` is nondecreasing in ``now``), so a stale
    ledger errs on the admitting side — the direction the §4.3 gate is
    documented to err in anyway.

    ``extra`` rows (the incoming queries of an admission check) are merged
    into deadline position on the fly without mutating the ledger.
    """

    def __init__(self, queries: Sequence[Query] = ()):
        self._ids: List[str] = []
        self._queries: List[Query] = []
        self._deadlines: List[float] = []
        self._work: List[float] = []
        self._arrive: List[float] = []
        self._wind_end: List[float] = []
        self._post: List[Optional[float]] = []
        self._arrays = None  # cached numpy views of the base rows
        for q in edf_order(queries):
            self._insert(len(self._ids), q, None)

    def __len__(self) -> int:
        return len(self._ids)

    def __contains__(self, query_id: str) -> bool:
        return query_id in self._ids

    @property
    def queries(self) -> List[Query]:
        """Live rows in EDF order (e.g. for the tiered overload check)."""
        return list(self._queries)

    # -- delta maintenance ----------------------------------------------
    def _insert(self, i: int, q: Query,
                post_work: Optional[float]) -> None:
        self._ids.insert(i, q.query_id)
        self._queries.insert(i, q)
        self._deadlines.insert(i, q.deadline)
        self._work.insert(i, q.min_comp_cost)
        self._arrive.insert(i, q.arrival.input_time(1))
        self._wind_end.insert(i, q.wind_end)
        self._post.insert(i, post_work)
        self._arrays = None

    def add(self, q: Query, now: Optional[float] = None,
            post_work: Optional[float] = None) -> None:
        """Insert a row at its deadline position (equal deadlines keep
        insertion order, like the stable EDF sort).  ``post_work`` may be
        supplied to skip the planner call; None computes it lazily on the
        first ``post_window`` check."""
        i = bisect.bisect_right(self._deadlines, q.deadline)
        if post_work is None and now is not None:
            post_work = min_post_window_work(q, now)
        self._insert(i, q, post_work)

    def discard(self, query_id: str) -> bool:
        """Drop the row for ``query_id`` (False when absent)."""
        try:
            i = self._ids.index(query_id)
        except ValueError:
            return False
        for rows in (self._ids, self._queries, self._deadlines, self._work,
                     self._arrive, self._wind_end, self._post):
            del rows[i]
        self._arrays = None
        return True

    def update(self, q: Query, now: Optional[float] = None) -> None:
        """Replace the row for ``q.query_id`` (shed thinned the stream,
        renegotiation moved the deadline): discard + re-add."""
        self.discard(q.query_id)
        self.add(q, now=now)

    # -- the checks ------------------------------------------------------
    def _base_arrays(self):
        if self._arrays is None:
            import numpy as np

            self._arrays = (
                np.array(self._deadlines, dtype=np.float64),
                np.array(self._work, dtype=np.float64),
                np.array(self._arrive, dtype=np.float64),
                np.array(self._wind_end, dtype=np.float64),
            )
        return self._arrays

    def _merged(self, extra: Sequence[Query], now: Optional[float],
                with_post: bool):
        """Base rows + ``extra`` merged into deadline position.  Returns
        (ids, deadlines, work, arrive, wind_end, post_or_None)."""
        import numpy as np

        dl, work, arrive, wend = self._base_arrays()
        ids = self._ids
        post = None
        if with_post:
            for i, p in enumerate(self._post):
                if p is None:  # lazily computed, then cached
                    self._post[i] = min_post_window_work(self._queries[i], now)
            post = np.array(self._post, dtype=np.float64)
        if extra:
            # np.insert with sorted positions keeps the stable merge order:
            # an extra row lands AFTER every equal-deadline base row, the
            # same place the stable sort of [*base, *extra] puts it.
            pos: List[int] = []
            edl: List[float] = []
            ework: List[float] = []
            earr: List[float] = []
            ewend: List[float] = []
            epost: List[float] = []
            eids = list(ids)
            offset = 0
            for q in edf_order(extra):
                i = bisect.bisect_right(self._deadlines, q.deadline)
                pos.append(i)
                eids.insert(i + offset, q.query_id)
                edl.append(q.deadline)
                ework.append(q.min_comp_cost)
                earr.append(q.arrival.input_time(1))
                ewend.append(q.wind_end)
                if with_post:
                    epost.append(min_post_window_work(q, now))
                offset += 1
            dl = np.insert(dl, pos, edl)
            work = np.insert(work, pos, ework)
            arrive = np.insert(arrive, pos, earr)
            wend = np.insert(wend, pos, ewend)
            if with_post:
                post = np.insert(post, pos, epost)
            ids = eids
        return ids, dl, work, arrive, wend, post

    def work_demand(self, extra: Sequence[Query] = (),
                    now: Optional[float] = None) -> FeasibilityReport:
        """Processor-demand bound over the maintained rows (+ ``extra``):
        vectorized twin of ``work_demand_condition``."""
        import numpy as np

        ids, dl, work, arrive, _, _ = self._merged(extra, now, False)
        if not len(dl):
            return FeasibilityReport(feasible=True, reasons=())
        cumw = np.cumsum(work)
        start = np.minimum.accumulate(arrive)
        anchor = start if now is None else np.maximum(start, now)
        budget = dl - anchor
        reasons = tuple(
            f"deadline-prefix through {ids[i]}: total work "
            f"{float(cumw[i]):.4g} exceeds budget {float(budget[i]):.4g} "
            f"(deadline {float(dl[i]):.6g} - work start {float(anchor[i]):.6g})"
            for i in np.flatnonzero(cumw > budget + 1e-9)
        )
        return FeasibilityReport(feasible=not reasons, reasons=reasons)

    def post_window(self, extra: Sequence[Query] = (),
                    now: Optional[float] = None) -> FeasibilityReport:
        """§7.4 post-window bound over the maintained rows (+ ``extra``):
        vectorized twin of ``post_window_condition`` (exact when the cached
        post-window work is fresh; see the class docstring)."""
        import numpy as np

        ids, dl, _, _, wend, post = self._merged(extra, now, True)
        if not len(dl):
            return FeasibilityReport(feasible=True, reasons=())
        cumpost = np.cumsum(post)
        anchor = np.minimum.accumulate(wend)
        if now is not None:
            anchor = np.maximum(anchor, now)
        budget = dl - anchor
        reasons = tuple(
            f"deadline-prefix through {ids[i]}: post-window work "
            f"{float(cumpost[i]):.4g} exceeds budget {float(budget[i]):.4g} "
            f"(deadline {float(dl[i]):.6g} - work start {float(anchor[i]):.6g})"
            for i in np.flatnonzero(cumpost > budget + 1e-9)
        )
        return FeasibilityReport(feasible=not reasons, reasons=reasons)

    def check(self, extra: Sequence[Query] = (),
              now: Optional[float] = None) -> FeasibilityReport:
        """Both prefix conditions over the maintained rows (+ ``extra``)."""
        parts = [self.post_window(extra, now), self.work_demand(extra, now)]
        return FeasibilityReport(
            feasible=all(p.feasible for p in parts),
            reasons=tuple(r for p in parts for r in p.reasons),
        )

    # -- tenancy ---------------------------------------------------------
    def merged_queries(self, extra: Sequence[Query] = ()) -> List[Query]:
        """Live ``Query`` rows with ``extra`` merged into deadline position
        — the same stable merge as ``_merged`` (an extra row lands AFTER
        every equal-deadline base row), but materialized as query objects
        for checks that need more than the cached numeric columns."""
        if not extra:
            return list(self._queries)
        out = list(self._queries)
        deadlines = list(self._deadlines)
        for q in edf_order(extra):
            i = bisect.bisect_right(deadlines, q.deadline)
            out.insert(i, q)
            deadlines.insert(i, q.deadline)
        return out

    def tenant_check(self, extra: Sequence[Query] = (),
                     now: Optional[float] = None,
                     config: Optional["TenancyConfig"] = None,  # noqa: F821
                     ) -> FeasibilityReport:
        """Per-tenant quota conditions over the maintained rows
        (+ ``extra``): the incremental twin of calling
        ``repro.core.tenancy.tenant_quota_condition`` on the equivalent
        snapshot list.  Verdicts AND reason strings are byte-identical to
        the snapshot path over the same rows — the condition re-sorts each
        tenant's rows with the stable EDF helper, so the merge order above
        collapses to the stable sort of ``[*base, *extra]`` (the tenancy
        regression tests pin this).  ``config=None`` is trivially
        feasible (no quotas to violate)."""
        if config is None:
            return FeasibilityReport(feasible=True, reasons=())
        from .tenancy import tenant_quota_condition

        return tenant_quota_condition(self.merged_queries(extra), config, now)


def post_window_condition(
    queries: Sequence[Query], now: Optional[float] = None
) -> FeasibilityReport:
    """§7.4's necessary condition, generalised to EDF prefixes.

    Sort by deadline; for every deadline-prefix, the sum of minimum
    post-window work must fit between the EARLIEST window end in the prefix
    (before which none of that work can start — and never before ``now``)
    and the prefix's deadline.  A single shared executor cannot do better
    regardless of strategy, so failure proves infeasibility.  (The paper's
    §7.4 instance — identical windows, sum of last-batch costs 105 vs
    largest deadline — is the degenerate case of this check.)

    Evaluated as prefix sums over a one-shot ``DemandLedger`` built at
    ``now``: each query's ``min_post_window_work`` is computed ONCE (the
    per-prefix re-walk used to re-run the backward planner O(n^2) times)
    and accumulated left-to-right exactly like the old inner sum.
    """
    return DemandLedger(queries).post_window(now=now)


def work_demand_condition(
    queries: Sequence[Query], now: Optional[float] = None
) -> FeasibilityReport:
    """Processor-demand bound (classic single-machine necessary condition):
    for every deadline-prefix, the prefix's TOTAL minimum work must fit
    between the earliest instant any of it could start — no query can run
    before its first tuple arrives, and nothing runs before ``now`` — and
    the prefix's deadline.  One shared executor must complete ALL of the
    prefix's work by then regardless of strategy, so failure proves
    infeasibility.

    This complements ``post_window_condition``, which bounds only the work
    pinned AFTER each window's end: under smooth arrivals the per-query
    prewindow capacity of that check assumes a dedicated executor, so k
    overlapping queries that individually keep up — but jointly offer k
    times the executor's capacity — pass it while failing this one.  The
    overloaded regime (``repro.core.overload``) is detected here.

    Delegates to a one-shot ``DemandLedger`` (the maintained structure
    sessions keep incrementally); the prefix sums accumulate in the same
    order as the old scalar loop, so reports are byte-identical.
    """
    return DemandLedger(queries).work_demand(now=now)


def single_query_condition(queries: Sequence[Query]) -> FeasibilityReport:
    """Each query must be feasible in isolation (necessary)."""
    reasons: List[str] = []
    for q in queries:
        try:
            plan_single(q)
        except InfeasibleDeadline as e:
            reasons.append(f"{q.query_id}: infeasible alone ({e})")
    return FeasibilityReport(feasible=not reasons, reasons=tuple(reasons))


def blocking_period_bound(queries: Sequence[Query], c_max: float) -> FeasibilityReport:
    """§4.3: with batch costs bounded by C_max, a newly released urgent query
    waits at most C_max (+ its own work).  Flags queries whose slack at
    submission is smaller than that bound — they can miss purely from
    blocking, which no NINP strategy avoids."""
    reasons: List[str] = []
    for q in queries:
        slack = q.deadline - q.wind_end - q.min_comp_cost
        if 0 <= slack < c_max:
            reasons.append(
                f"{q.query_id}: slack {slack:.4g} < C_max {c_max:.4g}; "
                "vulnerable to NINP blocking"
            )
    # Blocking vulnerability is a warning, not a proof of infeasibility.
    return FeasibilityReport(feasible=True, reasons=tuple(reasons))


def check(
    queries: Sequence[Query],
    c_max: float = float("inf"),
    now: Optional[float] = None,
) -> FeasibilityReport:
    """Combined pre-flight: necessary conditions + blocking warnings."""
    parts = [
        single_query_condition(queries),
        post_window_condition(queries, now),
        work_demand_condition(queries, now),
        blocking_period_bound(queries, c_max),
    ]
    return FeasibilityReport(
        feasible=all(p.feasible for p in parts),
        reasons=tuple(r for p in parts for r in p.reasons),
    )


def admission_check(
    incoming: Sequence[Query],
    active: Sequence[Query] = (),
    c_max: float = float("inf"),
    now: Optional[float] = None,
    ledger: Optional[DemandLedger] = None,
) -> FeasibilityReport:
    """Online admission pre-flight: may ``incoming`` join the LIVE set?

    ``active`` are remaining-work snapshots of the currently admitted
    queries (a session builds them from its runtime state: pending tuples
    and their remaining arrival instants).  ``now`` is the admission
    instant: snapshots carry arrival timestamps of already-arrived-but-
    unprocessed tuples in the past, and without the ``now`` floor the
    prewindow analysis would credit a phantom prefix of processing time
    that has already elapsed.  The checks stay NECESSARY conditions, so
    ``feasible=False`` proves the union cannot be scheduled by any NINP
    strategy on one executor — the caller should reject the submission
    (§4.3: exact schedulability is NP-complete, so the gate errs on the
    admitting side; deadline misses remain a measured outcome).

    * each incoming query must be feasible in isolation (the active ones
      passed this gate at their own admission);
    * the §7.4 post-window condition must hold across the UNION;
    * C_max blocking warnings are reported for the incoming set.

    ``ledger`` switches the union checks to the INCREMENTAL path: the
    prefix conditions read the maintained ``DemandLedger`` rows (with
    ``incoming`` merged in on the fly) instead of rebuilding from an
    ``active`` snapshot list — ``active`` is ignored in that case.  Ledger
    rows are registered full-window demand, not remaining-work snapshots,
    and cached post-window work may predate ``now``; both approximations
    err on the admitting side (see ``DemandLedger``), so a session using
    this as a fast pre-gate falls back to the exact snapshot path when the
    fast verdict is infeasible.
    """
    if ledger is not None:
        parts = [
            single_query_condition(incoming),
            ledger.post_window(extra=incoming, now=now),
            ledger.work_demand(extra=incoming, now=now),
            blocking_period_bound(incoming, c_max),
        ]
    else:
        parts = [
            single_query_condition(incoming),
            post_window_condition([*active, *incoming], now),
            work_demand_condition([*active, *incoming], now),
            blocking_period_bound(incoming, c_max),
        ]
    return FeasibilityReport(
        feasible=all(p.feasible for p in parts),
        reasons=tuple(r for p in parts for r in p.reasons),
    )
