"""Schedulability analysis for the dynamic NINP scheduler (paper §4.3, §7.4).

Exact schedulability of non-preemptive task sets is NP-complete (Georges et
al., paper ref [21]), so — like the paper — we provide *necessary* conditions
used as a pre-flight check and in experiments to explain infeasible cases
(the paper's §7.4 "sum of last-batch costs was ~105, so the largest deadline
must be >= windowEnd + 105" analysis is exactly `post_window_condition`).

Every check takes an optional ``now``: the instant the verdict is being made
(an online admission).  Work cannot be scheduled in the past, so prewindow
capacity before ``now`` — a "phantom prefix" that previously let mid-session
admissions credit processing time that had already elapsed — does not count,
and neither does post-window budget before ``now``.  ``now=None`` (the
default) is the offline pre-run case: the whole timeline is still ahead.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from .policies.single import plan_single, plan_without_agg_cost
from .types import EPS, InfeasibleDeadline, Query


@dataclasses.dataclass(frozen=True)
class FeasibilityReport:
    feasible: bool  # False == a NECESSARY condition failed (definitely infeasible)
    reasons: Tuple[str, ...]

    def __bool__(self) -> bool:
        return self.feasible


def max_prewindow_tuples(q: Query, now: Optional[float] = None) -> int:
    """Largest stream prefix a dedicated executor could finish strictly by
    q's window end (in-order batches, arrivals respected, nothing scheduled
    before ``now``).  Monotone in k, so binary-searchable via the backward
    planner on the k-tuple prefix."""
    import dataclasses as _dc

    floor = -math.inf if now is None else now

    def feasible(k: int) -> bool:
        if k == 0:
            return True
        # The k-prefix as its own query.  ``wind_end`` is inert to the
        # backward planner (it plans against the explicit deadline below)
        # but must satisfy the Query invariant wind_end >= wind_start even
        # for arrival models whose early instants precede the declared
        # window start (session remaining-work snapshots, ShiftedArrival
        # windows) — clamp instead of crashing the admission path.
        qk = _dc.replace(
            q,
            num_tuples_total=k,
            wind_end=max(q.arrival.input_time(k), q.wind_start),
            deadline=q.wind_end,
        )
        try:
            plan = plan_without_agg_cost(qk, q.wind_end)
        except InfeasibleDeadline:
            return False
        # No phantom prefix: the plan must be executable from ``now`` on.
        return not plan.batches or plan.batches[0].sched_time >= floor - EPS

    lo, hi = 0, q.num_tuples_total
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if feasible(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


def min_post_window_work(q: Query, now: Optional[float] = None) -> float:
    """Lower bound on the work that MUST run after q's window end: even if a
    dedicated executor maximally front-loads the stream prefix (from ``now``
    on), the remaining tuples still cost at least one batch after the window
    (final-aggregation cost excluded to keep the bound valid for
    single-batch completions)."""
    k = max_prewindow_tuples(q, now)
    rest = q.num_tuples_total - k
    return q.cost_model.cost(rest) if rest > 0 else 0.0


def post_window_condition(
    queries: Sequence[Query], now: Optional[float] = None
) -> FeasibilityReport:
    """§7.4's necessary condition, generalised to EDF prefixes.

    Sort by deadline; for every deadline-prefix, the sum of minimum
    post-window work must fit between the EARLIEST window end in the prefix
    (before which none of that work can start — and never before ``now``)
    and the prefix's deadline.  A single shared executor cannot do better
    regardless of strategy, so failure proves infeasibility.  (The paper's
    §7.4 instance — identical windows, sum of last-batch costs 105 vs
    largest deadline — is the degenerate case of this check.)
    """
    reasons: List[str] = []
    qs = sorted(queries, key=lambda q: q.deadline)
    for i in range(len(qs)):
        prefix = qs[: i + 1]
        anchor = min(q.wind_end for q in prefix)
        if now is not None:
            anchor = max(anchor, now)
        work = sum(min_post_window_work(q, now) for q in prefix)
        budget = qs[i].deadline - anchor
        if work > budget + 1e-9:
            reasons.append(
                f"deadline-prefix through {qs[i].query_id}: post-window work "
                f"{work:.4g} exceeds budget {budget:.4g} "
                f"(deadline {qs[i].deadline:.6g} - work start {anchor:.6g})"
            )
    return FeasibilityReport(feasible=not reasons, reasons=tuple(reasons))


def work_demand_condition(
    queries: Sequence[Query], now: Optional[float] = None
) -> FeasibilityReport:
    """Processor-demand bound (classic single-machine necessary condition):
    for every deadline-prefix, the prefix's TOTAL minimum work must fit
    between the earliest instant any of it could start — no query can run
    before its first tuple arrives, and nothing runs before ``now`` — and
    the prefix's deadline.  One shared executor must complete ALL of the
    prefix's work by then regardless of strategy, so failure proves
    infeasibility.

    This complements ``post_window_condition``, which bounds only the work
    pinned AFTER each window's end: under smooth arrivals the per-query
    prewindow capacity of that check assumes a dedicated executor, so k
    overlapping queries that individually keep up — but jointly offer k
    times the executor's capacity — pass it while failing this one.  The
    overloaded regime (``repro.core.overload``) is detected here.
    """
    reasons: List[str] = []
    qs = sorted(queries, key=lambda q: q.deadline)
    work = 0.0
    start = math.inf
    for i, q in enumerate(qs):
        # min_comp_cost is each query's cheapest possible processing (one
        # batch, no final agg) — a lower bound on its demand.
        work += q.min_comp_cost
        start = min(start, q.arrival.input_time(1))
        anchor = start if now is None else max(start, now)
        budget = q.deadline - anchor
        if work > budget + 1e-9:
            reasons.append(
                f"deadline-prefix through {q.query_id}: total work "
                f"{work:.4g} exceeds budget {budget:.4g} "
                f"(deadline {q.deadline:.6g} - work start {anchor:.6g})"
            )
    return FeasibilityReport(feasible=not reasons, reasons=tuple(reasons))


def single_query_condition(queries: Sequence[Query]) -> FeasibilityReport:
    """Each query must be feasible in isolation (necessary)."""
    reasons: List[str] = []
    for q in queries:
        try:
            plan_single(q)
        except InfeasibleDeadline as e:
            reasons.append(f"{q.query_id}: infeasible alone ({e})")
    return FeasibilityReport(feasible=not reasons, reasons=tuple(reasons))


def blocking_period_bound(queries: Sequence[Query], c_max: float) -> FeasibilityReport:
    """§4.3: with batch costs bounded by C_max, a newly released urgent query
    waits at most C_max (+ its own work).  Flags queries whose slack at
    submission is smaller than that bound — they can miss purely from
    blocking, which no NINP strategy avoids."""
    reasons: List[str] = []
    for q in queries:
        slack = q.deadline - q.wind_end - q.min_comp_cost
        if 0 <= slack < c_max:
            reasons.append(
                f"{q.query_id}: slack {slack:.4g} < C_max {c_max:.4g}; "
                "vulnerable to NINP blocking"
            )
    # Blocking vulnerability is a warning, not a proof of infeasibility.
    return FeasibilityReport(feasible=True, reasons=tuple(reasons))


def check(
    queries: Sequence[Query],
    c_max: float = float("inf"),
    now: Optional[float] = None,
) -> FeasibilityReport:
    """Combined pre-flight: necessary conditions + blocking warnings."""
    parts = [
        single_query_condition(queries),
        post_window_condition(queries, now),
        work_demand_condition(queries, now),
        blocking_period_bound(queries, c_max),
    ]
    return FeasibilityReport(
        feasible=all(p.feasible for p in parts),
        reasons=tuple(r for p in parts for r in p.reasons),
    )


def admission_check(
    incoming: Sequence[Query],
    active: Sequence[Query] = (),
    c_max: float = float("inf"),
    now: Optional[float] = None,
) -> FeasibilityReport:
    """Online admission pre-flight: may ``incoming`` join the LIVE set?

    ``active`` are remaining-work snapshots of the currently admitted
    queries (a session builds them from its runtime state: pending tuples
    and their remaining arrival instants).  ``now`` is the admission
    instant: snapshots carry arrival timestamps of already-arrived-but-
    unprocessed tuples in the past, and without the ``now`` floor the
    prewindow analysis would credit a phantom prefix of processing time
    that has already elapsed.  The checks stay NECESSARY conditions, so
    ``feasible=False`` proves the union cannot be scheduled by any NINP
    strategy on one executor — the caller should reject the submission
    (§4.3: exact schedulability is NP-complete, so the gate errs on the
    admitting side; deadline misses remain a measured outcome).

    * each incoming query must be feasible in isolation (the active ones
      passed this gate at their own admission);
    * the §7.4 post-window condition must hold across the UNION;
    * C_max blocking warnings are reported for the incoming set.
    """
    parts = [
        single_query_condition(incoming),
        post_window_condition([*active, *incoming], now),
        work_demand_condition([*active, *incoming], now),
        blocking_period_bound(incoming, c_max),
    ]
    return FeasibilityReport(
        feasible=all(p.feasible for p in parts),
        reasons=tuple(r for p in parts for r in p.reasons),
    )
