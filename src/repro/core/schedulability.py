"""Schedulability analysis for the dynamic NINP scheduler (paper §4.3, §7.4).

Exact schedulability of non-preemptive task sets is NP-complete (Georges et
al., paper ref [21]), so — like the paper — we provide *necessary* conditions
used as a pre-flight check and in experiments to explain infeasible cases
(the paper's §7.4 "sum of last-batch costs was ~105, so the largest deadline
must be >= windowEnd + 105" analysis is exactly `post_window_condition`).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from .policies.single import plan_single, plan_without_agg_cost
from .types import InfeasibleDeadline, Query


@dataclasses.dataclass(frozen=True)
class FeasibilityReport:
    feasible: bool  # False == a NECESSARY condition failed (definitely infeasible)
    reasons: Tuple[str, ...]

    def __bool__(self) -> bool:
        return self.feasible


def max_prewindow_tuples(q: Query) -> int:
    """Largest stream prefix a dedicated executor could finish strictly by
    q's window end (in-order batches, arrivals respected).  Monotone in k, so
    binary-searchable via the backward planner on the k-tuple prefix."""
    import dataclasses as _dc

    def feasible(k: int) -> bool:
        if k == 0:
            return True
        qk = _dc.replace(
            q,
            num_tuples_total=k,
            wind_end=q.arrival.input_time(k),
            deadline=q.wind_end,
        )
        try:
            plan_without_agg_cost(qk, q.wind_end)
            return True
        except InfeasibleDeadline:
            return False

    lo, hi = 0, q.num_tuples_total
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if feasible(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


def min_post_window_work(q: Query) -> float:
    """Lower bound on the work that MUST run after q's window end: even if a
    dedicated executor maximally front-loads the stream prefix, the remaining
    tuples still cost at least one batch after the window (final-aggregation
    cost excluded to keep the bound valid for single-batch completions)."""
    k = max_prewindow_tuples(q)
    rest = q.num_tuples_total - k
    return q.cost_model.cost(rest) if rest > 0 else 0.0


def post_window_condition(queries: Sequence[Query]) -> FeasibilityReport:
    """§7.4's necessary condition, generalised to EDF prefixes.

    Sort by deadline; for every deadline-prefix, the sum of minimum
    post-window work must fit between the EARLIEST window end in the prefix
    (before which none of that work can start) and the prefix's deadline.
    A single shared executor cannot do better regardless of strategy, so
    failure proves infeasibility.  (The paper's §7.4 instance — identical
    windows, sum of last-batch costs 105 vs largest deadline — is the
    degenerate case of this check.)
    """
    reasons: List[str] = []
    qs = sorted(queries, key=lambda q: q.deadline)
    for i in range(len(qs)):
        prefix = qs[: i + 1]
        anchor = min(q.wind_end for q in prefix)
        work = sum(min_post_window_work(q) for q in prefix)
        budget = qs[i].deadline - anchor
        if work > budget + 1e-9:
            reasons.append(
                f"deadline-prefix through {qs[i].query_id}: post-window work "
                f"{work:.4g} exceeds budget {budget:.4g} "
                f"(deadline {qs[i].deadline:.6g} - earliest window end {anchor:.6g})"
            )
    return FeasibilityReport(feasible=not reasons, reasons=tuple(reasons))


def single_query_condition(queries: Sequence[Query]) -> FeasibilityReport:
    """Each query must be feasible in isolation (necessary)."""
    reasons: List[str] = []
    for q in queries:
        try:
            plan_single(q)
        except InfeasibleDeadline as e:
            reasons.append(f"{q.query_id}: infeasible alone ({e})")
    return FeasibilityReport(feasible=not reasons, reasons=tuple(reasons))


def blocking_period_bound(queries: Sequence[Query], c_max: float) -> FeasibilityReport:
    """§4.3: with batch costs bounded by C_max, a newly released urgent query
    waits at most C_max (+ its own work).  Flags queries whose slack at
    submission is smaller than that bound — they can miss purely from
    blocking, which no NINP strategy avoids."""
    reasons: List[str] = []
    for q in queries:
        slack = q.deadline - q.wind_end - q.min_comp_cost
        if 0 <= slack < c_max:
            reasons.append(
                f"{q.query_id}: slack {slack:.4g} < C_max {c_max:.4g}; "
                "vulnerable to NINP blocking"
            )
    # Blocking vulnerability is a warning, not a proof of infeasibility.
    return FeasibilityReport(feasible=True, reasons=tuple(reasons))


def check(queries: Sequence[Query], c_max: float = float("inf")) -> FeasibilityReport:
    """Combined pre-flight: necessary conditions + blocking warnings."""
    parts = [
        single_query_condition(queries),
        post_window_condition(queries),
        blocking_period_bound(queries, c_max),
    ]
    return FeasibilityReport(
        feasible=all(p.feasible for p in parts),
        reasons=tuple(r for p in parts for r in p.reasons),
    )


def admission_check(
    incoming: Sequence[Query],
    active: Sequence[Query] = (),
    c_max: float = float("inf"),
) -> FeasibilityReport:
    """Online admission pre-flight: may ``incoming`` join the LIVE set?

    ``active`` are remaining-work snapshots of the currently admitted
    queries (a session builds them from its runtime state: pending tuples
    and their remaining arrival instants).  The checks stay NECESSARY
    conditions, so ``feasible=False`` proves the union cannot be scheduled
    by any NINP strategy on one executor — the caller should reject the
    submission (§4.3: exact schedulability is NP-complete, so the gate errs
    on the admitting side; deadline misses remain a measured outcome).

    * each incoming query must be feasible in isolation (the active ones
      passed this gate at their own admission);
    * the §7.4 post-window condition must hold across the UNION;
    * C_max blocking warnings are reported for the incoming set.
    """
    parts = [
        single_query_condition(incoming),
        post_window_condition([*active, *incoming]),
        blocking_period_bound(incoming, c_max),
    ]
    return FeasibilityReport(
        feasible=all(p.feasible for p in parts),
        reasons=tuple(r for p in parts for r in p.reasons),
    )
