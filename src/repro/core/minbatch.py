"""Minimum batch size for the dynamic scenario (paper §4.1, Eq. 9).

The dynamic scheduler cannot hold work back for globally optimal batches
(other queries claim the executor), so each query is processed whenever
``MinBatch`` tuples are ready.  ``MinBatch`` trades cost against
schedulability:

* cost bound   — processing everything in MinBatch-sized chunks (plus final
                 aggregation) must cost at most ``(1 + delta_rsf)`` times the
                 single-batch minimum (Eq. 9: delta_rsf = 0.1 -> factor 1.1);
* latency bound— one MinBatch must cost <= ``c_max`` so the non-preemptive
                 blocking period is bounded (§4.2/§4.3);
* group floor  — at least ~2x the number of GROUP-BY groups, else partial
                 aggregation shrinks nothing (§4.1).
"""
from __future__ import annotations

import math
from typing import List, Sequence, Union

from .cost_model import CostModelBase, LinearCostModel
from .types import InfeasibleDeadline


def find_min_batch_size(
    num_tuples_total: int,
    cost_model: CostModelBase,
    delta_rsf: float,
    c_max: float,
    num_groups: int = 0,
) -> int:
    """FindMinBatchSize (Algorithm 2 helper).

    Smallest batch size whose total batched cost respects Eq. (9), then capped
    so a single batch never exceeds ``c_max``; floored at ``2 * num_groups``
    when that is compatible with ``c_max``.
    """
    n = num_tuples_total
    if n <= 0:
        return 1
    budget = (1.0 + delta_rsf) * cost_model.cost(n)

    # batched_cost is non-increasing in batch size (fewer batches => less
    # overhead + less final agg), so binary-search the smallest x within budget.
    lo, hi = 1, n
    if cost_model.batched_cost(n, n) > budget + 1e-9:
        raise InfeasibleDeadline("cost budget below single-batch cost")
    while lo < hi:
        mid = (lo + hi) // 2
        if cost_model.batched_cost(n, mid) <= budget + 1e-9:
            hi = mid
        else:
            lo = mid + 1
    x = lo

    # Group floor (§4.1): significant reduction needs >= 2x groups per batch.
    if num_groups > 0:
        x = max(x, min(2 * num_groups, n))

    # C_max cap (§4.2): one batch must fit the scheduler quantum.  This may
    # override the Eq.-9 bound — the paper gives C_max precedence ("its
    # Minbatch size is reduced such that its cost does not exceed C_max").
    if cost_model.cost(1) > c_max + 1e-9:
        raise InfeasibleDeadline(
            f"cost of a single tuple {cost_model.cost(1):.3g} exceeds C_max {c_max:.3g}"
        )
    cap = cost_model.tuples_processable(c_max)
    return max(1, min(x, cap, n))


def find_min_batch_sizes(
    num_tuples_totals: Sequence[int],
    cost_models: Sequence[CostModelBase],
    delta_rsf: float,
    c_max: float,
    num_groups: Union[int, Sequence[int]] = 0,
) -> List[int]:
    """Batch ``find_min_batch_size`` over parallel rows.

    When every row's cost model is exactly a ``LinearCostModel`` (and
    ``c_max`` is finite), all binary searches run SIMULTANEOUSLY over
    packed numpy arrays — each iteration halves every row's bracket at
    once, so sizing k queries costs O(log max_n) vectorized steps instead
    of k independent scalar searches.  The float operations replicate the
    scalar algorithm's order exactly, so results are identical element for
    element, and an infeasible row raises the same ``InfeasibleDeadline``
    (first row in input order wins, like a scalar loop would).  Any other
    cost model falls back to the per-row scalar routine.
    """
    ns = [int(n) for n in num_tuples_totals]
    models = list(cost_models)
    if len(ns) != len(models):
        raise ValueError("num_tuples_totals and cost_models length mismatch")
    if isinstance(num_groups, int):
        groups = [num_groups] * len(ns)
    else:
        groups = [int(g) for g in num_groups]
        if len(groups) != len(ns):
            raise ValueError("num_groups length mismatch")
    if (not ns
            or not math.isfinite(c_max)
            or any(type(m) is not LinearCostModel for m in models)):
        return [
            find_min_batch_size(n, m, delta_rsf, c_max, g)
            for n, m, g in zip(ns, models, groups)
        ]
    import numpy as np

    n_arr = np.array(ns, dtype=np.int64)
    tc = np.array([m.tuple_cost for m in models], dtype=np.float64)
    oh = np.array([m.overhead for m in models], dtype=np.float64)
    apb = np.array([m.agg_per_batch for m in models], dtype=np.float64)
    agg_oh = np.array([m.agg_overhead for m in models], dtype=np.float64)
    g_arr = np.array(groups, dtype=np.int64)

    live = n_arr > 0  # n <= 0 rows return 1 before any feasibility check
    n = np.where(live, n_arr, 1)
    single = n * tc + oh  # cost(n), n >= 1
    budget = (1.0 + delta_rsf) * single
    cost1 = 1 * tc + oh  # cost(1)
    bad_budget = live & (single > budget + 1e-9)
    bad_cmax = live & (cost1 > c_max + 1e-9)
    bad = bad_budget | bad_cmax
    if bad.any():
        i = int(np.argmax(bad))
        if bad_budget[i]:
            raise InfeasibleDeadline("cost budget below single-batch cost")
        raise InfeasibleDeadline(
            f"cost of a single tuple {float(cost1[i]):.3g} "
            f"exceeds C_max {c_max:.3g}"
        )

    # All rows bisect in lock-step; a row whose bracket closed keeps
    # evaluating its (now fixed) lo — harmless and branch-free.
    lo = np.ones_like(n)
    hi = n.copy()
    while True:
        active = lo < hi
        if not active.any():
            break
        mid = (lo + hi) // 2
        full = n // mid
        rem = n - full * mid
        c = full * (mid * tc + oh) + np.where(rem > 0, rem * tc + oh, 0.0)
        nb = full + (rem > 0)
        c = c + np.where(nb > 1, nb * apb + agg_oh, 0.0)
        ok = c <= budget + 1e-9
        hi = np.where(active & ok, mid, hi)
        lo = np.where(active & ~ok, mid + 1, lo)
    x = lo

    x = np.where(g_arr > 0, np.maximum(x, np.minimum(2 * g_arr, n)), x)
    tc_safe = np.where(tc > 0, tc, 1.0)
    capf = np.floor((c_max - oh) / tc_safe + 1e-9)
    cap = np.where(
        c_max < oh, 0,
        np.where(tc <= 0, 1 << 40, capf.astype(np.int64)),
    )
    out = np.maximum(1, np.minimum(np.minimum(x, cap), n))
    return [int(v) if ok_row else 1 for v, ok_row in zip(out, live)]
