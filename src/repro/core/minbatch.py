"""Minimum batch size for the dynamic scenario (paper §4.1, Eq. 9).

The dynamic scheduler cannot hold work back for globally optimal batches
(other queries claim the executor), so each query is processed whenever
``MinBatch`` tuples are ready.  ``MinBatch`` trades cost against
schedulability:

* cost bound   — processing everything in MinBatch-sized chunks (plus final
                 aggregation) must cost at most ``(1 + delta_rsf)`` times the
                 single-batch minimum (Eq. 9: delta_rsf = 0.1 -> factor 1.1);
* latency bound— one MinBatch must cost <= ``c_max`` so the non-preemptive
                 blocking period is bounded (§4.2/§4.3);
* group floor  — at least ~2x the number of GROUP-BY groups, else partial
                 aggregation shrinks nothing (§4.1).
"""
from __future__ import annotations

from .cost_model import CostModelBase
from .types import InfeasibleDeadline


def find_min_batch_size(
    num_tuples_total: int,
    cost_model: CostModelBase,
    delta_rsf: float,
    c_max: float,
    num_groups: int = 0,
) -> int:
    """FindMinBatchSize (Algorithm 2 helper).

    Smallest batch size whose total batched cost respects Eq. (9), then capped
    so a single batch never exceeds ``c_max``; floored at ``2 * num_groups``
    when that is compatible with ``c_max``.
    """
    n = num_tuples_total
    if n <= 0:
        return 1
    budget = (1.0 + delta_rsf) * cost_model.cost(n)

    # batched_cost is non-increasing in batch size (fewer batches => less
    # overhead + less final agg), so binary-search the smallest x within budget.
    lo, hi = 1, n
    if cost_model.batched_cost(n, n) > budget + 1e-9:
        raise InfeasibleDeadline("cost budget below single-batch cost")
    while lo < hi:
        mid = (lo + hi) // 2
        if cost_model.batched_cost(n, mid) <= budget + 1e-9:
            hi = mid
        else:
            lo = mid + 1
    x = lo

    # Group floor (§4.1): significant reduction needs >= 2x groups per batch.
    if num_groups > 0:
        x = max(x, min(2 * num_groups, n))

    # C_max cap (§4.2): one batch must fit the scheduler quantum.  This may
    # override the Eq.-9 bound — the paper gives C_max precedence ("its
    # Minbatch size is reduced such that its cost does not exceed C_max").
    if cost_model.cost(1) > c_max + 1e-9:
        raise InfeasibleDeadline(
            f"cost of a single tuple {cost_model.cost(1):.3g} exceeds C_max {c_max:.3g}"
        )
    cap = cost_model.tuples_processable(c_max)
    return max(1, min(x, cap, n))
