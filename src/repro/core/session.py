"""Continuous intermittent-query sessions (the paper's Custom Query
Scheduler RUNS CONTINUOUSLY — §1's "results are obtained at the end of each
window", §4's "queries may be added or removed at any point").

Everything before this module modelled one-shot windows: ``Planner.run``
drains a fixed workload, resets the executor per query and returns.  A
``SessionRuntime`` is the long-lived generalization:

* **recurring windows** — a ``RecurringQuerySpec`` is instantiated into
  per-window ``Query`` objects lazily at window roll-over; executor/pool
  clocks CARRY OVER across windows (one continuous timeline, never reset
  after session start);
* **online admission** — ``submit`` gates new work behind a schedulability
  pre-flight (``repro.core.schedulability.admission_check``) against
  remaining-work snapshots of the live set; ``withdraw`` removes a query
  mid-run.  Both take effect between batches (§4.2) through the shared
  ``DynamicLoopCore``, whose ``replan`` receives ``"admission"``
  SchedulingEvents;
* **self-calibrating costs** — with ``calibrate=True`` each recurring
  query's cost model is wrapped in a ``CalibratingCostModel`` fed by
  execution feedback (modelled true durations in simulation — see
  ``OracleCostExecutor`` — or measured wall seconds on real backends).
  When the drift metric crosses ``drift_threshold`` the session refits and
  replans FUTURE work: static windows are planned at window start with the
  refreshed model; dynamic runtimes get their MinBatch re-sized through the
  policy's ``on_recalibrate`` hook;
* **pane sharing** — with ``sharing=True`` the session keeps ONE
  ``repro.core.panes.SharedBook`` for its whole lifetime: window queries on
  a common ``Query.stream`` with actual overlap (several live specs, or one
  spec whose ``slide_tuples`` < range) run under the amortized
  ``SharedCostModel`` and their pane partials carry over across recurring
  windows — window ``w+1`` reuses what window ``w`` scanned, and the
  refcounted ``PaneStore`` evicts each pane the moment its last subscriber
  has consumed it;
* **predictive scheduling** — with ``forecast=`` every closed window's
  realized arrivals feed a per-spec ``repro.core.forecast``
  ``ArrivalForecaster`` (Holt-style level+trend with confidence bands).
  At window roll-over the session re-runs the overload machinery against
  the FORECAST arrival curve and sheds the new window proactively —
  before the burst lands — instead of reacting mid-burst; a mid-window
  miss detector compares realized arrivals against the forecast burst and
  REFUNDS a premature shed (restoring the original window) when the
  predicted demand is not materializing, falling back to the reactive
  path.  With ``sharing=True`` idle loop instants additionally pre-warm
  the pane cache for forecast future windows (speculative deposits,
  written off as misses when the window never consumes them).  The
  arrival history itself is collected UNCONDITIONALLY and exposed through
  ``history()`` — forecasting only adds the acting-on part.

Static policies run each window's plan on the same carried-over timeline
(``execute_plan(carryover=True)``): window k+1 starts no earlier than both
its own ``submit_time`` and the end of window k's execution — the session
owns ONE executor, exactly like the dynamic NINP loop.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple, Union

from .api import Executor, SchedulingPolicy, get_policy
from .arrivals import ArrivalModel, ThinnedArrival, TraceArrival
from .cost_model import CalibratingCostModel, SharedCostModel
from .forecast import (
    ArrivalForecast,
    ArrivalForecaster,
    ArrivalObservation,
    ForecastConfig,
    SpecHistory,
    forecast_query,
    observe_arrival,
    offered_arrival,
)
from .overload import (
    OverloadConfig,
    RenegotiationProposal,
    apply_shed,
    min_deadline_extension,
    overload_check,
    plan_shedding,
    tiered_work_demand_condition,
)
from .panes import PaneStats, SharedBook, pane_width
from .runtime import (
    DynamicQuerySpec,
    ExecutorPool,
    OracleCostExecutor,
    QueryRuntime,
    RuntimeState,
    _core_class,
)
from .schedulability import DemandLedger, FeasibilityReport, admission_check
from .tenancy import TenancyConfig, TenantQuota, tenant_quota_condition
from .types import (
    EPS,
    BatchExecution,
    InfeasibleDeadline,
    Query,
    QueryOutcome,
    RecurringQuerySpec,
    SessionTrace,
    split_window_id,
    window_query_id,
)

# Remaining-arrival snapshots for the admission pre-flight are exact up to
# this many pending tuples; beyond it the ORIGINAL query stands in (a
# conservative, still-valid input to the necessary conditions).
_SNAPSHOT_CAP = 20_000

# Per-spec arrival observations retained for ``history()``/forecasting
# (oldest evicted first; the forecaster's EWMA state is unaffected).
_HISTORY_CAP = 512

# Pseudo-subscriber prefix for speculative pane pre-warms.  ``?`` cannot
# start a submitted base id's per-window query id, so prewarm references
# can never collide with a real subscriber.
_PREWARM_TAG = "?forecast:"


@dataclasses.dataclass(frozen=True)
class AdmissionResult:
    """Outcome of ``SessionRuntime.submit``.

    ``decision`` refines the boolean: ``"admit"`` (feasible as submitted),
    ``"shed"`` (admitted with load shedding — ``shed_fraction`` of the
    stream dropped, answers are estimates within ``error_bound``),
    ``"renegotiate"`` (admitted after the accept hook took the proposed
    deadline extension in ``proposal``), or ``"reject"``.  Without overload
    control only ``"admit"``/``"reject"`` occur, and a declined proposal is
    a ``"reject"`` whose ``proposal`` records what was offered.
    """

    admitted: bool
    report: FeasibilityReport
    base_id: str
    decision: str = ""
    shed_fraction: float = 0.0
    error_bound: float = 0.0
    proposal: Optional[RenegotiationProposal] = None

    def __bool__(self) -> bool:
        return self.admitted


@dataclasses.dataclass
class _LiveSpec:
    """Session-side bookkeeping for one recurring query."""

    rspec: RecurringQuerySpec
    calibrator: Optional[CalibratingCostModel] = None
    next_window: int = 0
    withdrawn: bool = False
    # pane sharing: False when the stream's (first-registration-wins) pane
    # width does not divide this spec's range/slide/offset — such a spec
    # runs UNSHARED (no amortized cost model, no pane subscriptions) rather
    # than promising amortization it cannot physically realize.
    pane_ok: bool = True
    # overload control: admission-time load shed applied to this spec (every
    # window samples its stream at rate 1 - shed_fraction; answers are
    # scaled estimates within error_bound).
    shed_fraction: float = 0.0
    error_bound: float = 0.0
    # seed threaded into every ThinnedArrival this spec's shedding creates
    # (``OverloadConfig.seed``): fixes the systematic-sampling phase so
    # shed runs are reproducible; None keeps the historical phase-0 picks.
    shed_seed: Optional[int] = None
    # predictive scheduling (repro.core.forecast): per-window realized
    # arrival observations (collected unconditionally — the fuel of
    # ``history()``), the spec's forecaster (None unless ``forecast=``),
    # and the miss-triggered hold that keeps a misbehaving forecast from
    # acting until a window lands back inside its band.
    history: List[ArrivalObservation] = dataclasses.field(default_factory=list)
    forecaster: Optional[ArrivalForecaster] = None
    forecast_hold: bool = False
    # dynamic path: instantiated window runtimes; static path: pending Queries
    runtimes: List[QueryRuntime] = dataclasses.field(default_factory=list)
    pending_static: List[Query] = dataclasses.field(default_factory=list)

    @property
    def base_id(self) -> str:
        return self.rspec.base_id

    @property
    def exhausted(self) -> bool:
        if self.withdrawn:
            return True
        nw = self.rspec.num_windows
        return nw is not None and self.next_window >= nw

    @property
    def in_flight(self) -> bool:
        """Any instantiated window still running (or waiting to run)."""
        if self.pending_static:
            return True
        return any(not (rt.completed or rt.deleted) for rt in self.runtimes)

    @property
    def open_ended(self) -> bool:
        return self.rspec.num_windows is None and not self.withdrawn

    def cost_model(self):
        return (self.calibrator if self.calibrator is not None
                else self.rspec.base.cost_model)

    def window_truth(self, window: int) -> Optional[ArrivalModel]:
        """Window ``window``'s TRUE arrival process, thinned to this spec's
        shed rate when overload control degraded it: shedding is an
        actuation — the dropped tuples are never ingested, so the loop's
        availability/readiness logic must see the sampled stream."""
        truth = self.rspec.window_truth(window)
        if truth is None or self.shed_fraction <= 0:
            return truth
        keep = self.rspec.base.num_tuples_total  # base already thinned
        if truth.num_tuples_total <= keep:
            return truth
        return ThinnedArrival(base=truth, keep=keep, seed=self.shed_seed)


@dataclasses.dataclass
class _ProactiveShed:
    """One window's forecast-driven proactive shed, kept until the window
    closes so the mid-window miss check can compare realized arrivals
    against the forecast burst — and refund the shed (restore the original
    window) when the predicted demand is not materializing."""

    live: _LiveSpec
    forecast: ArrivalForecast
    check_at: float            # instant of the mid-window forecast-miss check
    fraction: float            # cumulative shed applied to the window
    error_bound: float
    orig_query: Query          # pre-shed window query (the refund target)
    orig_truth: Optional[ArrivalModel]
    checked: bool = False
    missed: bool = False


def as_recurring(
    spec: Union[Query, DynamicQuerySpec, RecurringQuerySpec],
) -> RecurringQuerySpec:
    """Normalize a submission: one-shot queries become single-window specs."""
    if isinstance(spec, RecurringQuerySpec):
        return spec
    if isinstance(spec, DynamicQuerySpec):
        truth = spec.truth
        return RecurringQuerySpec(
            base=spec.query,
            period=max(spec.query.wind_end - spec.query.wind_start, 1.0),
            num_windows=1,
            truth_factory=(lambda w: truth),
            num_groups=spec.num_groups,
            delete_time=spec.delete_time,
            total_known=spec.total_known,
        )
    if isinstance(spec, Query):
        return RecurringQuerySpec(
            base=spec,
            period=max(spec.wind_end - spec.wind_start, 1.0),
            num_windows=1,
        )
    raise TypeError(f"cannot submit {type(spec).__name__} to a session")


class SessionRuntime:
    """The long-running event loop behind ``repro.core.Session``.

    Drive it with ``submit`` / ``withdraw`` between ``run_until`` calls::

        s = SessionRuntime(policy="llf-dynamic")
        s.submit(RecurringQuerySpec(base=q, period=60.0, num_windows=10))
        s.run_until(300.0)          # windows roll over, clocks carry
        s.submit(other)             # mid-run admission (pre-flight gated)
        s.run_until(900.0)
        s.trace.outcome_series(q.query_id)
    """

    def __init__(
        self,
        policy: Union[str, SchedulingPolicy] = "llf-dynamic",
        executor: Optional[Executor] = None,
        *,
        workers: Optional[int] = None,
        start_time: Optional[float] = None,
        calibrate: bool = False,
        drift_threshold: float = 0.25,
        min_samples: int = 4,
        refit_every: int = 8,
        c_max: Optional[float] = None,
        admission_control: bool = True,
        sharing: bool = False,
        pane_tuples: Optional[int] = None,
        overload: Union[bool, OverloadConfig] = False,
        on_renegotiate: Optional[
            Callable[[RenegotiationProposal], bool]] = None,
        forecast: Union[bool, ForecastConfig, None] = None,
        runtime: Optional[str] = None,
        admission: str = "snapshot",
        tenancy: Union[TenancyConfig, Dict[str, TenantQuota], None] = None,
        **policy_params,
    ):
        if isinstance(policy, str):
            policy = get_policy(policy, **policy_params)
        elif policy_params:
            raise TypeError("policy_params only apply when policy is a name")
        if c_max is not None and hasattr(policy, "c_max"):
            # ``c_max`` is both a session knob (the loop's wall-time
            # straggler bound) and a policy knob (MinBatch sizing, §4.2).
            # One explicit value must mean ONE bound — mirror it onto the
            # policy so Session(policy="llf-dynamic", c_max=x) sizes batches
            # exactly like Planner(policy="llf-dynamic", c_max=x).
            policy.c_max = c_max
        self.policy = policy
        executor = OracleCostExecutor() if executor is None else executor
        if workers is not None:
            executor = ExecutorPool(backend=executor, workers=workers)
        self.executor = executor
        self.calibrate = calibrate
        self.drift_threshold = drift_threshold
        self.min_samples = min_samples
        self.refit_every = refit_every
        self.c_max = c_max if c_max is not None else getattr(policy, "c_max", None)
        self.admission_control = admission_control
        # Overload control (repro.core.overload): None == disabled — the
        # admission gate stays the plain admit/reject of the feasible-regime
        # runtime.  Enabled, an infeasible submission is degraded instead of
        # rejected: minimum load shed (lowest tiers first), else the
        # smallest deadline extension offered through ``on_renegotiate``.
        if isinstance(overload, OverloadConfig):
            self.overload: Optional[OverloadConfig] = overload
        else:
            self.overload = OverloadConfig() if overload else None
        self.on_renegotiate = on_renegotiate
        # Predictive scheduling (repro.core.forecast): None == disabled —
        # arrival history is still collected (``history()``), but nothing
        # acts on it and every trace stays byte-identical to the reactive
        # session.  Enabled, window roll-overs replan against the forecast
        # arrival curve (proactive shedding needs ``overload=`` too) and
        # idle capacity pre-warms forecast panes (needs ``sharing=True``).
        if isinstance(forecast, ForecastConfig):
            self.forecast: Optional[ForecastConfig] = forecast
        else:
            self.forecast = ForecastConfig() if forecast else None
        # Multi-tenancy (repro.core.tenancy): None == disabled — every
        # query belongs to the anonymous pool and all traces stay
        # byte-identical to the single-tenant session.  Enabled, admission
        # enforces per-tenant rate/capacity quotas and overload shedding
        # arbitrates ACROSS tenants by weighted max-min fairness before the
        # strict tiers order work WITHIN each tenant's share.
        if isinstance(tenancy, dict):
            tenancy = TenancyConfig(quotas=dict(tenancy))
        self.tenancy: Optional[TenancyConfig] = tenancy
        # Pane sharing (repro.core.panes): ONE book for the whole session, so
        # pane partials cached in window w carry over to every later window
        # that overlaps it (slide < range), and across queries on the stream.
        self.book: Optional[SharedBook] = (
            SharedBook(pane_tuples=pane_tuples) if sharing else None
        )
        if pane_tuples is not None and not sharing:
            raise ValueError("pane_tuples= only applies with sharing=True")
        self.trace = SessionTrace()
        # live SharedCostModel wrappers per stream (query_id, model), kept
        # in sync with the sharer count by _resync_sharers
        self._shared_models: Dict[str, List] = {}
        self._live: Dict[str, _LiveSpec] = {}
        self._state = RuntimeState(
            runtimes=[],
            trace=self.trace,
            num_workers=getattr(executor, "num_workers", 1),
            worker_names=tuple(getattr(executor, "worker_names", ())),
        )
        # Decision core: ``runtime="heap"`` opts the dynamic loop into the
        # event-heap core (O(log n) per decision, trace-identical to the
        # scan); ``"scan"``/None keep the reference full-walk core.
        self._core = _core_class(policy, runtime)(
            policy, executor, self._state,
            on_batch=self._observe, c_max=self.c_max,
        )
        # Admission pre-flight mode: ``"snapshot"`` rebuilds remaining-work
        # snapshots of the live set per submission (exact, O(n) cost-model
        # and planner calls each time); ``"incremental"`` maintains a
        # per-deadline ``DemandLedger`` updated by delta on window
        # open/close/withdraw/shed and answers the prefix-sum conditions
        # from it — full-window rows, so demand is over-estimated and an
        # infeasible verdict falls back to the exact snapshot path before
        # any reject/shed decision (the fast path only ever short-circuits
        # ACCEPTS).
        if admission not in ("snapshot", "incremental"):
            raise ValueError(
                f"admission must be 'snapshot' or 'incremental', "
                f"got {admission!r}"
            )
        self._ledger: Optional[DemandLedger] = (
            DemandLedger() if admission == "incremental" else None
        )
        self._is_dynamic = getattr(policy, "kind", "static") == "dynamic"
        self._start_time = start_time
        self._started = start_time is not None
        self._outcomes_seen = 0
        # per-window batch counts for final-agg calibration feedback (O(1)
        # instead of re-scanning the whole session trace per window)
        self._batch_counts: Dict[str, int] = {}
        # window-level (mid-run) sheds on the static path: query_id ->
        # (cumulative fraction, error bound), stamped onto the outcome
        self._window_shed: Dict[str, tuple] = {}
        # predictive scheduling: per-window offered arrival awaiting its
        # close-time observation, forecasts awaiting band scoring,
        # proactive sheds awaiting the mid-window miss check, and window
        # ids whose panes were speculatively pre-warmed.
        self._window_truths: Dict[
            str, Tuple[_LiveSpec, ArrivalModel, int, float, float]] = {}
        self._pending_forecasts: Dict[
            str, Tuple[_LiveSpec, ArrivalForecast]] = {}
        self._proactive: Dict[str, _ProactiveShed] = {}
        self._prewarmed: set = set()
        # cascaded rollups: window ids currently deferred on an upstream
        # spec (their panes pre-subscribed so the upstream's partials
        # survive until the downstream window materializes)
        self._cascade_wait: set = set()
        if start_time is not None:
            executor.reset(start_time)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def now(self) -> float:
        """Current modelled time of the session's continuous timeline."""
        return self.executor.clock()

    @property
    def live_ids(self) -> List[str]:
        """Base ids of every submitted, not-yet-withdrawn query."""
        return [b for b, l in self._live.items() if not l.withdrawn]

    def calibrator(self, base_id: str) -> Optional[CalibratingCostModel]:
        """The live ``CalibratingCostModel`` of ``base_id`` (None unless the
        session runs with ``calibrate=True``)."""
        return self._live[base_id].calibrator

    @property
    def pane_stats(self) -> Optional[PaneStats]:
        """Scan/hit/eviction counters of the session's pane cache (None
        unless the session runs with ``sharing=True``)."""
        return None if self.book is None else self.book.store.stats

    def _stream_sharers(self, stream: str) -> int:
        """Expected subscribers per pane of ``stream`` across the live
        PANE-COMPATIBLE specs: each spec contributes its window-overlap
        factor (how many of its own sliding windows cover one pane) — 1
        for tumbling windows.  Incompatible specs run unshared and count
        for nothing.  A spec whose last window has been INSTANTIATED but is
        still in flight keeps counting: its windows still subscribe panes,
        so dropping it from the divisor would re-price the other sharers'
        scans as if the sharing had already ended."""
        return sum(
            _spec_overlap(l.rspec) for l in self._live.values()
            if not l.withdrawn and l.pane_ok
            and (not l.exhausted or l.in_flight)
            and l.rspec.base.stream == stream
        )

    def _resync_sharers(self, stream: str) -> None:
        """Re-amortize every live window's SharedCostModel on ``stream`` to
        the CURRENT sharer count (documented mutability of ``sharers``):
        queries joining or leaving must not leave in-flight windows pricing
        scans against a stale k.  Models of completed windows are pruned."""
        if self.book is None:
            return
        k = max(self._stream_sharers(stream), 1)
        models = self._shared_models.get(stream, [])
        keep = []
        for qid, m in models:
            sub = self.book._subs.get(qid)
            if sub is not None and sub.done:
                continue
            m.sharers = k
            keep.append((qid, m))
        self._shared_models[stream] = keep

    # ------------------------------------------------------------------
    # Admission / withdrawal
    # ------------------------------------------------------------------
    def submit(
        self,
        spec: Union[Query, DynamicQuerySpec, RecurringQuerySpec],
        *,
        force: bool = False,
    ) -> AdmissionResult:
        """Admit a (recurring) query into the live session.

        The schedulability pre-flight checks the spec's FIRST window against
        remaining-work snapshots of everything currently admitted, evaluated
        AT the submission instant — work cannot run in the past, so backlog
        that already arrived counts in full (necessary conditions only:
        rejection proves infeasibility, acceptance promises nothing —
        deadline misses remain a measured outcome).  ``force=True`` records
        the report but admits regardless.

        With overload control enabled (``overload=``), an infeasible
        submission is degraded instead of rejected: the minimum load shed
        (lowest priority tiers first, incoming and active queries alike)
        that restores the necessary conditions is applied — answers become
        scaled sample estimates, reported through
        ``QueryOutcome.shed_fraction``/``error_bound`` and ``"shed"``
        session events; when shedding is disallowed (``Query.shed=False``)
        or insufficient, the smallest feasible deadline extension is
        offered to the ``on_renegotiate`` hook (``"renegotiate"`` events).
        Only then does the submission fall through to rejection.
        """
        rspec = as_recurring(spec)
        base_id = rspec.base_id
        if split_window_id(base_id)[1] is not None:
            raise ValueError(
                f"{base_id!r} collides with the per-window id namespace "
                "'<base>#w<k>'; pick a base id without a '#w<digits>' suffix"
            )
        if base_id in self._live:
            # Covers withdrawn ids too: a second incarnation would re-mint
            # the same per-window ids, and runtime/trace lookups (first
            # match by id) would then hit the dead incarnation's rows.
            raise ValueError(
                f"{base_id!r} already used in this session (live or "
                "withdrawn); pick a fresh base id per incarnation"
            )
        if rspec.base.upstream == base_id:
            raise ValueError(
                f"{base_id!r} names itself as upstream; a cascaded rollup "
                "must consume a DIFFERENT live spec's output"
            )
        calibrator = None
        if self.calibrate:
            if isinstance(rspec.base.cost_model, CalibratingCostModel):
                calibrator = rspec.base.cost_model
            else:
                calibrator = CalibratingCostModel(
                    rspec.base.cost_model,
                    min_samples=self.min_samples,
                    refit_every=self.refit_every,
                )
        live = _LiveSpec(rspec=rspec, calibrator=calibrator)
        live.shed_seed = None if self.overload is None else self.overload.seed
        if self.forecast is not None:
            live.forecaster = ArrivalForecaster(self.forecast)

        first = rspec.window_query(0, cost_model=live.cost_model())
        stream = rspec.base.stream
        width = None
        if self.book is not None and stream is not None:
            # Pane grid of the stream: fixed by the first compatible
            # submission as the GCD of its window range, slide and start
            # offset (so every window lands on pane boundaries).  A LATER
            # spec whose geometry the established width does not divide
            # runs unshared — re-gridding a live stream would invalidate
            # existing subscriptions, and wrapping an unalignable spec in
            # SharedCostModel would promise amortization that never
            # physically happens.
            width = self.book.peek_width(
                stream,
                pane_width(
                    (rspec.base.num_tuples_total,),
                    (s for s in (rspec.slide_tuples, rspec.base.stream_offset)
                     if s),
                ),
            )
            live.pane_ok = _pane_compatible(rspec, width)
            if live.pane_ok:
                # The admission pre-flight must already see the SHARED
                # cost — a query that is only feasible because its scans
                # are amortized should be admitted under sharing.
                k = self._stream_sharers(stream) + _spec_overlap(rspec)
                if k >= 2:
                    first = dataclasses.replace(
                        first,
                        cost_model=SharedCostModel(first.cost_model,
                                                   sharers=k,
                                                   pane_tuples=width),
                    )
        c_max = self.c_max if self.c_max is not None else float("inf")
        now = self.now
        snaps: List[Query] = []
        fast_ok = False
        if (self._ledger is not None and self.admission_control
                and not force):
            # Incremental fast path (admission="incremental"): answer the
            # prefix-sum conditions from the maintained ledger — no
            # snapshot rebuild, no per-row planner calls.  Ledger rows are
            # FULL windows, so demand is over-estimated; a feasible verdict
            # safely short-circuits to admit, an infeasible one falls back
            # to the exact snapshot pre-flight below before any
            # reject/shed decision.
            report = admission_check([first], (), c_max=c_max, now=now,
                                     ledger=self._ledger)
            fast_ok = report.feasible and (
                self.overload is None
                or tiered_work_demand_condition(
                    [*self._ledger.queries, first], now).feasible
            ) and (
                self.tenancy is None
                or self._ledger.tenant_check(
                    [first], now=now, config=self.tenancy).feasible
            )
        if not fast_ok:
            snaps = self._active_snapshot()
            report = admission_check([first], snaps, c_max=c_max, now=now)
            if self.tenancy is not None:
                # Per-tenant quota pre-flight rides on top of the generic
                # schedulability conditions (same merged ordering as the
                # ledger's ``tenant_check`` so reasons stay byte-equal).
                quota = tenant_quota_condition(
                    [*snaps, first], self.tenancy, now)
                report = FeasibilityReport(
                    feasible=report.feasible and quota.feasible,
                    reasons=(*report.reasons, *quota.reasons),
                )
        decision, shed_fraction, error_bound, proposal = "admit", 0.0, 0.0, None
        if self.admission_control and not force and not fast_ok:
            if self.overload is not None:
                # Overload activation additionally consults the tier-strict
                # demand bound: THIS runtime protects low tier numbers, so
                # a submission the generic (policy-agnostic) conditions
                # accept can still be doomed behind higher-priority work.
                needs = (not report.feasible or not
                         tiered_work_demand_condition([*snaps, first],
                                                      now).feasible)
            else:
                needs = not report.feasible
            if needs:
                outcome = None
                if self.overload is not None:
                    outcome = self._overload_admit(
                        live, first, snaps, c_max, now)
                if outcome is None:
                    self.trace.log("reject", now, base_id,
                                   "; ".join(report.reasons))
                    return AdmissionResult(False, report, base_id,
                                           decision="reject",
                                           proposal=proposal)
                decision, report, shed_fraction, error_bound, proposal = outcome
                if decision == "reject":
                    self.trace.log("reject", now, base_id,
                                   "; ".join(report.reasons))
                    return AdmissionResult(False, report, base_id,
                                           decision="reject",
                                           proposal=proposal)
                rspec = live.rspec  # shed/renegotiation may have replaced it

        self._register_true_cost(rspec)
        if self.book is not None and stream is not None:
            if live.pane_ok:
                self.book.register_stream(stream, width)
            else:
                self.trace.log(
                    "pane_incompatible", now, base_id,
                    f"stream={stream};width={width};"
                    f"range={rspec.base.num_tuples_total};"
                    f"slide={rspec.slide_tuples};"
                    f"offset={rspec.base.stream_offset}",
                )
        self._live[base_id] = live
        self.trace.log(
            "submit", now, base_id,
            f"period={rspec.period};windows={rspec.num_windows or 'inf'}",
        )
        self._instantiate_next(live)
        return AdmissionResult(
            True, report, base_id, decision=decision,
            shed_fraction=shed_fraction, error_bound=error_bound,
            proposal=proposal,
        )

    def withdraw(self, base_id: str) -> None:
        """Remove a live query mid-run: active windows are deleted at the
        next between-batch instant (§4.2), future windows never open."""
        live = self._live[base_id]
        if live.withdrawn:
            return
        now = self.now
        live.withdrawn = True
        for rt in live.runtimes:
            if not rt.completed and rt.spec.delete_time is None:
                rt.spec.delete_time = now
                self._core.notify(rt)
        if self._ledger is not None:
            for rt in live.runtimes:
                if not rt.completed:
                    self._ledger.discard(rt.q.query_id)
            for q in live.pending_static:
                self._ledger.discard(q.query_id)
        if self.book is not None:
            # Release the withdrawn windows' pane references so shared
            # panes they alone were pinning get evicted.
            for rt in live.runtimes:
                if not rt.completed:
                    self.book.withdraw(rt.q.query_id)
            for q in live.pending_static:
                self.book.withdraw(q.query_id)
            if live.rspec.base.stream is not None:
                # Surviving windows must stop amortizing scans across a
                # sharer that just left: re-amortize their SharedCostModels
                # AND re-size their MinBatches — remaining-cost and laxity
                # recompute from the live model at every decision instant,
                # but a MinBatch sized under the cheaper pre-withdraw
                # amortization can now cost more than C_max per batch,
                # breaking the §4.2-4.3 blocking bound for everyone else.
                stream = live.rspec.base.stream
                self._resync_sharers(stream)
                self._resize_stream_minbatches(stream, now)
        # Predictive bookkeeping dies with the windows: pending forecasts
        # of never-closing windows are unscoreable, and unconsumed
        # pre-warms are forecast misses (the demand never ran).
        for qid in ([rt.q.query_id for rt in live.runtimes]
                    + [q.query_id for q in live.pending_static]):
            self._pending_forecasts.pop(qid, None)
            self._proactive.pop(qid, None)
            if self.book is not None and qid in self._prewarmed:
                self.book.discard_prewarm(_PREWARM_TAG + qid)
                self._prewarmed.discard(qid)
        live.pending_static.clear()
        self.trace.log("withdraw", now, base_id)

    def _resize_stream_minbatches(self, stream: str, now: float) -> None:
        """Re-run MinBatch sizing for every live runtime on ``stream`` (its
        amortized cost just changed — a sharer joined or left)."""
        hook = getattr(self.policy, "on_recalibrate", None)
        if hook is None:
            return
        for l in self._live.values():
            if l.withdrawn or l.rspec.base.stream != stream:
                continue
            for rt in l.runtimes:
                if rt.admitted and not (rt.completed or rt.deleted):
                    try:
                        hook(rt, now)
                    except InfeasibleDeadline:
                        pass  # keep the previous MinBatch; sizing is advisory
                    self._core.notify(rt)
                    if (self._ledger is not None
                            and self._ledger.discard(rt.q.query_id)):
                        self._ledger.add(rt.q)

    # ------------------------------------------------------------------
    # Overload control (repro.core.overload)
    # ------------------------------------------------------------------
    def _overload_admit(self, live, first: Query, snaps: List[Query],
                        c_max: float, now: float):
        """The infeasible-admission escalation ladder: minimum load shed
        (lowest tiers first, incoming and actives alike), else smallest
        deadline extension through the ``on_renegotiate`` hook, else None
        (fall through to rejection).  Returns ``(decision, report,
        shed_fraction, error_bound, proposal)`` and mutates ``live`` (and
        shed active runtimes) accordingly."""
        cfg = self.overload
        rspec = live.rspec
        base_id = rspec.base_id
        plan = plan_shedding([first, *snaps], c_max=c_max, now=now,
                             config=cfg, prior_shed=self._prior_shed(),
                             tenancy=self.tenancy)
        if plan.feasible and not plan.fractions:
            return "admit", plan.report, 0.0, 0.0, None
        # ``plan.report`` explains every rejection below: it is the FAILING
        # feasibility report (shedding could not restore the conditions).
        if plan.feasible and plan.fractions:
            f_in = plan.fractions.get(first.query_id, 0.0)
            shed_fr = bound = 0.0
            if f_in > 0:
                thin_base, shed_fr, bound = apply_shed(
                    rspec.base, f_in, seed=cfg.seed)
                live.rspec = dataclasses.replace(rspec, base=thin_base)
                live.shed_fraction, live.error_bound = shed_fr, bound
                # A thinned window no longer lands on the stream's pane
                # grid: run it unshared rather than promising amortization
                # the sampled scan cannot realize.
                live.pane_ok = False
                self.trace.log(
                    "shed", now, base_id,
                    f"fraction={shed_fr:.4f};error_bound={bound:.4f}",
                )
            for qid, f in plan.fractions.items():
                if qid != first.query_id:
                    self._shed_active(qid, f, now)
            return "shed", plan.report, shed_fr, bound, None
        if cfg.renegotiate:
            proposal = min_deadline_extension(
                first, snaps, c_max=c_max, now=now, config=cfg)
            if proposal is not None:
                accepted = (bool(self.on_renegotiate(proposal))
                            if self.on_renegotiate is not None else False)
                self.trace.log(
                    "renegotiate", now, base_id,
                    f"extension={proposal.extension:.6g};accepted={accepted}",
                )
                if accepted:
                    ext = proposal.extension
                    live.rspec = dataclasses.replace(
                        rspec,
                        deadline_offset=rspec.deadline_offset + ext,
                        base=dataclasses.replace(
                            rspec.base, deadline=rspec.base.deadline + ext),
                    )
                    return "renegotiate", proposal.report, 0.0, 0.0, proposal
                return "reject", plan.report, 0.0, 0.0, proposal
        return "reject", plan.report, 0.0, 0.0, None

    def _shed_active(self, qid: str, fraction: float, now: float) -> None:
        """Apply a shed fraction to one LIVE window (dynamic runtime or
        pending static window) — the dropped tuples are never ingested."""
        for l in self._live.values():
            if l.withdrawn:
                continue
            for rt in l.runtimes:
                if rt.q.query_id == qid and not (rt.completed or rt.deleted):
                    self._apply_runtime_shed(rt, fraction, now)
                    return
            for i, q in enumerate(l.pending_static):
                if q.query_id == qid:
                    thin, cum, bound = apply_shed(
                        q, fraction, seed=self._shed_seed)
                    if thin is not q:
                        l.pending_static[i] = thin
                        self._window_shed[qid] = (cum, bound)
                        if (self._ledger is not None
                                and self._ledger.discard(qid)):
                            self._ledger.add(thin)
                        self.trace.log(
                            "shed", now, qid,
                            f"fraction={cum:.4f};error_bound={bound:.4f}",
                        )
                    return

    def _apply_runtime_shed(self, rt: QueryRuntime, fraction: float,
                            now: float) -> None:
        thin, cum, bound = apply_shed(rt.q, fraction, processed=rt.processed,
                                      seed=self._shed_seed)
        if thin is rt.q:
            return
        rt.spec.query = thin
        truth = rt.spec.truth
        if truth is not None and truth.num_tuples_total > thin.num_tuples_total:
            # Shedding is an actuation: the dropped tuples are never
            # ingested, so the TRUE arrival the loop polls must be the
            # sampled stream too.
            keep = thin.num_tuples_total - rt.processed
            tail = truth.num_tuples_total - rt.processed
            rt.spec.truth = ThinnedArrival(
                base=truth, keep=max(0, min(keep, tail)), prefix=rt.processed,
                seed=self._shed_seed)
        rt.spec.shed_fraction, rt.spec.error_bound = cum, bound
        self.trace.log("shed", now, rt.q.query_id,
                       f"fraction={cum:.4f};error_bound={bound:.4f}")
        hook = getattr(self.policy, "on_shed", None)
        if hook is not None and rt.admitted:
            try:
                hook(rt, now)
            except InfeasibleDeadline:
                pass  # keep the previous MinBatch; sizing is advisory
        self._core.notify(rt)
        if self._ledger is not None and self._ledger.discard(rt.q.query_id):
            self._ledger.add(rt.q)

    def rebalance(self):
        """Mid-run overload response: when cost drift (recalibration) or a
        mis-sized admission leaves the LIVE set infeasible, shed the minimum
        from the lowest tiers to restore the necessary conditions.  Returns
        the ``SheddingPlan`` applied, or None when overload control is off
        or the live set is already feasible.  Called automatically after
        every recalibration refit; safe to call by hand at any time."""
        if self.overload is None:
            return None
        now = self.now
        snaps = self._active_snapshot()
        c_max = self.c_max if self.c_max is not None else float("inf")
        ok = overload_check(snaps, c_max=c_max, now=now).feasible
        if ok and self.tenancy is not None:
            ok = tenant_quota_condition(snaps, self.tenancy, now).feasible
        if ok:
            return None
        plan = plan_shedding(snaps, c_max=c_max, now=now,
                             config=self.overload,
                             prior_shed=self._prior_shed(),
                             tenancy=self.tenancy)
        if plan.feasible:
            for qid, f in plan.fractions.items():
                self._shed_active(qid, f, now)
        return plan

    def set_quota(self, tenant: str,
                  quota: Optional[TenantQuota] = None):
        """Set, replace or (``quota=None``) remove one tenant's quota at
        run time, then ``rebalance()`` so a tightened quota immediately
        sheds that tenant's own live windows against its new share.  Logged
        as a ``"quota"`` session event; enables tenancy on first use if the
        session was built without ``tenancy=``.  Returns the applied
        ``SheddingPlan`` (None when nothing had to move)."""
        if self.tenancy is None:
            self.tenancy = TenancyConfig()
        if quota is None:
            self.tenancy.quotas.pop(tenant, None)
            detail = "removed"
        else:
            self.tenancy.quotas[tenant] = quota
            detail = (f"weight={quota.weight:.6g};"
                      f"capacity={quota.capacity};rate={quota.rate}")
        self.trace.log("quota", self.now, tenant, detail)
        return self.rebalance()

    @property
    def _shed_seed(self) -> Optional[int]:
        """Sampling-phase seed every session-made ``ThinnedArrival`` uses
        (``OverloadConfig.seed``; None == historical phase 0)."""
        return None if self.overload is None else self.overload.seed

    def _prior_shed(self) -> Dict[str, float]:
        """Cumulative already-shed fraction per live window — snapshots
        erase the thinned arrival history, so the shed planner needs it
        supplied to keep repeated rounds within the configured caps."""
        from .overload import existing_shed

        out: Dict[str, float] = {}
        for l in self._live.values():
            if l.withdrawn:
                continue
            for rt in l.runtimes:
                if not (rt.completed or rt.deleted):
                    f = existing_shed(rt.q)
                    if f > 0:
                        out[rt.q.query_id] = f
            for q in l.pending_static:
                f = existing_shed(q)
                if f > 0:
                    out[q.query_id] = f
        return out

    # ------------------------------------------------------------------
    # Predictive scheduling (repro.core.forecast)
    # ------------------------------------------------------------------
    def history(
        self, base_id: Optional[str] = None,
    ) -> Union[SpecHistory, Dict[str, SpecHistory]]:
        """Public per-spec observation record: what the session has LEARNED
        about its recurring queries.

        For each spec: the per-window realized arrival observations
        (count, mean rate, burstiness — collected at every window close,
        with or without ``forecast=``), the calibration feedback loop's
        cost samples (``(num_tuples, observed_cost)`` batch pairs and
        ``(num_batches, observed_cost)`` final-aggregation pairs; empty
        without ``calibrate=True``), and the admission-time degradation in
        force.  This is the supported read path for consumers — the
        ``_LiveSpec``/calibrator buffers behind it are internals.

        With ``base_id`` returns that spec's ``SpecHistory`` (KeyError for
        unknown ids); without, a dict over every spec ever submitted
        (withdrawn ones included — their history remains observable).
        """
        if base_id is not None:
            return self._spec_history(self._live[base_id])
        return {b: self._spec_history(l) for b, l in self._live.items()}

    def _spec_history(self, live: _LiveSpec) -> SpecHistory:
        cal = live.calibrator
        return SpecHistory(
            base_id=live.base_id,
            arrivals=tuple(live.history),
            cost_samples=cal.samples if cal is not None else (),
            agg_samples=cal.agg_samples if cal is not None else (),
            shed_fraction=live.shed_fraction,
            error_bound=live.error_bound,
        )

    def forecaster(self, base_id: str) -> Optional[ArrivalForecaster]:
        """The live ``ArrivalForecaster`` of ``base_id`` (None unless the
        session runs with ``forecast=``)."""
        return self._live[base_id].forecaster

    def _proactive_replan(
        self, live: _LiveSpec, w: int, q: Query,
    ) -> Tuple[Query, Optional[Tuple[float, float]]]:
        """Window roll-over under forecasting: forecast window ``w``'s
        arrivals and, when the forecast burst would leave the live set
        infeasible, shed the new window NOW — before the burst lands —
        instead of waiting for the reactive path to fire mid-burst.

        Returns ``(query, None)`` when nothing was shed, else ``(thinned
        query, (cumulative_fraction, error_bound))``.  Only the NEW
        window's own planned fraction is actuated: proactively thinning
        OTHER live queries on a forecast would not be refundable once they
        process sampled prefixes, so active queries stay with the reactive
        machinery (``rebalance``/admission), which this window's trimmed
        demand now helps avoid."""
        fcr = live.forecaster
        if fcr is None or not fcr.ready or live.withdrawn:
            return q, None
        fc = fcr.forecast(w)
        if fc is None:
            return q, None
        # Score every acted-era forecast at window close (band check), even
        # ones a hold kept from acting — a held forecaster must be able to
        # EARN the hold release by landing back inside its band.
        self._pending_forecasts[q.query_id] = (live, fc)
        if (live.forecast_hold or self.overload is None or not q.shed
                or fc.lower <= 0):
            return q, None
        fq = forecast_query(q, fc)
        if fq is q:
            return q, None  # no burst compression to act on
        now = self.now
        c_max = self.c_max if self.c_max is not None else float("inf")
        snaps = self._active_snapshot()
        probe = [fq, *snaps]
        if (overload_check(probe, c_max=c_max, now=now).feasible
                and tiered_work_demand_condition(probe, now).feasible
                and (self.tenancy is None or tenant_quota_condition(
                    probe, self.tenancy, now).feasible)):
            return q, None  # the forecast burst fits — nothing to do
        plan = plan_shedding(probe, c_max=c_max, now=now,
                             config=self.overload,
                             prior_shed=self._prior_shed(),
                             tenancy=self.tenancy)
        if not plan.feasible:
            return q, None  # reactive path will deal with the real burst
        f = plan.fractions.get(fq.query_id, 0.0)
        if f <= 0:
            return q, None
        thin, cum, bound = apply_shed(q, f, seed=live.shed_seed)
        if thin is q:
            return q, None
        bs = fc.burst_span(q.wind_start, q.wind_end)
        check_at = (q.wind_end - bs) + self.forecast.miss_check_frac * bs
        self._proactive[q.query_id] = _ProactiveShed(
            live=live, forecast=fc, check_at=check_at, fraction=cum,
            error_bound=bound, orig_query=q, orig_truth=live.window_truth(w),
        )
        self.trace.log(
            "forecast_shed", now, q.query_id,
            f"fraction={cum:.4f};error_bound={bound:.4f};"
            f"predicted={fc.tuples:.1f};band=[{fc.lower:.1f},{fc.upper:.1f}]",
        )
        return thin, (cum, bound)

    def _forecast_review(self) -> None:
        """Mid-window forecast-miss check: once ``miss_check_frac`` of a
        proactively-shed window's forecast burst should have arrived,
        realized arrivals below the expected curve (lower band) mean the
        burst is NOT materializing — the shed was premature.  Record the
        miss, hold the forecaster from further action, and refund the shed
        when the window has not started consuming its sampled stream."""
        if not self._proactive:
            return
        now = self.now
        for qid, rec in self._proactive.items():
            if rec.checked or now < rec.check_at - EPS:
                continue
            rec.checked = True
            q0 = rec.orig_query
            offered = offered_arrival(
                rec.orig_truth if rec.orig_truth is not None else q0.arrival)
            actual = offered.tuples_available(now)
            expected = rec.forecast.expected_by(now, q0.wind_start,
                                                q0.wind_end)
            expected *= self.forecast.miss_tolerance
            if actual + EPS >= expected:
                continue  # burst on track (within tolerance) — keep the shed
            rec.missed = True
            rec.live.forecaster.record_miss()
            rec.live.forecast_hold = True
            self._refund_forecast_shed(qid, rec, now)

    def _refund_forecast_shed(self, qid: str, rec: _ProactiveShed,
                              now: float) -> None:
        """Undo one window's proactive shed (the forecast missed): restore
        the original window query/truth so the tuples the shed would have
        dropped are ingested after all.  Only safe while nothing of the
        sampled stream has been processed — beyond that the kept-index
        sampling is already baked into results and the shed stands."""
        live = rec.live
        for rt in live.runtimes:
            if rt.q.query_id != qid or rt.completed or rt.deleted:
                continue
            if rt.processed > 0:
                return  # sampled prefix consumed — refund no longer sound
            rt.spec.query = rec.orig_query
            rt.spec.truth = rec.orig_truth
            rt.spec.shed_fraction = live.shed_fraction
            rt.spec.error_bound = live.error_bound
            self.trace.log("forecast_refund", now, qid,
                           f"fraction={rec.fraction:.4f}")
            hook = getattr(self.policy, "on_shed", None)
            if hook is not None and rt.admitted:
                try:
                    hook(rt, now)  # re-size MinBatch for the restored total
                except InfeasibleDeadline:
                    pass  # keep the previous MinBatch; sizing is advisory
            self._core.notify(rt)
            if (self._ledger is not None
                    and self._ledger.discard(rt.q.query_id)):
                self._ledger.add(rt.q)
            return
        for i, q in enumerate(live.pending_static):
            if q.query_id == qid:
                live.pending_static[i] = rec.orig_query
                if self._ledger is not None and self._ledger.discard(qid):
                    self._ledger.add(rec.orig_query)
                self._window_shed.pop(qid, None)
                self.trace.log("forecast_refund", now, qid,
                               f"fraction={rec.fraction:.4f}")
                return

    def _prewarm(self) -> None:
        """Speculative pane pre-warming: the loop just idled, so spend the
        free capacity computing pane partials for registered FUTURE windows
        of specs whose forecaster has earned trust — when the window later
        runs, its scans become cache hits.  Deposits are refcount-tagged
        speculative (``repro.core.panes``): consumed ones convert to
        ``speculative_hits``, unconsumed ones are written off as
        ``speculative_misses`` when the window closes or is withdrawn."""
        if (self.book is None or self.forecast is None
                or not self.forecast.prewarm):
            return
        now = self.now
        for live in self._live.values():
            fcr = live.forecaster
            if (live.withdrawn or not live.pane_ok or fcr is None
                    or not fcr.ready or live.forecast_hold):
                continue
            for rt in live.runtimes:
                q = rt.q
                if (rt.completed or rt.deleted or rt.processed > 0
                        or q.stream is None
                        or q.wind_start <= now + EPS
                        or q.query_id in self._prewarmed
                        or q.query_id in self._proactive
                        or not self.book.knows(q.query_id)):
                    continue
                n = self.book.prewarm(q, _PREWARM_TAG + q.query_id)
                if n:
                    self._prewarmed.add(q.query_id)
                    self.trace.log("pane_prewarm", now, q.query_id,
                                   f"panes={n}")

    def _on_window_close(self, outcome: QueryOutcome) -> None:
        """Close-time bookkeeping of one window: observe its realized
        arrivals into the spec's history, fold them into the forecaster,
        score the window's forecast against its confidence band, and write
        off any unconsumed speculative pre-warm."""
        qid = outcome.query_id
        rec = self._window_truths.pop(qid, None)
        if rec is None:
            return  # not a session window (defensive)
        live, offered, w, ws, we = rec
        obs = observe_arrival(offered, window=w, wind_start=ws, wind_end=we)
        live.history.append(obs)
        if len(live.history) > _HISTORY_CAP:
            del live.history[0]
        fcr = live.forecaster
        pending = self._pending_forecasts.pop(qid, None)
        pro = self._proactive.pop(qid, None)
        if fcr is not None:
            if pending is not None and not (pro is not None and pro.missed):
                fc = pending[1]
                if fc.contains(obs.num_tuples):
                    fcr.record_hit()
                    live.forecast_hold = False
                else:
                    fcr.record_miss()
                    live.forecast_hold = True
            fcr.observe(obs)
        if self.book is not None and qid in self._prewarmed:
            self.book.discard_prewarm(_PREWARM_TAG + qid)
            self._prewarmed.discard(qid)

    # ------------------------------------------------------------------
    # Driving the loop
    # ------------------------------------------------------------------
    def run_until(self, horizon: float, max_steps: int = 1_000_000) -> SessionTrace:
        """Advance the session's continuous timeline to ``horizon``,
        processing every decision instant on the way (window roll-overs,
        admissions, batches, recalibrations)."""
        if math.isinf(horizon):
            open_ended = [l.base_id for l in self._live.values() if l.open_ended]
            if open_ended:
                raise ValueError(
                    f"open-ended specs {open_ended} never drain; use a "
                    "finite horizon (run_until) or withdraw them first"
                )
        self._ensure_started(horizon)
        if self._is_dynamic:
            self._run_dynamic_until(horizon, max_steps)
        else:
            self._run_static_until(horizon, max_steps)
        self._drain_outcome_events()
        return self.trace

    def run(self, max_steps: int = 1_000_000) -> SessionTrace:
        """Drain every admitted window (bounded specs only)."""
        return self.run_until(math.inf, max_steps=max_steps)

    # -- dynamic path ---------------------------------------------------
    def _run_dynamic_until(self, horizon: float, max_steps: int) -> None:
        for _ in range(max_steps):
            self._replenish()
            status = self._core.tick(horizon)
            self._drain_outcome_events()
            if status == "wait":
                # The loop just idled forward to the next readiness
                # instant: free capacity forecast-driven pane pre-warming
                # may spend (no-op unless forecast= AND sharing=).
                self._prewarm()
            if status == "horizon":
                return
            if status == "stop" or (
                status == "done"
                and all(l.exhausted for l in self._live.values())
            ):
                # Drained (or the policy declared nothing will ever be
                # ready): reflect the full passage of time to the horizon so
                # later submissions join at the session's current instant.
                if math.isfinite(horizon):
                    self.executor.advance(horizon)
                return
        raise RuntimeError(f"session exceeded {max_steps} steps before "
                           f"reaching horizon {horizon}")

    # -- static path ----------------------------------------------------
    def _run_static_until(self, horizon: float, max_steps: int) -> None:
        from .runtime import execute_plan

        for _ in range(max_steps):
            self._replenish(horizon)
            q, live = self._next_static(horizon)
            if q is None:
                # Nothing left at or before the horizon; reflect the passage
                # of time so admissions submitted later see a current clock.
                if math.isfinite(horizon):
                    nxt = self._earliest_static()
                    self.executor.advance(
                        horizon if nxt is None else min(horizon, nxt)
                    )
                return
            live.pending_static.remove(q)
            window = split_window_id(q.query_id)[1] or 0
            truth = live.window_truth(window)
            if (truth is not None
                    and truth.num_tuples_total > q.num_tuples_total):
                # Window-level shed (``_shed_active`` or a proactive
                # forecast shed thinned this one pending window): the true
                # stream must deliver the sampled tuples only — shedding
                # happens at ingestion.
                truth = ThinnedArrival(base=truth, keep=q.num_tuples_total,
                                       seed=self._shed_seed)
            try:
                plan = self.policy.plan(q)[q.query_id]
            except InfeasibleDeadline as e:
                # An unplannable window is a MISS, not a non-event: record
                # an outcome (never completes, full shortfall) so met/total
                # metrics stay honest, plus the reason as its own event.
                self.trace.log("window_infeasible",
                               max(self.now, q.submit_time), q.query_id,
                               str(e))
                self.trace.outcomes.append(QueryOutcome(
                    query_id=q.query_id,
                    completion_time=math.inf,
                    deadline=q.deadline,
                    total_cost=0.0,
                    num_batches=0,
                    tuples_processed=0,
                    num_tuples_total=q.num_tuples_total,
                    tenant=q.tenant,
                ))
                self._drain_outcome_events()
                continue
            shed_fr, err_b = self._window_shed.get(
                q.query_id, (live.shed_fraction, live.error_bound))
            execute_plan(
                q, plan, self.executor, truth=truth,
                trace=self.trace, on_batch=self._observe,
                c_max=self.c_max, carryover=True,
                shed_fraction=shed_fr, error_bound=err_b,
            )
            self._drain_outcome_events()
        raise RuntimeError(f"session exceeded {max_steps} steps before "
                           f"reaching horizon {horizon}")

    def _next_static(self, horizon: float):
        best, best_live = None, None
        for live in self._live.values():
            for q in live.pending_static:
                if q.submit_time > horizon + EPS:
                    continue
                if best is None or q.submit_time < best.submit_time:
                    best, best_live = q, live
        return best, best_live

    def _earliest_static(self) -> Optional[float]:
        starts = [q.submit_time for l in self._live.values()
                  for q in l.pending_static]
        return min(starts) if starts else None

    # ------------------------------------------------------------------
    # Window roll-over
    # ------------------------------------------------------------------
    def _cascade_ready(self, live: _LiveSpec, w: int) -> bool:
        """A cascaded window (its spec names ``upstream=``) only opens once
        every upstream window its span covers has CLOSED — the rollup
        consumes the upstream's per-window outputs, so opening earlier
        would read a partial cascade.  Upstream windows are covered when
        their window end falls within the downstream window's span.  An
        unknown or withdrawn upstream ungates (nothing left to wait for)."""
        up = live.rspec.base.upstream
        if up is None:
            return True
        uplive = self._live.get(up)
        if uplive is None or uplive.withdrawn:
            return True
        ur = uplive.rspec
        q_end = live.rspec.base.wind_end + w * live.rspec.period
        kmax = math.floor((q_end - ur.base.wind_end) / ur.period + EPS)
        if ur.num_windows is not None:
            kmax = min(kmax, ur.num_windows - 1)
        if kmax < 0:
            return True
        if uplive.next_window <= kmax:
            return False  # a covered upstream window has not even opened
        for rt in uplive.runtimes:
            uw = split_window_id(rt.q.query_id)[1] or 0
            if uw <= kmax and not (rt.completed or rt.deleted):
                return False
        for uq in uplive.pending_static:
            if (split_window_id(uq.query_id)[1] or 0) <= kmax:
                return False
        return True

    def _cascade_defer(self, live: _LiveSpec, w: int) -> None:
        """First deferral of a cascaded window: pre-subscribe its panes so
        the upstream windows' reference-counted partials survive in the
        ``PaneStore`` until the rollup materializes, and log one
        ``"cascade_defer"`` event.  Subsequent deferrals of the same window
        are silent — ``_replenish`` retries every heartbeat."""
        qid = (live.rspec.base_id if live.rspec.num_windows == 1
               else window_query_id(live.rspec.base_id, w))
        if qid in self._cascade_wait:
            return
        self._cascade_wait.add(qid)
        if (self.book is not None and live.pane_ok
                and live.rspec.base.stream is not None
                and live.rspec.base.stream in self.book.widths):
            q = live.rspec.window_query(w, cost_model=live.cost_model())
            self.book.register(q)
        self.trace.log("cascade_defer", self.now, qid,
                       f"upstream={live.rspec.base.upstream}")

    def _instantiate_next(self, live: _LiveSpec) -> None:
        if live.exhausted:
            return
        w = live.next_window
        if not self._cascade_ready(live, w):
            self._cascade_defer(live, w)
            return
        q = live.rspec.window_query(w, cost_model=live.cost_model())
        truth = live.window_truth(w)
        # Arrival history is collected for EVERY window (the fuel of
        # ``history()`` and forecasting): remember the offered stream —
        # shedding unwrapped — and observe it once the window closes.
        self._window_truths[q.query_id] = (
            live,
            offered_arrival(truth if truth is not None else q.arrival),
            w,
            q.wind_start,
            q.wind_end,
        )
        q, proactive = self._proactive_replan(live, w, q)
        if (proactive is not None and truth is not None
                and truth.num_tuples_total > q.num_tuples_total):
            # A proactive shed is the same actuation as a reactive one:
            # the dropped tuples are never ingested.
            truth = ThinnedArrival(base=truth, keep=q.num_tuples_total,
                                   seed=live.shed_seed)
        if (self.book is not None and q.stream is not None and live.pane_ok
                and proactive is None):
            # Shared stream with actual overlap (other live specs and/or
            # this spec's own sliding windows): the window query plans and
            # runs under the amortized shared cost, and its panes join the
            # session-wide store — partials cached by earlier windows are
            # reused here (cache carry-over across recurring windows).
            # A proactively-shed window skips this: its thinned scan no
            # longer lands on the pane grid (same rule as admission shed).
            k = self._stream_sharers(q.stream)
            if k >= 2:
                q.cost_model = SharedCostModel(
                    q.cost_model, sharers=k,
                    pane_tuples=self.book.widths[q.stream],
                )
                self.book.register(q)
                self._shared_models.setdefault(q.stream, []).append(
                    (q.query_id, q.cost_model))
                self._resync_sharers(q.stream)
        live.next_window += 1
        self.trace.log("window_open", q.submit_time, q.query_id,
                       "" if q.upstream is None
                       else f"upstream={q.upstream}")
        if self._ledger is not None:
            # One ledger row per open window, in deadline position; the
            # post-window work is computed lazily at the first check.
            self._ledger.add(q)
        if self._is_dynamic:
            shed_fr, err_b = (proactive if proactive is not None
                              else (live.shed_fraction, live.error_bound))
            spec = DynamicQuerySpec(
                query=q,
                truth=truth,
                num_groups=live.rspec.num_groups,
                delete_time=live.rspec.delete_time,
                total_known=live.rspec.total_known,
                shed_fraction=shed_fr,
                error_bound=err_b,
            )
            rt = QueryRuntime(spec=spec)
            live.runtimes.append(rt)
            self._state.runtimes.append(rt)
        else:
            if proactive is not None:
                self._window_shed[q.query_id] = proactive
            live.pending_static.append(q)

    def _replenish(self, horizon: float = math.inf) -> None:
        """Keep the NEXT window of every live spec instantiated (lazy
        roll-over: open-ended recurrence never materializes more than one
        future window ahead).  The static path additionally materializes
        every window opening before ``horizon``.

        Doubles as the predictive heartbeat: pending forecast-miss checks
        run first, so a refund lands before the loop's next decision."""
        self._forecast_review()
        for live in self._live.values():
            if self._is_dynamic:
                last = live.runtimes[-1] if live.runtimes else None
                if (last is None or last.admitted) and not live.exhausted:
                    self._instantiate_next(live)
            else:
                while (
                    not live.exhausted
                    and live.rspec.window_start(live.next_window)
                    <= horizon + EPS
                ):
                    before = live.next_window
                    self._instantiate_next(live)
                    if live.next_window == before:
                        break  # cascade-deferred: retry next heartbeat

    # ------------------------------------------------------------------
    # Calibration feedback
    # ------------------------------------------------------------------
    def _observe(self, ex: BatchExecution) -> None:
        shared = False
        if self.book is not None:
            shared = self.book.knows(ex.query_id)
            self.book.observe(ex)
        live = self._live.get(split_window_id(ex.query_id)[0])
        if live is None or live.calibrator is None or shared:
            # Shared windows skip calibration feedback: the modelled batch
            # durations are amortized shared costs, which would mis-train a
            # calibrator that predicts the UNSHARED base (see docs/API.md,
            # "Pane sharing" — compose the two only on real backends whose
            # wall seconds measure actual shared work).
            return
        cal = live.calibrator
        if ex.kind == "final_agg":
            # Observed duration: measured wall seconds on real backends,
            # modelled (true) duration in simulation.
            wall = getattr(self.executor, "last_agg_wall", None)
            nb = self._batch_counts.pop(ex.query_id, 0)
            cal.observe_agg(nb, wall if wall is not None else ex.end - ex.start)
            return
        if ex.kind != "batch" or ex.num_tuples <= 0:
            return
        self._batch_counts[ex.query_id] = (
            self._batch_counts.get(ex.query_id, 0) + 1
        )
        wall = getattr(self.executor, "last_batch_wall", None)
        cal.observe(ex.num_tuples,
                    wall if wall is not None else ex.end - ex.start,
                    worker=ex.worker or None)
        drift = cal.drift()
        if drift > self.drift_threshold and cal.num_observations >= cal.min_samples:
            self._recalibrate(live, drift)

    def _recalibrate(self, live: _LiveSpec, drift: float) -> None:
        """Drift crossed the threshold: refit NOW and replan future work.

        Dynamic runtimes get their MinBatch re-sized via the policy's
        ``on_recalibrate`` hook; static windows pick the refreshed model up
        at plan time (plans are made at window start).  The NINP invariant
        is untouched — only future sizing/ordering changes.
        """
        cal = live.calibrator
        if not cal.refit_now():
            return
        now = self.now
        self.trace.log(
            "recalibrate", now, live.base_id,
            f"drift={drift:.4f};refit={cal.refits};obs={cal.num_observations}",
        )
        hook = getattr(self.policy, "on_recalibrate", None)
        if hook is not None:
            for rt in live.runtimes:
                if rt.admitted and not (rt.completed or rt.deleted):
                    try:
                        hook(rt, now)
                    except InfeasibleDeadline:
                        pass  # keep the previous MinBatch; sizing is advisory
                    self._core.notify(rt)
        if self._ledger is not None:
            # The refit changed the shared cost model underneath every row
            # of this spec: re-read the cached work quantities.
            for rt in live.runtimes:
                if (not (rt.completed or rt.deleted)
                        and self._ledger.discard(rt.q.query_id)):
                    self._ledger.add(rt.q)
            for i, q in enumerate(live.pending_static):
                if self._ledger.discard(q.query_id):
                    self._ledger.add(q)
        # Drift can leave the corrected workload infeasible — the overload
        # path (when enabled) sheds the minimum from the lowest tiers to
        # restore the necessary conditions instead of riding into misses.
        self.rebalance()

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _ensure_started(self, horizon: float) -> None:
        """First drive: anchor the timeline at the earliest submitted work
        (matching ``runtime.run``'s start), unless ``start_time`` pinned it."""
        if self._started:
            return
        starts: List[float] = []
        if self._is_dynamic:
            starts = [rt.q.submit_time for rt in self._state.runtimes]
        else:
            starts = [q.submit_time for l in self._live.values()
                      for q in l.pending_static]
        start = min(starts, default=0.0)
        if math.isfinite(horizon):
            start = min(start, horizon)
        self.executor.reset(start)
        self._started = True

    def _register_true_cost(self, rspec: RecurringQuerySpec) -> None:
        if rspec.true_cost_model is None:
            return
        backend = getattr(self.executor, "backend", self.executor)
        if isinstance(backend, OracleCostExecutor):
            backend.true_models[rspec.base_id] = rspec.true_cost_model
        else:
            raise TypeError(
                "true_cost_model requires an OracleCostExecutor backend "
                f"(got {type(backend).__name__}); real backends exhibit "
                "their own true costs"
            )

    def _active_snapshot(self) -> List[Query]:
        """Remaining-work snapshots of everything currently admitted, for
        the admission pre-flight."""
        now = self.now
        snaps: List[Query] = []
        for live in self._live.values():
            if live.withdrawn:
                continue
            for rt in live.runtimes:
                if rt.completed or rt.deleted:
                    continue
                snap = _remaining_query(rt, now)
                if snap is not None:
                    snaps.append(snap)
            snaps.extend(live.pending_static)
        return _relax_doomed(snaps, now)

    def _drain_outcome_events(self) -> None:
        while self._outcomes_seen < len(self.trace.outcomes):
            o = self.trace.outcomes[self._outcomes_seen]
            self._outcomes_seen += 1
            self.trace.log(
                "window_close", o.completion_time, o.query_id,
                f"met={o.met_deadline};shortfall={o.shortfall}",
            )
            if self._ledger is not None:
                self._ledger.discard(o.query_id)
            self._on_window_close(o)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (
            f"SessionRuntime(policy={getattr(self.policy, 'name', '?')!r}, "
            f"now={self.now:.6g}, live={self.live_ids})"
        )


def _pane_compatible(rspec: RecurringQuerySpec, width: int) -> bool:
    """True when ``width`` divides the spec's window range, slide and start
    offset — i.e. every window of the spec is an exact union of panes on
    the stream's grid.  Anything else would subscribe few or zero panes
    while still advertising amortized costs."""
    if width < 1:
        return False
    slide = rspec.slide_tuples or 0
    return (
        rspec.base.num_tuples_total % width == 0
        and rspec.base.stream_offset % width == 0
        and (slide % width == 0 if slide else True)
    )


def _spec_overlap(rspec: RecurringQuerySpec) -> int:
    """How many windows of ``rspec`` cover one stream pane in steady state:
    ``ceil(range / slide)`` for sliding windows, 1 for tumbling (slide >=
    range) or single-window specs."""
    if rspec.base.stream is None or rspec.num_windows == 1:
        return 1
    slide = rspec.slide_tuples or 0
    if slide <= 0:
        ov = max(rspec.base.num_tuples_total, 1)  # identical windows
    else:
        ov = -(-rspec.base.num_tuples_total // slide)  # ceil
    if rspec.num_windows is not None:
        # No more windows than exist can ever cover one pane.
        ov = min(ov, rspec.num_windows)
    return max(ov, 1)


def _remaining_query(rt: QueryRuntime, now: float) -> Optional[Query]:
    """Snapshot of an in-flight query's REMAINING work as a fresh Query
    (pending tuples with their remaining arrival instants): the live-set
    input to ``admission_check``.  Falls back to the original query above
    ``_SNAPSHOT_CAP`` pending tuples (conservative but still a valid
    necessary-condition input).

    Deadlines already beyond saving are relaxed by the caller
    (``_relax_doomed``) before the snapshot set reaches the admission
    checks."""
    q = rt.q
    remaining = q.num_tuples_total - rt.processed
    if remaining <= 0:
        return None
    if rt.processed == 0 or remaining > _SNAPSHOT_CAP:
        return q
    ts = tuple(
        q.arrival.input_time(k)
        for k in range(rt.processed + 1, q.num_tuples_total + 1)
    )
    return dataclasses.replace(
        q,
        num_tuples_total=remaining,
        arrival=TraceArrival(timestamps=ts),
        wind_start=ts[0],
        wind_end=max(ts[-1], ts[0]),
        submit_time=None,
    )


def _relax_doomed(snaps: List[Query], now: float) -> List[Query]:
    """Relax deadlines that are already beyond saving.

    Processing the snapshot set in EDF order, each query's completion is at
    least ``now`` plus the cumulative minimum work before and including it —
    arrival availability and batching overheads only push it later.  A
    deadline below that lower bound is ALREADY lost, whatever is or is not
    admitted next: leaving it in place would make every deadline-prefix
    containing it infeasible and lock admissions out permanently.  Such
    deadlines are relaxed to the bound — the query's demand still occupies
    the executor in every prefix, but only deadlines that can still be won
    constrain the verdict."""
    order = sorted(snaps, key=lambda q: q.deadline)
    t = now
    relaxed: Dict[int, float] = {}
    for q in order:
        t += q.cost_model.cost(q.num_tuples_total)
        if q.deadline < t:
            relaxed[id(q)] = t
    if not relaxed:
        return snaps
    return [
        dataclasses.replace(q, deadline=relaxed[id(q)])
        if id(q) in relaxed else q
        for q in snaps
    ]
