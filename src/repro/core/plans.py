"""Plan utilities: cost accounting and invariant checking for Schedules.

These are not scheduling schemes — every policy's output (a ``Schedule``)
can be priced with ``plan_cost`` and vetted with ``validate_schedule``
before execution.  They historically lived in ``repro.core.single_query``
(now a deprecation-shim module for the legacy ``schedule_*`` API); this is
their canonical home, so canonical code never has to import through a shim
module.
"""
from __future__ import annotations

from .types import EPS as _EPS, Query, Schedule


def plan_cost(query: Query, plan: Schedule) -> float:
    """Total computation cost of a plan = batch costs + final agg (Eq. 1/4)."""
    cm = query.cost_model
    c = sum(cm.cost(b.num_tuples) for b in plan.batches)
    if plan.num_batches > 1:
        c += cm.agg_cost(plan.num_batches)
    return c


def validate_schedule(query: Query, plan: Schedule) -> None:
    """Assert the plan's invariants (used by tests and before execution):

    * covers all tuples exactly once,
    * batch k starts only after its tuples have arrived,
    * batches do not overlap in time,
    * last batch (+ final agg) completes by the deadline.
    """
    cm, arr = query.cost_model, query.arrival
    if plan.total_tuples != query.num_tuples_total:
        raise AssertionError(
            f"plan covers {plan.total_tuples} != {query.num_tuples_total}"
        )
    done = 0
    prev_end = float("-inf")
    for b in plan.batches:
        done += b.num_tuples
        avail = arr.input_time(done)
        if b.sched_time < avail - _EPS:
            raise AssertionError(
                f"batch at {b.sched_time} needs tuple #{done} available {avail}"
            )
        if b.sched_time < prev_end - _EPS:
            raise AssertionError("overlapping batches")
        prev_end = b.sched_time + cm.cost(b.num_tuples)
    finish = prev_end + (cm.agg_cost(plan.num_batches) if plan.num_batches > 1 else 0.0)
    if finish > query.deadline + 1e-6:
        raise AssertionError(f"finish {finish} > deadline {query.deadline}")
