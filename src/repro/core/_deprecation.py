"""Deprecation plumbing for the legacy ``schedule_*`` free functions.

Under the ``"default"`` warning ACTION a DeprecationWarning shows once per
(module, lineno) — i.e. exactly once per CALL SITE — which is the behaviour
the shims' tests pin down.  Note that plain ``python script.py`` ignores
DeprecationWarning outside ``__main__`` entirely (PEP 565); the warnings are
visible under ``-W default``, pytest's filters, or from __main__ code.
``stacklevel=3`` attributes the warning to the shim's caller:
helper (1) -> shim (2) -> call site (3).
"""
from __future__ import annotations

import warnings


def warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} (see docs/API.md migration table)",
        DeprecationWarning,
        stacklevel=3,
    )
