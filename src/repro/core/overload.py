"""Overload control: priority tiers, bounded-error load shedding and
deadline renegotiation.

The paper's schedulers (§4-§5) assume the workload is schedulable; when the
necessary conditions of ``repro.core.schedulability`` fail — at admission,
or mid-run when cost drift makes remaining deadlines infeasible — the
runtime previously let queries blow their deadlines with full shortfall.
Deadline-aware engines need an explicit overloaded-regime story (Cameo's
priority + reactive degradation; POTUS's predictive shedding): this module
adds a fourth decision dimension — how MUCH of the stream to process — on
top of the paper's when / where / in-what-order:

* **priority tiers** — ``Query.tier`` (0 = highest) is STRICT: the dynamic
  policies never run a ready tier-k query while a ready query of a lower
  tier number exists; within a tier the chosen strategy (LLF/EDF/SJF/RR)
  orders as before.  With every query on the default tier 0 the ordering —
  and every trace — is byte-identical to the tierless runtime.
* **bounded-error load shedding** — ``plan_shedding`` computes the MINIMUM
  shed (uniform tuple sampling, lowest-priority tiers first) that restores
  the necessary schedulability conditions, as a ``SheddingPlan`` of
  per-query drop fractions.  ``apply_shed`` realizes a fraction on a query
  by thinning its arrival (``repro.core.arrivals.ThinnedArrival`` —
  systematic uniform sampling), so every planner, policy and admission
  check transparently sees the smaller workload.  Real backends fetch the
  sampled tuples through the thinned index map and SCALE the aggregates by
  the inverse keep rate (``repro.serve.analytics``), making shed answers
  unbiased estimates whose relative error bound (``shed_error_bound``) is
  reported in ``QueryOutcome.shed_fraction`` / ``error_bound``.
* **deadline renegotiation** — when a query's answer must stay exact
  (``Query.shed=False``), ``min_deadline_extension`` finds the smallest
  deadline extension that makes the workload feasible; a session surfaces
  it as a ``RenegotiationProposal`` through its accept/reject hook and a
  ``"renegotiate"`` session event (``repro.core.session``).

Everything here is advisory arithmetic over the schedulability conditions —
pure functions with no runtime state.  The *enforcement* points are the
tier-aware ``DynamicPolicy.replan`` ordering and the session admission path
(admit / admit-with-shed / renegotiate / reject); both are inert unless
overload control is switched on (``Session(overload=True)``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .arrivals import ThinnedArrival
from .schedulability import FeasibilityReport, admission_check, edf_order
from .tenancy import (TenancyConfig, demand_by_tenant,
                      tenant_quota_condition)
from .types import EPS, Query

__all__ = [
    "OverloadConfig",
    "RenegotiationProposal",
    "SheddingPlan",
    "apply_shed",
    "min_deadline_extension",
    "overload_check",
    "plan_shedding",
    "shed_error_bound",
    "tiered_work_demand_condition",
]

# Shed fractions are searched on a per-mille grid: fine enough that the
# minimum-shed guarantee is within 0.1% of optimal, coarse enough that the
# search (and the reported fractions) stay deterministic and readable.
_SHED_RESOLUTION = 1000


@dataclasses.dataclass(frozen=True)
class OverloadConfig:
    """Knobs of the overload-control subsystem.

    ``max_shed`` caps the tuple fraction any single query may lose;
    ``max_error_bound`` caps the reported relative error bound of a shed
    answer — a shed that would blow either cap is treated as infeasible and
    the admission falls through to renegotiation/rejection.
    ``renegotiate`` gates the deadline-extension path for ``shed=False``
    queries; ``max_extension`` bounds the largest extension ever proposed.

    ``headroom`` over-sheds (and over-extends) past the bare necessary
    conditions by requiring every deadline budget to fit ``1 + headroom``
    times the demanded work.  The conditions are NECESSARY, not sufficient:
    they ignore per-batch overheads, final aggregations and NINP
    quantization (waiting for MinBatches, non-preemptable blocking), so a
    workload shed exactly to the conditions' edge completes a whisker past
    its deadlines.  ``headroom=0`` keeps the pure minimum-shed semantics;
    ~0.2-0.3 absorbs the batching overheads in practice (the overload
    benchmark's setting).  Overload ACTIVATION always uses the untightened
    conditions — headroom only shapes how far a triggered shed goes.

    ``seed`` is threaded into every ``ThinnedArrival`` the session applies
    (``apply_shed(seed=...)``): the systematic sample's random start phase
    becomes an explicit, reproducible choice instead of the fixed phase 0.
    Which tuples a shed keeps never changes plan arithmetic (counts are
    phase-invariant) — only the realized sample; ``None`` (the default)
    keeps the historical phase-0 sampling byte-for-byte.
    """

    max_shed: float = 0.9
    max_error_bound: float = 0.5
    renegotiate: bool = True
    max_extension: float = math.inf
    headroom: float = 0.0
    seed: Optional[int] = None

    def __post_init__(self) -> None:
        if not 0.0 <= self.max_shed < 1.0:
            raise ValueError(f"max_shed must be in [0, 1), got {self.max_shed}")
        if self.max_error_bound <= 0:
            raise ValueError("max_error_bound must be positive")
        if self.max_extension < 0:
            raise ValueError("max_extension must be >= 0")
        if self.headroom < 0:
            raise ValueError("headroom must be >= 0")


@dataclasses.dataclass(frozen=True)
class SheddingPlan:
    """Output of ``plan_shedding``: the minimum shed restoring feasibility.

    ``fractions[qid]`` is the fraction of query ``qid``'s REMAINING tuples
    to drop — only sheddable queries appear, and only with fractions > 0.
    ``error_bounds[qid]`` is the reported relative error bound of the
    resulting estimate (``shed_error_bound`` of the cumulative degradation,
    prior rounds included).  ``feasible`` says whether
    the plan actually restores the necessary conditions: ``False`` means
    even the maximum allowed shed cannot, and ``fractions`` is empty.
    """

    fractions: Dict[str, float]
    error_bounds: Dict[str, float]
    feasible: bool
    report: FeasibilityReport

    def __bool__(self) -> bool:
        return self.feasible

    @property
    def total_shed(self) -> float:
        """Sum of per-query shed fractions (the search's minimization
        objective, lexicographic after tier order)."""
        return sum(self.fractions.values())


@dataclasses.dataclass(frozen=True)
class RenegotiationProposal:
    """The smallest deadline extension that makes ``query_id`` feasible
    against the live set — offered to the submitter for accept/reject."""

    query_id: str
    deadline: float
    proposed_deadline: float
    report: FeasibilityReport

    @property
    def extension(self) -> float:
        return self.proposed_deadline - self.deadline


def shed_error_bound(shed_fraction: float, kept_tuples: int) -> float:
    """Relative error bound of a scaled aggregate estimate after dropping
    ``shed_fraction`` of the tuples uniformly, keeping ``kept_tuples``.

    A sum/count estimated from a uniform sample of ``n`` of ``N`` tuples and
    scaled by ``N/n`` has relative standard error ``sqrt((1-n/N)/n) * cv``
    where ``cv`` is the per-tuple coefficient of variation; we report the
    2-sigma (~95%) bound under the distribution-free normalization
    ``cv = 1``::

        bound = 2 * sqrt(shed_fraction / kept_tuples)

    Monotone increasing in the shed fraction, decreasing in sample size, and
    exactly 0 when nothing was shed — which is what the monotonicity tests
    and the benchmark's error-vs-load curves rely on.  ``kept_tuples == 0``
    (everything shed) reports ``inf``: there is no estimate.
    """
    if shed_fraction <= 0:
        return 0.0
    if kept_tuples <= 0:
        return math.inf
    return 2.0 * math.sqrt(shed_fraction / kept_tuples)


def apply_shed(query: Query, fraction: float, *,
               processed: int = 0,
               seed: Optional[int] = None) -> Tuple[Query, float, float]:
    """Thin ``query`` by dropping ``fraction`` of its not-yet-processed
    tuples uniformly; returns ``(thinned_query, actual_fraction, bound)``.

    ``processed`` tuples (a mid-run shed) are exempt — they already ran.
    Dropping is integral, so ``actual_fraction`` (dropped / original total,
    NOT just the tail) can differ slightly from the request; the reported
    ``bound`` is ``shed_error_bound`` of the realized shed.  ``fraction <=
    0`` returns the query untouched.  Re-shedding an already-thinned query
    composes: the new ``ThinnedArrival`` wraps the previous one.
    ``seed`` picks the systematic sample's start phase
    (``ThinnedArrival.seed`` — reproducible sampling; None = phase 0).
    """
    total = query.num_tuples_total
    tail = total - processed
    if fraction <= 0 or tail <= 0:
        return query, existing_shed(query), shed_error_bound(
            existing_shed(query), total)
    drop = min(int(fraction * tail + 1e-9), tail)
    if drop <= 0:
        return query, existing_shed(query), shed_error_bound(
            existing_shed(query), total)
    keep = tail - drop
    arr = ThinnedArrival(base=query.arrival, keep=keep, prefix=processed,
                         seed=seed)
    new_total = processed + keep
    # Cumulative fraction against the query's ORIGINAL (pre-shed) total.
    orig = original_total(query)
    cum = (orig - new_total) / orig if orig > 0 else 0.0
    thinned = dataclasses.replace(
        query,
        num_tuples_total=new_total,
        arrival=arr,
        wind_end=max(arr.wind_end, query.wind_start),
    )
    return thinned, cum, shed_error_bound(cum, new_total)


def original_total(query: Query) -> int:
    """The query's pre-shed tuple total: unwraps nested ``ThinnedArrival``
    AND ``ShiftedArrival`` layers — windows >= 1 of a shed recurring spec
    carry ``ShiftedArrival(base=ThinnedArrival(...))``, and stopping at the
    shift wrapper would erase the shed history (under-reporting cumulative
    degradation and letting repeated shed rounds compound past the caps)."""
    from .arrivals import ShiftedArrival

    arr = query.arrival
    while isinstance(arr, (ThinnedArrival, ShiftedArrival)):
        arr = arr.base
    return max(arr.num_tuples_total, query.num_tuples_total)


def existing_shed(query: Query) -> float:
    """Fraction already shed from ``query`` (0.0 for unthinned queries)."""
    orig = original_total(query)
    if orig <= 0:
        return 0.0
    return max(0.0, (orig - query.num_tuples_total) / orig)


def _sheddable(q: Query) -> bool:
    # Pane-shared queries are excluded: thinning one subscriber's window
    # would desynchronize it from the stream's pane grid, silently breaking
    # the amortization its SharedCostModel promises.
    from .cost_model import SharedCostModel

    return q.shed and not isinstance(q.cost_model, SharedCostModel)


def tiered_work_demand_condition(
    queries: Sequence[Query], now: Optional[float] = None
) -> FeasibilityReport:
    """Work-demand bound specialized to the TIER-STRICT runtime.

    The generic necessary conditions (``repro.core.schedulability``) hold
    for ANY dispatch strategy — including ones that would starve high
    tiers.  The overload runtime is not any strategy: a ready lower-tier-
    number query always runs first, so before a query can COMPLETE the
    executor must also have absorbed (almost) every strictly-higher-
    priority tuple that arrived first.  The charge horizon is therefore
    ``min(q.deadline, q's last-tuple arrival)`` — a query cannot finish
    before its own stream does, but higher-tier work arriving AFTER the
    query could already be done never delays it.  Edge effects (a final
    batch dispatched just before a higher-tier MinBatch turns ready) can
    make this mildly conservative, so it steers only the shed/renegotiation
    planners on top of ``admission_check``; it is NOT part of the generic
    admission gate, whose verdicts stay policy-agnostic.
    """
    reasons: List[str] = []
    queries = list(queries)
    # Hoisted row caches: the quadratic walk below used to re-derive
    # min_comp_cost (a cost-model call) and the first-tuple instant (an
    # arrival-model call) per (q, p) PAIR; one call per query suffices.
    # The inner loop keeps the original submission order so the float
    # accumulation — and therefore any logged reason text — is unchanged.
    min_cost = [p.min_comp_cost for p in queries]
    first_in = [p.arrival.input_time(1) for p in queries]
    for q in edf_order(queries):
        # Lower bound on q's completion: its own last tuple must arrive.
        done_floor = q.arrival.input_time(q.num_tuples_total)
        if now is not None:
            done_floor = max(done_floor, now)
        horizon = min(q.deadline, done_floor)
        work = 0.0
        start = math.inf
        for j, p in enumerate(queries):
            if p.deadline <= q.deadline + 1e-12:
                work += min_cost[j]
            elif p.tier < q.tier:
                # Higher-priority work competing before q can be done:
                # only the tuples that will have arrived by the horizon.
                avail = p.arrival.tuples_available(horizon)
                if avail <= 0:
                    continue
                work += p.cost_model.cost(avail)
            else:
                continue
            start = min(start, first_in[j])
        anchor = start if now is None else max(start, now)
        budget = q.deadline - anchor
        if work > budget + 1e-9:
            reasons.append(
                f"tiered demand through {q.query_id}: work {work:.4g} "
                f"(incl. higher tiers) exceeds budget {budget:.4g} "
                f"(deadline {q.deadline:.6g} - work start {anchor:.6g})"
            )
    return FeasibilityReport(feasible=not reasons, reasons=tuple(reasons))


def overload_check(
    queries: Sequence[Query],
    c_max: float = float("inf"),
    now: Optional[float] = None,
) -> FeasibilityReport:
    """The overload subsystem's feasibility verdict: the generic necessary
    conditions PLUS the tier-strict demand bound."""
    rep = admission_check(queries, c_max=c_max, now=now)
    tiered = tiered_work_demand_condition(queries, now)
    return FeasibilityReport(
        feasible=rep.feasible and tiered.feasible,
        reasons=(*rep.reasons, *tiered.reasons),
    )


def _tighten(queries: Sequence[Query], now: Optional[float],
             headroom: float) -> List[Query]:
    """Shrink every deadline budget by ``1 + headroom`` (see
    ``OverloadConfig.headroom``) so the shed/extension search leaves room
    for the batching overheads the necessary conditions cannot see."""
    if headroom <= 0:
        return list(queries)
    out = []
    for q in queries:
        ref = now if now is not None else min(q.submit_time, q.wind_start)
        budget = q.deadline - ref
        if budget > 0:
            q = dataclasses.replace(q, deadline=ref + budget / (1.0 + headroom))
        out.append(q)
    return out


def _tenant_shed_groups(
    queries: Sequence[Query],
    tenancy: TenancyConfig,
    now: Optional[float],
) -> List[List[str]]:
    """Shed-group order under tenancy: fairness ABOVE tiers.

    Capacity over the workload's deadline horizon is divided across
    tenants by weight: tenant ``t``'s entitlement is ``w_t / sum(w) *
    capacity``, further capped by its ``capacity`` quota.  Tenants OVER
    their entitlement drain first, in order of normalized utilization
    (demand / entitlement, highest first) — the weighted max-min
    draining order: bursters consuming multiples of their slice shed
    against their own excess before anyone else is touched.  If pinning
    every over-entitlement tenant at its cap still leaves the set
    infeasible, the residual comes from the within-entitlement tenants
    in ascending WEIGHT order (utilization descending as tie-break):
    the weight is precisely the knob a tenant's SLO buys, so a weight-2
    victim outlasts every weight-1 neighbour even when the victim's own
    utilization is momentarily higher.  That is the no-starvation
    property the tenancy test suite pins: a well-behaved tenant is
    never degraded while an over-entitlement tenant still has shed
    budget left, and never before a lower-weight peer.  Tiers order
    groups WITHIN each tenant exactly as the single-principal planner
    does (lowest tier first).

    The ratio is taken against the UNCAPPED entitlement, not the
    demand-capped ``fair_shares`` allocation — under the latter every
    satisfied tenant's ratio degenerates to exactly 1.0 and the order
    between them would collapse to the name tie-break.

    Deterministic: final ties break on the tenant name (tenantless
    queries sort last among equals).
    """
    demand = demand_by_tenant(queries)
    anchor = now if now is not None else min(
        q.arrival.input_time(1) for q in queries)
    capacity = max(max(q.deadline for q in queries) - anchor, 0.0)
    weights = {t: tenancy.weight(t) for t in demand}
    total_w = sum(weights.values())

    def entitlement(t) -> float:
        slice_ = capacity * weights[t] / total_w if total_w > EPS else 0.0
        quota = tenancy.quota(t)
        if quota is not None and quota.capacity is not None:
            slice_ = min(slice_, quota.capacity * capacity)
        return slice_

    def ratio(t) -> float:
        d = demand[t]
        if d <= EPS:
            return 0.0
        s = entitlement(t)
        return d / s if s > EPS else math.inf

    def sort_key(t):
        name = "" if t is None else str(t)
        if ratio(t) > 1.0 + 1e-9:
            # Over entitlement: most-over first.
            return (0, -ratio(t), 0.0, name)
        # Within entitlement: lowest weight first, then highest
        # utilization — weight buys protection, not just share.
        return (1, weights[t], -ratio(t), name)

    order = sorted(demand, key=sort_key)
    groups: List[List[str]] = []
    for t in order:
        mine = [q for q in queries if _sheddable(q) and q.tenant == t]
        for tier in sorted({q.tier for q in mine}, reverse=True):
            groups.append([q.query_id for q in mine if q.tier == tier])
    return groups


def plan_shedding(
    queries: Sequence[Query],
    c_max: float = float("inf"),
    now: Optional[float] = None,
    config: OverloadConfig = OverloadConfig(),
    processed: Optional[Dict[str, int]] = None,
    prior_shed: Optional[Dict[str, float]] = None,
    tenancy: Optional[TenancyConfig] = None,
) -> SheddingPlan:
    """Minimum load shed restoring the necessary schedulability conditions.

    ``queries`` is the would-be live set (remaining-work snapshots for
    in-flight queries; ``processed`` marks tuples of each that already ran
    and are exempt from shedding).  Sheddable queries (``Query.shed=True``,
    not pane-shared) are degraded group by group — one group per tier,
    lowest tier (largest ``tier`` number) first: a drop fraction is
    binary-searched per group — each member sheds ``min(group level, its
    own cap)``, where a query's cap is the largest fraction keeping its
    cumulative shed within ``config.max_shed`` and its reported error
    bound within ``config.max_error_bound``.  Only if a group's maximum
    allowed shed still leaves the set infeasible does the next group join
    the search.  Within the deciding group the level is minimized to the
    search resolution (0.1%), so the plan is the smallest shed — group-
    lexicographically — that the (headroom-tightened) necessary conditions
    accept.

    ``tenancy`` switches on multi-tenant arbitration (inert while every
    query has ``tenant=None`` — the group order, every probe and every
    report stay byte-identical to the single-principal planner).  With
    tenants present, feasibility additionally requires
    ``tenant_quota_condition`` and groups are ordered tenant-major by
    ``_tenant_shed_groups``: over-fair-share tenants shed first (against
    their OWN quota), tiers order groups within each tenant, and a tenant
    within its share is touched only after every over-share tenant is
    exhausted.

    Error bounds are stamped PER QUERY, from each query's own kept sample
    (``effective``/``realize``), never from the pooled totals of its
    group: two queries at the same group level report different bounds
    when their kept counts differ, and a small tenant population can
    never borrow a large pool's optimistic bound.  The tenancy regression
    tests pin this invariant.

    The returned plan's ``feasible`` is False when even shedding every
    allowed query to its cap cannot restore the conditions.

    ``prior_shed`` maps a query id to the fraction ALREADY shed from it in
    earlier rounds (vs its true original total).  Remaining-work snapshots
    erase the thinned history, so without it successive shed rounds — one
    per admission — would each see a fresh query and compound past the
    caps; with it, a query's cap reflects its CUMULATIVE degradation, and
    an exhausted query simply stops being sheddable.
    """
    processed = processed or {}
    prior_shed = prior_shed or {}
    tenant_mode = tenancy is not None and any(
        q.tenant is not None for q in queries)

    def feasibility(qs: Sequence[Query]) -> FeasibilityReport:
        rep = overload_check(qs, c_max=c_max, now=now)
        if not tenant_mode:
            return rep
        tq = tenant_quota_condition(qs, tenancy, now)
        return FeasibilityReport(
            feasible=rep.feasible and tq.feasible,
            reasons=(*rep.reasons, *tq.reasons),
        )

    base_report = feasibility(queries)
    if base_report.feasible:
        return SheddingPlan({}, {}, True, base_report)

    if tenant_mode:
        groups = _tenant_shed_groups(queries, tenancy, now)
    else:
        tiers = sorted({q.tier for q in queries if _sheddable(q)},
                       reverse=True)
        groups = [[q.query_id for q in queries
                   if _sheddable(q) and q.tier == t] for t in tiers]
    groups = [g for g in groups if g]
    if not groups:
        return SheddingPlan({}, {}, False, base_report)
    group_of = {qid: gi for gi, g in enumerate(groups) for qid in g}

    def effective(q: Query, kept_local: int):
        """(cumulative fraction vs the TRUE original, error bound) of a
        candidate shed leaving ``kept_local`` of ``q``'s current tuples.

        The prior degradation is the LARGER of ``prior_shed``'s entry and
        what the query's own arrival chain still shows (a remaining-work
        snapshot may retain the thin chain or erase it); the cumulative
        fraction is then one minus the surviving ratio — prior kept times
        this round's local keep ratio.  Composing ``apply_shed``'s
        returned fraction with ``prior_shed`` instead would double-count
        every round whose snapshot retained its chain (``apply_shed``
        already reports CUMULATIVE fractions for those), collapsing the
        query's remaining cap and recruiting higher-priority groups for
        load the degraded query could still absorb itself.  The bound
        uses the locally-kept count, which under-counts a prior round's
        processed prefix — conservative (never reports a bound smaller
        than the realized one)."""
        total = q.num_tuples_total
        orig = original_total(q)
        pf_visible = 1.0 - total / orig if orig > 0 else 0.0
        pf = max(prior_shed.get(q.query_id, 0.0), pf_visible)
        ratio = kept_local / total if total > 0 else 1.0
        cum = max(1.0 - (1.0 - pf) * ratio, 0.0)
        return cum, shed_error_bound(cum, kept_local)

    def query_cap(q: Query) -> float:
        """Largest grid fraction whose REALIZED shed keeps this query's
        cumulative fraction and error bound within the caps.  Both grow
        monotonically with the fraction, so binary-searchable."""
        pr = processed.get(q.query_id, 0)
        lo, hi = 0, _SHED_RESOLUTION
        while lo < hi:
            mid = (lo + hi + 1) // 2
            f = mid / _SHED_RESOLUTION
            thin, _, _ = apply_shed(q, f, processed=pr)
            cum, bound = effective(q, thin.num_tuples_total)
            if (cum <= config.max_shed + 1e-9
                    and bound <= config.max_error_bound + 1e-9):
                lo = mid
            else:
                hi = mid - 1
        return lo / _SHED_RESOLUTION

    caps = {q.query_id: query_cap(q) for q in queries if _sheddable(q)}

    def realize(levels: Dict[int, float]):
        """Apply per-group levels (clipped to each member's own cap);
        returns (shed set, fractions, bounds).  The bound stamped for a
        query comes from ITS OWN cumulative fraction and kept count —
        never from pooled group totals (see the docstring invariant)."""
        out: List[Query] = []
        fr: Dict[str, float] = {}
        eb: Dict[str, float] = {}
        for q in queries:
            f = levels.get(group_of.get(q.query_id, -1), 0.0)
            f = min(f, caps.get(q.query_id, 0.0))
            if f <= 0:
                out.append(q)
                continue
            thin, _, _ = apply_shed(
                q, f, processed=processed.get(q.query_id, 0))
            out.append(thin)
            if thin is not q:
                cum, bound = effective(q, thin.num_tuples_total)
                fr[q.query_id] = f
                eb[q.query_id] = bound
        return out, fr, eb

    def check_levels(levels: Dict[int, float]):
        out, fr, eb = realize(levels)
        rep = feasibility(_tighten(out, now, config.headroom))
        return rep.feasible, fr, eb, rep

    levels: Dict[int, float] = {}
    for gi in range(len(groups)):
        probe = dict(levels)
        probe[gi] = 1.0  # every member clipped to its own cap
        feas, _, _, rep = check_levels(probe)
        if not feas:
            if gi < len(groups) - 1:
                # Even this group's maximum shed is not enough: pin it and
                # recruit the next group.
                levels[gi] = 1.0
                continue
            return SheddingPlan({}, {}, False, rep)
        # Binary-search the minimal level for THIS group (earlier groups
        # stay pinned): feasibility is monotone in the level.
        lo, hi = 0, _SHED_RESOLUTION
        while lo < hi:
            mid = (lo + hi) // 2
            probe[gi] = mid / _SHED_RESOLUTION
            feas, _, _, _ = check_levels(probe)
            if feas:
                hi = mid
            else:
                lo = mid + 1
        # ``lo`` always lands on a level that tested feasible (``hi`` only
        # ever holds feasible levels, and the loop exits with lo == hi).
        probe[gi] = lo / _SHED_RESOLUTION
        _, fr, eb, rep = check_levels(probe)
        return SheddingPlan(fr, eb, True, rep)
    return SheddingPlan({}, {}, False, base_report)


def min_deadline_extension(
    incoming: Query,
    active: Sequence[Query] = (),
    c_max: float = float("inf"),
    now: Optional[float] = None,
    config: OverloadConfig = OverloadConfig(),
) -> Optional[RenegotiationProposal]:
    """Smallest deadline extension making ``incoming`` feasible against
    ``active`` — the renegotiation offer for ``shed=False`` queries.

    Returns None when no extension up to ``config.max_extension`` restores
    the conditions (the active set is already drowning) or when the
    workload is feasible as-is (nothing to renegotiate).

    The returned proposal is always VALID (re-verified feasible at the
    proposed deadline).  It is the true minimum when feasibility is
    monotone in the extension — the common case; a longer deadline can in
    principle pull extra work into its own demand prefix faster than it
    buys budget, and the geometric probe + bisection then land on a
    feasible-but-not-globally-minimal boundary.
    """
    def feasible(ext: float, headroom: float = config.headroom
                 ) -> Tuple[bool, FeasibilityReport]:
        q = dataclasses.replace(incoming, deadline=incoming.deadline + ext)
        rep = overload_check(
            [*_tighten([q], now, headroom), *_tighten(active, now, headroom)],
            c_max=c_max, now=now)
        return rep.feasible, rep

    # Activation on the UNTIGHTENED conditions (headroom only shapes the
    # proposal): nothing to renegotiate when the workload truly fits.
    ok, rep = feasible(0.0, headroom=0.0)
    if ok:
        return None
    # Exponential probe for a feasible ceiling, then bisect.  The natural
    # scale is the query's own single-batch cost (an extension smaller than
    # one batch rarely flips a verdict).
    step = max(incoming.min_comp_cost, 1.0)
    hi = step
    cap = config.max_extension
    for _ in range(60):  # bounded probe: the active set may be past saving
        if hi >= cap or feasible(hi)[0]:
            break
        hi *= 2.0
    hi = min(hi, cap)
    if not math.isfinite(hi):
        return None
    ok, rep = feasible(hi)
    if not ok:
        return None
    lo = 0.0
    for _ in range(60):  # bisect to float resolution
        if hi - lo <= max(1e-9, 1e-9 * abs(hi)):
            break
        mid = (lo + hi) / 2.0
        if feasible(mid)[0]:
            hi = mid
        else:
            lo = mid
    ok, rep = feasible(hi)
    return RenegotiationProposal(
        query_id=incoming.query_id,
        deadline=incoming.deadline,
        proposed_deadline=incoming.deadline + hi,
        report=rep,
    )
