"""Arrival forecasting for predictive (proactive) scheduling.

The paper's schedulers assume each window's arrival curve is known up
front; PRs 3/5 made the runtime *reactive* — it recalibrates after cost
drift is observed and sheds after overload has already materialized, so
deadlines are at risk before the system acts.  Predictive tuple scheduling
(POTUS) shows that acting on a FORECAST of the arrival process beats the
reactive baseline: by the time a burst lands, the schedule has already
made room for it.

This module is the forecasting layer of that story:

* ``ArrivalObservation`` / ``observe_arrival`` — one closed window's
  realized arrival statistics (tuple count, mean rate, burstiness),
  extracted from the window's OFFERED stream (``offered_arrival`` unwraps
  any shedding so the forecast tracks demand, not past actuation).
* ``ArrivalForecaster`` — per-spec Holt-style EWMA (level + linear trend)
  over the inter-window observation series, with an exponentially-weighted
  residual variance giving confidence bands; rate and burstiness are
  EWMA-smoothed alongside the count.
* ``ArrivalForecast`` — one window-ahead point forecast plus its band
  (``lower``/``upper`` = point ± z·std) and the forecast burst SHAPE
  (``expected_by`` — how many tuples should have arrived by an instant if
  the forecast is on track; the session's forecast-miss detector compares
  realized arrivals against it mid-window).
* ``forecast_query`` — a pessimistic stand-in window query for the
  schedulability machinery: the planned tuple count arriving at the
  forecast burst pace (compressed into the tail of the window), which is
  what ``repro.core.session`` feeds to ``admission_check``/
  ``plan_shedding`` at window roll-over so shedding happens BEFORE the
  burst (proactive replanning).
* ``SpecHistory`` — the public per-spec observation record returned by
  ``Session.history()``: arrival observations plus the calibrator's cost
  feedback (``CalibratingCostModel.samples``/``agg_samples``), replacing
  consumer access to ``_LiveSpec``/calibrator privates.

Everything here is advisory arithmetic with no runtime state of its own;
the enforcement points live in ``repro.core.session`` (proactive shed /
refund / speculative pane pre-warm) and are inert — every trace
byte-identical — unless a session enables ``forecast=``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from .arrivals import ArrivalModel, ShiftedArrival, ThinnedArrival, UniformWindowArrival
from .types import EPS, Query

__all__ = [
    "ArrivalForecast",
    "ArrivalForecaster",
    "ArrivalObservation",
    "ForecastConfig",
    "SpecHistory",
    "forecast_query",
    "observe_arrival",
    "offered_arrival",
]

# Segments the window is split into when estimating burstiness from a
# closed window's arrival curve: peak segment rate over mean rate.  Eight
# localizes a burst to 12.5% of the window — sharp enough to act on,
# coarse enough that ordinary jitter does not read as a burst.
_BURST_SEGMENTS = 8


def offered_arrival(arr: ArrivalModel) -> ArrivalModel:
    """The OFFERED stream behind ``arr``: every ``ThinnedArrival`` layer
    (load shedding is an actuation, not demand) unwrapped, time shifts
    preserved.  Forecasts must be fit on what the stream tried to deliver,
    or a shed window would teach the forecaster that demand dropped."""
    if isinstance(arr, ShiftedArrival):
        base = offered_arrival(arr.base)
        if base is arr.base:
            return arr
        return ShiftedArrival(base=base, shift=arr.shift)
    if isinstance(arr, ThinnedArrival):
        return offered_arrival(arr.base)
    return arr


@dataclasses.dataclass(frozen=True)
class ArrivalObservation:
    """Realized arrival statistics of ONE closed window.

    ``burstiness`` is the peak-to-mean rate ratio over
    ``_BURST_SEGMENTS`` equal sub-spans: 1.0 for a uniform stream, ~k when
    the whole window lands in a 1/k tail."""

    window: int
    wind_start: float
    wind_end: float
    num_tuples: int
    burstiness: float = 1.0

    @property
    def span(self) -> float:
        return self.wind_end - self.wind_start

    @property
    def mean_rate(self) -> float:
        """Tuples per time unit over the window span (inf for instant
        windows)."""
        if self.span <= 0:
            return math.inf if self.num_tuples > 0 else 0.0
        return self.num_tuples / self.span


def observe_arrival(arr: ArrivalModel, window: int = 0, *,
                    wind_start: Optional[float] = None,
                    wind_end: Optional[float] = None) -> ArrivalObservation:
    """Extract an ``ArrivalObservation`` from a CLOSED window's arrival
    model (all arrivals realized).  ``arr`` should be the offered stream —
    callers with a possibly-shed model unwrap via ``offered_arrival``.

    ``wind_start`` / ``wind_end`` override the observation frame; pass the
    QUERY's window bounds when the arrival model covers a narrower span
    (e.g. a tail burst), or burstiness would be measured against the burst
    itself and read as uniform."""
    n = arr.num_tuples_total
    start = arr.wind_start if wind_start is None else wind_start
    end = arr.wind_end if wind_end is None else wind_end
    span = end - start
    burst = 1.0
    if n > 0 and span > 0:
        prev = 0
        peak = 0
        for i in range(1, _BURST_SEGMENTS + 1):
            a = arr.tuples_available(start + span * i / _BURST_SEGMENTS)
            peak = max(peak, a - prev)
            prev = a
        burst = max(1.0, peak * _BURST_SEGMENTS / n)
    return ArrivalObservation(
        window=window, wind_start=start, wind_end=end,
        num_tuples=n, burstiness=burst,
    )


@dataclasses.dataclass(frozen=True)
class ForecastConfig:
    """Knobs of the predictive-scheduling subsystem
    (``Session(forecast=...)``).

    ``alpha`` is the EWMA smoothing factor for level/trend/rate/burstiness
    (1.0 = last observation wins); ``z`` the half-width of the confidence
    band in residual standard deviations; ``min_history`` how many closed
    windows a spec needs before its forecasts are ACTED on (proactive
    shedding, pre-warming) — below it the forecaster only learns.
    ``miss_check_frac`` places the mid-window forecast-miss check: once
    that fraction of the FORECAST burst should have arrived, realized
    arrivals below ``miss_tolerance`` times the expected curve (lower
    band) declare a miss (the session falls back to the reactive path and
    refunds the window's proactive shed).  The tolerance absorbs burst
    TIMING error — a real burst landing slightly later than forecast must
    not read as "the burst is not coming".
    ``prewarm`` gates speculative pane deposits during idle capacity
    (``repro.core.panes``; needs ``sharing=True`` to do anything).
    """

    alpha: float = 0.5
    z: float = 2.0
    min_history: int = 2
    miss_check_frac: float = 0.5
    miss_tolerance: float = 0.5
    prewarm: bool = True

    def __post_init__(self) -> None:
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        if self.z < 0:
            raise ValueError(f"z must be >= 0, got {self.z}")
        if self.min_history < 1:
            raise ValueError(
                f"min_history must be >= 1, got {self.min_history}")
        if not 0.0 < self.miss_check_frac <= 1.0:
            raise ValueError(
                f"miss_check_frac must be in (0, 1], got "
                f"{self.miss_check_frac}")
        if not 0.0 < self.miss_tolerance <= 1.0:
            raise ValueError(
                f"miss_tolerance must be in (0, 1], got "
                f"{self.miss_tolerance}")


@dataclasses.dataclass(frozen=True)
class ArrivalForecast:
    """One window-ahead forecast: expected tuple count with a ±z·std band,
    plus the smoothed rate and burstiness shaping the expected curve."""

    window: int
    tuples: float
    std: float
    z: float
    rate: float
    burstiness: float = 1.0

    @property
    def lower(self) -> float:
        return max(0.0, self.tuples - self.z * self.std)

    @property
    def upper(self) -> float:
        return self.tuples + self.z * self.std

    def contains(self, actual: float) -> bool:
        """Did the realized count land inside the confidence band?"""
        return self.lower - EPS <= actual <= self.upper + EPS

    def burst_span(self, wind_start: float, wind_end: float) -> float:
        """Forecast burst duration inside ``[wind_start, wind_end]``: the
        window span compressed by the burstiness ratio, anchored at the
        window END (bursts that matter are the ones that leave no time to
        drain)."""
        span = max(wind_end - wind_start, 0.0)
        return span / max(self.burstiness, 1.0)

    def expected_by(self, t: float, wind_start: float, wind_end: float,
                    count: Optional[float] = None) -> float:
        """Tuples expected to have arrived by ``t`` if the forecast is on
        track: ``count`` (default: the band's LOWER edge — the miss check
        must not cry wolf on an ordinary shortfall) arriving uniformly
        over the forecast burst span at the window tail."""
        if count is None:
            count = self.lower
        bs = self.burst_span(wind_start, wind_end)
        if bs <= 0:
            return count if t >= wind_end - EPS else 0.0
        frac = (t - (wind_end - bs)) / bs
        return count * min(max(frac, 0.0), 1.0)


class ArrivalForecaster:
    """Per-spec arrival forecaster: Holt's linear exponential smoothing on
    the inter-window tuple-count series plus EWMA rate/burstiness, fed one
    ``ArrivalObservation`` per closed window (``observe``).

    The confidence band is ±z standard deviations of the exponentially-
    weighted ONE-STEP-AHEAD residuals: each observation is first scored
    against the forecast made before it, then folded in — so the band
    widens exactly when the forecaster is actually missing.  ``hits`` /
    ``misses`` count the session's scoring of acted-on forecasts (band
    containment at window close, mid-window burst checks)."""

    def __init__(self, config: Optional[ForecastConfig] = None):
        self.config = config if config is not None else ForecastConfig()
        self._level: Optional[float] = None
        self._trend = 0.0
        self._var = 0.0
        self._rate: Optional[float] = None
        self._burst: Optional[float] = None
        self._count = 0
        self.hits = 0
        self.misses = 0

    @property
    def num_observations(self) -> int:
        return self._count

    @property
    def ready(self) -> bool:
        """Enough history to ACT on forecasts (vs just learning)."""
        return self._count >= self.config.min_history

    def observe(self, obs: ArrivalObservation) -> None:
        """Fold one closed window's realized arrivals into the forecast
        state."""
        a = self.config.alpha
        y = float(obs.num_tuples)
        if self._level is None:
            self._level = y
        else:
            resid = y - (self._level + self._trend)
            self._var += a * (resid * resid - self._var)
            prev = self._level
            self._level = a * y + (1.0 - a) * (self._level + self._trend)
            self._trend = a * (self._level - prev) + (1.0 - a) * self._trend
        rate = obs.mean_rate
        if math.isfinite(rate):
            self._rate = rate if self._rate is None else (
                a * rate + (1.0 - a) * self._rate)
        # Level-style initialization: the first observation IS the estimate
        # (an EWMA crawling up from 1.0 would under-forecast the burst for
        # many windows, and a too-wide burst span under-sheds).
        if self._burst is None:
            self._burst = obs.burstiness
        else:
            self._burst += a * (obs.burstiness - self._burst)
        self._count += 1

    def forecast(self, window: int) -> Optional[ArrivalForecast]:
        """One-window-ahead forecast (None before any observation)."""
        if self._level is None:
            return None
        return ArrivalForecast(
            window=window,
            tuples=max(0.0, self._level + self._trend),
            std=math.sqrt(max(self._var, 0.0)),
            z=self.config.z,
            rate=self._rate if self._rate is not None else 0.0,
            burstiness=max(self._burst if self._burst is not None else 1.0,
                           1.0),
        )

    def record_hit(self) -> None:
        self.hits += 1

    def record_miss(self) -> None:
        self.misses += 1

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"ArrivalForecaster(n={self._count}, level={self._level}, "
                f"trend={self._trend:.4g}, burst={self._burst:.3g}, "
                f"hits={self.hits}, misses={self.misses})")


def forecast_query(query: Query, fc: ArrivalForecast) -> Query:
    """The pessimistic stand-in ``query`` for proactive feasibility checks:
    the PLANNED tuple count arriving at the forecast burst pace — uniformly
    over the burst span at the window tail — instead of the predicted
    curve.  Count stays the planned one (the window never processes more
    than it planned; fewer-than-planned arrivals only make scheduling
    easier), so the stand-in differs from the real window purely in
    arrival TIMING, which is exactly the risk a late burst poses.

    Near-uniform forecasts (burst span within 10% of the window span —
    segment discretization alone reads a uniform stream as burstiness
    ~1.04) return ``query`` unchanged: a no-op stand-in keeps the
    proactive path inert when there is no burst to get ahead of."""
    span = query.wind_end - query.wind_start
    bs = fc.burst_span(query.wind_start, query.wind_end)
    if span <= 0 or bs >= 0.9 * span - EPS or query.num_tuples_total <= 0:
        return query
    arr = UniformWindowArrival(
        wind_start=query.wind_end - bs,
        wind_end=query.wind_end,
        num_tuples_total=query.num_tuples_total,
    )
    return dataclasses.replace(query, wind_start=arr.wind_start, arrival=arr)


@dataclasses.dataclass(frozen=True)
class SpecHistory:
    """Public per-spec observation record (``Session.history()``): what
    the session has LEARNED about one recurring query — per-window arrival
    observations plus the calibration feedback loop's cost samples.

    ``cost_samples`` / ``agg_samples`` are the calibrator's buffered
    ``(num_tuples, observed_cost)`` / ``(num_batches, observed_cost)``
    pairs (empty without ``calibrate=True``); ``shed_fraction`` /
    ``error_bound`` the spec-level admission degradation currently in
    force.  This is the supported read path — ``_LiveSpec`` and the
    calibrator's buffers are internals."""

    base_id: str
    arrivals: Tuple[ArrivalObservation, ...] = ()
    cost_samples: Tuple[Tuple[float, float], ...] = ()
    agg_samples: Tuple[Tuple[float, float], ...] = ()
    shed_fraction: float = 0.0
    error_bound: float = 0.0

    @property
    def num_windows_observed(self) -> int:
        return len(self.arrivals)
