"""The scheduling API: Planner / SchedulingPolicy / Executor.

The paper's contribution is a FAMILY of interchangeable scheduling schemes —
single-query with/without aggregation cost (§3.1), constraint-based (§3.2),
dynamic multi-query under LLF/EDF/SJF/RR (§4) — evaluated against a common
executor.  This module makes that structure first-class:

* ``SchedulingPolicy``  — the scheme interface: ``plan(queries, ...) -> Plan``
  for static planning, plus ``replan(event, state) -> PolicyDecision`` for
  event-driven dynamic dispatch (Algorithm 2's per-decision-instant logic).
* policy registry      — string-keyed: ``@register_policy("edf-dynamic")``,
  ``get_policy(name, **params)``, ``list_policies()``.  Every legacy
  ``schedule_*`` free function is a registered policy; the old names survive
  as thin deprecation shims.
* ``Planner``          — the user-facing facade: ``Planner(policy="single")``
  then ``.plan(queries)`` or ``.run(workload, executor)``.
* ``Executor``         — the execution backend protocol: ``submit_batch`` /
  ``finalize`` / ``clock``.  Implemented by the discrete-event simulator
  (``repro.core.runtime.SimulatedExecutor``), the JAX analytics executor
  (``repro.serve.analytics.AnalyticsRuntimeExecutor``) and the model-serving
  engine (``repro.serve.engine.ServingExecutor``).  All three share ONE
  runtime loop (``repro.core.runtime.run``), which owns deadline checking,
  C_max straggler re-queue and trace recording.  Any backend scales out by
  wrapping it in ``repro.core.runtime.ExecutorPool`` (W workers with
  independent modelled clocks over one physical backend); decisions may
  target a named worker or split into per-worker shards
  (``PolicyDecision.worker`` / ``PolicyDecision.shards``).

Scheduling state/decision events flow::

    Planner(policy) --plan()--> Plan --run()--> runtime.run(policy, executor)
                                                   |  replan(event, state)
                                                   v
                                            executor.submit_batch/finalize
"""
from __future__ import annotations

import dataclasses
from typing import (
    Callable,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    Type,
    Union,
    runtime_checkable,
)

from .cost_model import CostModelBase
from .types import ExecutionTrace, Plan, PolicyDecision, Query, Schedule


# ---------------------------------------------------------------------------
# Policy protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SchedulingEvent:
    """Why the runtime is consulting the policy (§4.2's decision instants)."""

    kind: str  # "start" | "batch_end" | "admission" | "wake"
    now: float
    query_id: Optional[str] = None


@runtime_checkable
class SchedulingPolicy(Protocol):
    """One scheduling scheme.

    ``kind`` is "static" (a full per-query Plan is computed up front and
    executed with Algorithm 1's triggers) or "dynamic" (the policy is
    consulted at every decision instant via ``replan``).
    """

    name: str
    kind: str

    def plan(
        self,
        queries: Union[Query, Sequence[Query]],
        cost_model: Optional[CostModelBase] = None,
        now: float = 0.0,
    ) -> Plan:
        """Static plan for ``queries`` (predicted arrival models only).

        ``cost_model`` overrides the per-query cost model when given (e.g. a
        freshly calibrated model for all queries of one executor).
        """
        ...

    def replan(self, event: SchedulingEvent, state: "RuntimeState") -> PolicyDecision:  # noqa: F821
        """Dynamic decision at one instant; static policies need not implement."""
        ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type] = {}


def register_policy(name: str, *aliases: str) -> Callable[[Type], Type]:
    """Class decorator: register a SchedulingPolicy under ``name`` (+aliases).

        @register_policy("edf-dynamic")
        class EDFPolicy(DynamicPolicy): ...
    """

    def deco(cls: Type) -> Type:
        for key in (name, *aliases):
            if key in _REGISTRY and _REGISTRY[key] is not cls:
                raise ValueError(f"policy name {key!r} already registered")
        if _REGISTRY.get(getattr(cls, "name", None)) is not cls:
            # First registration fixes the canonical name; registering the
            # same class again only adds aliases (list_policies() keeps
            # reporting the canonical name).
            cls.name = name
        for key in (name, *aliases):
            _REGISTRY[key] = cls
        return cls

    return deco


def _ensure_builtin_policies() -> None:
    # Importing the package registers every built-in policy exactly once.
    from . import policies  # noqa: F401


def get_policy(name: str, **params) -> SchedulingPolicy:
    """Instantiate the policy registered under ``name``.

    ``params`` are forwarded to the policy constructor (e.g.
    ``get_policy("llf-dynamic", delta_rsf=0.5, c_max=30.0)``).
    """
    _ensure_builtin_policies()
    try:
        cls = _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(set(_REGISTRY)))
        raise KeyError(f"unknown policy {name!r}; registered: {known}") from None
    return cls(**params)


def list_policies() -> Tuple[str, ...]:
    """Canonical names of all registered policies (aliases excluded)."""
    _ensure_builtin_policies()
    return tuple(sorted({cls.name for cls in _REGISTRY.values()}))


# ---------------------------------------------------------------------------
# Executor protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class Executor(Protocol):
    """Execution backend driven by the shared runtime loop.

    Executors keep the MODELLED clock (cost units == time units, exactly how
    the paper's §7 experiments report results); real backends additionally do
    the physical work inside ``submit_batch``/``finalize``.

    Prefer subclassing ``repro.core.runtime.BaseExecutor`` (override
    ``_execute``/``_finalize``) over implementing this protocol from scratch:
    the base class also provides the OPTIONAL members the loop uses when
    present — ``wall_seconds`` (per-query real seconds), ``last_batch_wall``
    (feeds C_max straggler detection; without it stragglers are never
    flagged) and ``requeue_batch`` (idempotent straggler re-dispatch).
    """

    def clock(self) -> float:
        """Current modelled time."""
        ...

    def advance(self, t: float) -> None:
        """Idle forward to modelled time ``t`` (no-op if in the past)."""
        ...

    def reset(self, t: float) -> None:
        """Rewind/initialize the clock to ``t`` (start of a query timeline —
        static runs give every query its own timeline, so this can move the
        clock backward, unlike ``advance``)."""
        ...

    def submit_batch(self, query: Query, num_tuples: int, offset: int) -> float:
        """Process ``num_tuples`` of ``query`` starting at tuple ``offset``;
        advance the clock by — and return — the modelled batch cost."""
        ...

    def finalize(self, query: Query, num_batches: int) -> float:
        """Final aggregation (§2.1) after the last batch; advance the clock
        by — and return — the modelled aggregation cost."""
        ...


# ---------------------------------------------------------------------------
# Planner facade
# ---------------------------------------------------------------------------


class Planner:
    """User-facing entry point: a policy plus convenience plumbing.

        planner = Planner(policy="single")
        plan = planner.plan(query)                       # static Plan
        trace = planner.run(specs)                       # simulate
        trace = planner.run(specs, executor=real_exec)   # real backend
    """

    def __init__(
        self,
        policy: Union[str, SchedulingPolicy] = "single",
        **policy_params,
    ):
        if isinstance(policy, str):
            self.policy = get_policy(policy, **policy_params)
        else:
            if policy_params:
                raise TypeError(
                    "policy_params only apply when policy is given by name"
                )
            self.policy = policy

    @property
    def name(self) -> str:
        """Canonical registry name of the wrapped policy."""
        return self.policy.name

    def plan(
        self,
        queries: Union[Query, Sequence[Query]],
        cost_model: Optional[CostModelBase] = None,
        now: float = 0.0,
    ) -> Plan:
        """Static ``Plan`` for ``queries`` under the wrapped policy (the
        PREDICTED arrival models; dynamic policies return their
        deterministic projection).  ``cost_model`` overrides every query's
        own model when given."""
        return self.policy.plan(queries, cost_model=cost_model, now=now)

    def schedule(self, query: Query, **kw) -> Schedule:
        """Single-query convenience: the Schedule for one query."""
        return self.plan(query, **kw)[query.query_id]

    def run(
        self,
        workload,
        executor: Optional[Executor] = None,
        *,
        workers: Optional[int] = None,
        share: bool = False,
        pane_tuples: Optional[int] = None,
        **runtime_kw,
    ) -> ExecutionTrace:
        """Execute ``workload`` (Queries or DynamicQuerySpecs) end to end
        through the shared runtime loop; simulates when no executor given.

        ``workers=W`` wraps ``executor`` in an ``ExecutorPool`` of W workers
        (``workers=4`` with no executor: a 4-way simulated pool).

        ``share=True`` enables pane-based shared execution for queries that
        name a common ``Query.stream`` (``repro.core.panes``): their cost
        models become amortized one-scan-+-k-merges ``SharedCostModel``s and
        pane partials are cached/reused across overlapping windows.
        ``pane_tuples`` overrides the per-stream GCD pane width.  The
        returned trace carries the pane bookkeeping as ``trace.pane_book``
        (scan/hit/eviction stats under ``.store.stats``).  With
        ``share=False`` (default) the run is byte-identical to the unshared
        runtime.

        ``runtime="heap"`` opts dynamic policies into the event-heap
        decision core (``repro.core.runtime.HeapLoopCore``): O(log n) per
        decision instant instead of the reference core's full O(n) state
        walk, with byte-identical traces (docs/ARCHITECTURE.md "Decision
        core").  ``runtime="scan"``/default keeps the reference core;
        policies with custom ``replan`` logic fall back to it silently."""
        from .runtime import ExecutorPool, run as _run

        if workers is not None:
            executor = ExecutorPool(backend=executor, workers=workers)
        if share:
            from .panes import run_shared

            trace, book = run_shared(
                self.policy, workload, executor,
                pane_tuples=pane_tuples, **runtime_kw,
            )
            trace.pane_book = book
            return trace
        if pane_tuples is not None:
            raise ValueError("pane_tuples= only applies with share=True")
        return _run(self.policy, workload, executor=executor, **runtime_kw)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"Planner(policy={self.policy.name!r})"


def as_queries(queries: Union[Query, Sequence[Query]]) -> List[Query]:
    """Normalize the ``plan()`` input: one query or a sequence."""
    if isinstance(queries, Query):
        return [queries]
    return list(queries)


# ---------------------------------------------------------------------------
# Session facade (continuous operation)
# ---------------------------------------------------------------------------


class Session:
    """Facade over ``repro.core.session.SessionRuntime``: the long-running
    counterpart of ``Planner`` for CONTINUOUS operation.

    Where ``Planner.run`` drains a fixed workload and returns, a Session
    stays live: recurring queries roll over window after window on one
    carried-over executor timeline, new queries are admitted (gated by a
    schedulability pre-flight) or withdrawn mid-run, and — with
    ``calibrate=True`` — cost models refit themselves from execution
    feedback, triggering replans of future windows when drift crosses the
    threshold::

        s = Session(policy="llf-dynamic", calibrate=True)
        s.submit(RecurringQuerySpec(base=q, period=60.0, num_windows=None))
        s.run_until(600.0)            # ten windows roll over
        s.submit(urgent_query)        # online admission at t=600
        s.run_until(1200.0)
        s.withdraw(q.query_id)
        series = s.trace.outcome_series(q.query_id)

    Accepts everything ``Planner.run`` accepts (policy name or instance,
    ``executor=``, ``workers=`` pool shorthand) plus the session knobs
    (``calibrate``, ``drift_threshold``, ``min_samples``, ``refit_every``,
    ``c_max``, ``admission_control``, ``start_time``), the pane-sharing
    knobs (``sharing=True`` to share pane partials across overlapping
    windows of queries on a common ``Query.stream``, ``pane_tuples`` to
    override the GCD pane width — docs/API.md "Pane sharing"), the
    overload knobs (``overload=``, ``on_renegotiate=`` — docs/API.md
    "Overload control"), the predictive-scheduling knob (``forecast=``
    — arrival forecasting, proactive shedding ahead of forecast bursts,
    speculative pane pre-warming; docs/API.md "Predictive scheduling")
    and the scaling knobs (``runtime="heap"`` for the O(log n) event-heap
    decision core, ``admission="incremental"`` for the maintained
    ``DemandLedger`` admission fast path — docs/API.md "Scaling the
    decision core").  ``tenancy=`` (a ``repro.core.tenancy.TenancyConfig``
    or a ``{tenant: TenantQuota}`` dict) turns on multi-tenant arbitration:
    per-tenant rate/capacity quotas at admission, weighted max-min fairness
    ACROSS tenants when overload shedding kicks in, and ``set_quota`` for
    runtime quota changes (docs/API.md "Multi-tenancy").
    """

    def __init__(self, policy: Union[str, SchedulingPolicy] = "llf-dynamic",
                 executor: Optional[Executor] = None, **session_kw):
        from .session import SessionRuntime

        self._runtime = SessionRuntime(policy, executor, **session_kw)

    # -- delegation (the facade IS the runtime, minus its internals) -----
    @property
    def policy(self) -> SchedulingPolicy:
        """The scheduling policy driving this session."""
        return self._runtime.policy

    @property
    def executor(self) -> Executor:
        """The session's (single, carried-over) execution backend."""
        return self._runtime.executor

    @property
    def now(self) -> float:
        """Current modelled time of the session's continuous timeline."""
        return self._runtime.now

    @property
    def trace(self):
        """The live ``SessionTrace``: executions, outcomes and session
        lifecycle events recorded so far."""
        return self._runtime.trace

    @property
    def live_ids(self) -> List[str]:
        """Base ids of every submitted, not-yet-withdrawn query."""
        return self._runtime.live_ids

    @property
    def book(self):
        """Pane-sharing bookkeeping (``repro.core.panes.SharedBook``) when
        the session runs with ``sharing=True``; None otherwise."""
        return self._runtime.book

    @property
    def pane_stats(self):
        """Pane-cache scan/hit/eviction counters (None without sharing)."""
        return self._runtime.pane_stats

    def calibrator(self, base_id: str):
        """The live ``CalibratingCostModel`` of ``base_id`` (None unless
        the session was built with ``calibrate=True``)."""
        return self._runtime.calibrator(base_id)

    def history(self, base_id: Optional[str] = None):
        """Public per-spec observation record
        (``repro.core.forecast.SpecHistory``): per-window realized arrival
        observations (collected at every window close, with or without
        ``forecast=``) plus the calibration loop's cost samples and the
        admission-time shed in force.  With ``base_id`` one spec's record;
        without, a dict over every spec ever submitted."""
        return self._runtime.history(base_id)

    def forecaster(self, base_id: str):
        """The live ``ArrivalForecaster`` of ``base_id`` (None unless the
        session was built with ``forecast=``)."""
        return self._runtime.forecaster(base_id)

    def submit(self, spec, *, force: bool = False):
        """Admit a Query / DynamicQuerySpec / RecurringQuerySpec into the
        live session, gated by the schedulability pre-flight
        (``repro.core.schedulability.admission_check``); ``force=True``
        records the report but admits regardless.  Returns an
        ``AdmissionResult`` (truthy iff admitted)."""
        return self._runtime.submit(spec, force=force)

    def withdraw(self, base_id: str) -> None:
        """Remove a live query mid-run: active windows are deleted at the
        next between-batch instant (§4.2), future windows never open."""
        self._runtime.withdraw(base_id)

    def set_quota(self, tenant: str, quota=None):
        """Set, replace or (``quota=None``) remove one tenant's
        ``TenantQuota`` at run time, then rebalance so a tightened quota
        immediately sheds that tenant's own live windows against its new
        share.  Returns the applied ``SheddingPlan`` (None when nothing
        had to move)."""
        return self._runtime.set_quota(tenant, quota)

    def rebalance(self):
        """Mid-run overload response: shed the minimum from the lowest
        tiers (fair shares first under ``tenancy=``) when the live set has
        drifted infeasible.  Returns the applied ``SheddingPlan`` or None."""
        return self._runtime.rebalance()

    def run_until(self, horizon: float, max_steps: int = 1_000_000):
        """Advance the continuous timeline to ``horizon``, processing every
        decision instant (window roll-overs, admissions, batches,
        recalibrations) on the way; returns the ``SessionTrace``."""
        return self._runtime.run_until(horizon, max_steps=max_steps)

    def run(self, max_steps: int = 1_000_000):
        """Drain every admitted window (bounded specs only — open-ended
        recurrence needs ``run_until``)."""
        return self._runtime.run(max_steps=max_steps)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return repr(self._runtime).replace("SessionRuntime", "Session", 1)
