"""Legacy dynamic multi-query entry point (paper §4, Algorithm 2).

Algorithm 2's event loop moved to ``repro.core.runtime`` (the single runtime
loop shared by every executor) and the per-strategy decision logic to
``repro.core.policies.dynamic`` (registered as ``llf-dynamic`` /
``edf-dynamic`` / ``sjf-dynamic`` / ``rr-dynamic``); ``schedule_dynamic``
below is a thin deprecation shim kept for the pre-Planner API.
``DynamicQuerySpec`` (the workload spec) now lives in
``repro.core.runtime`` and is re-exported here unchanged.

Migration:

    schedule_dynamic(specs, Strategy.LLF, delta_rsf=d, c_max=c)
        -> Planner(policy="llf-dynamic", delta_rsf=d, c_max=c).run(specs)
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from ._deprecation import warn_deprecated
from .runtime import DynamicQuerySpec, LARGE_NUMBER  # noqa: F401  (re-export)
from .types import BatchExecution, ExecutionTrace, Strategy

__all__ = ["DynamicQuerySpec", "LARGE_NUMBER", "schedule_dynamic"]


def schedule_dynamic(
    specs: Sequence[DynamicQuerySpec],
    strategy: Strategy = Strategy.LLF,
    delta_rsf: float = 0.5,
    c_max: float = 30.0,
    start_time: Optional[float] = None,
    max_steps: int = 1_000_000,
    on_batch: Optional[Callable[[BatchExecution], None]] = None,
) -> ExecutionTrace:
    """Deprecated shim for the ``<strategy>-dynamic`` policies."""
    warn_deprecated(
        "schedule_dynamic()",
        f'Planner(policy="{strategy.value}-dynamic").run(specs)',
    )
    from .policies.dynamic import policy_for_strategy
    from .runtime import SimulatedExecutor, run

    policy = policy_for_strategy(strategy, delta_rsf=delta_rsf, c_max=c_max)
    return run(
        policy,
        specs,
        SimulatedExecutor(),
        start_time=start_time,
        max_steps=max_steps,
        on_batch=on_batch,
    )
