"""Dynamic multi-query scheduling (paper §4, Algorithm 2).

Non-idling, non-preemptive (NINP) time-shared executor: whenever the executor
is free, every active query whose MinBatch is ready (or which is past its
estimated readiness time — §4.4 jitter handling) competes under the chosen
strategy (LLF / EDF / SJF / RR); the winner runs ONE MinBatch to completion.
Batch cost is bounded by C_max at MinBatch-sizing time, which bounds the
blocking period any newly arrived urgent query can suffer (§4.2-4.3).

The engine is a discrete-event simulation where cost units == time units
(exactly how the paper's §7 experiments report "cost").  The same decision
logic is reused by the real executors in ``repro.serve`` — they supply a
wall-clock ``now`` and real batch-execution callbacks.

Uncertainty handling (§4.4):
* rate jitter           — triggers fire on min(count-ready, estimated time);
* unknown total tuples  — slack uses an estimated total (observed rate x
                          window) refreshed at every decision instant.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .arrivals import ArrivalModel
from .minbatch import find_min_batch_size
from .types import (
    BatchExecution,
    ExecutionTrace,
    Query,
    QueryOutcome,
    Strategy,
)

LARGE_NUMBER = 1e18  # Algorithm 2's sentinel for "not ready"
_EPS = 1e-9


@dataclasses.dataclass
class DynamicQuerySpec:
    """One query as submitted to the dynamic scheduler.

    ``truth`` is the actual arrival process; planners only ever consult
    ``query.arrival`` (the predicted model).  ``delete_time`` models §4's
    "queries may be added or removed at any point".
    """

    query: Query
    truth: Optional[ArrivalModel] = None
    delete_time: Optional[float] = None
    num_groups: int = 0
    total_known: bool = True

    def __post_init__(self) -> None:
        if self.truth is None:
            self.truth = self.query.arrival


@dataclasses.dataclass
class _Runtime:
    spec: DynamicQuerySpec
    min_batch: int = 0
    processed: int = 0
    batches_done: int = 0
    admitted: bool = False
    deleted: bool = False
    completed: bool = False
    rr_seq: int = 0  # FIFO ticket for round-robin

    @property
    def q(self) -> Query:
        return self.spec.query

    def est_total(self, now: float) -> int:
        """Total tuples: known, or estimated from the observed rate (§4.4)."""
        if self.spec.total_known:
            return self.q.num_tuples_total
        seen = self.spec.truth.tuples_available(now)
        span = max(now - self.q.wind_start, _EPS)
        window = max(self.q.wind_end - self.q.wind_start, _EPS)
        if now >= self.q.wind_end:
            return seen
        return max(seen, int(math.ceil(seen / span * window)))

    def pending(self, now: float) -> int:
        return max(self.est_total(now) - self.processed, 0)

    def avail(self, now: float) -> int:
        return max(self.spec.truth.tuples_available(now) - self.processed, 0)

    def remaining_cost(self, now: float) -> float:
        """FindMinCompCost: pending tuples in MinBatch chunks + final agg."""
        pend = self.pending(now)
        if pend == 0:
            return 0.0
        cm = self.q.cost_model
        full, rem = divmod(pend, max(self.min_batch, 1))
        nb = full + (1 if rem else 0)
        c = full * cm.cost(self.min_batch) + (cm.cost(rem) if rem else 0.0)
        total_batches = self.batches_done + nb
        if total_batches > 1:
            c += cm.agg_cost(total_batches)
        return c

    def laxity(self, now: float) -> float:
        """Eq. (10): deadline - now - remaining cost."""
        return self.q.deadline - now - self.remaining_cost(now)

    def ready(self, now: float) -> bool:
        """MinBatch ready, or past the *predicted* readiness instant with
        something to process, or window over with a tail remainder (§4.4)."""
        if self.completed or self.deleted or not self.admitted:
            return False
        a = self.avail(now)
        if a <= 0:
            return False
        if a >= self.min_batch:
            return True
        est_ready = self.q.arrival.input_time(self.processed + self.min_batch)
        if now >= est_ready - _EPS:
            return True
        return now >= self.q.wind_end - _EPS and self.processed + a >= self.est_total(now)

    def next_ready_time(self, now: float) -> float:
        """Earliest future instant at which ``ready`` can flip true (sim only)."""
        if self.completed or self.deleted:
            return math.inf
        if not self.admitted:
            return self.q.submit_time
        truth = self.spec.truth
        want = self.processed + self.min_batch
        cands = [self.q.arrival.input_time(want)]  # predicted readiness (§4.4)
        if want <= truth.num_tuples_total:
            cands.append(truth.input_time(want))  # actual count-readiness
        elif truth.tuples_available(truth.wind_end) > self.processed:
            cands.append(max(self.q.wind_end, truth.input_time(truth.num_tuples_total)))
        t = min(cands)
        return t if t > now + _EPS else now + _EPS


def _priority(rt: _Runtime, now: float, strategy: Strategy) -> Tuple:
    if strategy is Strategy.LLF:
        return (rt.laxity(now), rt.q.deadline, rt.rr_seq)
    if strategy is Strategy.EDF:
        return (rt.q.deadline, rt.laxity(now), rt.rr_seq)
    if strategy is Strategy.SJF:
        return (rt.remaining_cost(now), rt.q.deadline, rt.rr_seq)
    if strategy is Strategy.RR:
        return (rt.rr_seq,)
    raise ValueError(strategy)


def schedule_dynamic(
    specs: Sequence[DynamicQuerySpec],
    strategy: Strategy = Strategy.LLF,
    delta_rsf: float = 0.5,
    c_max: float = 30.0,
    start_time: Optional[float] = None,
    max_steps: int = 1_000_000,
    on_batch: Optional[Callable[[BatchExecution], None]] = None,
) -> ExecutionTrace:
    """Algorithm 2 (generalised over the four strategies of §4.2).

    Returns the full execution trace with per-query outcomes.  ``on_batch``
    lets a real executor observe/perform each processed batch.
    """
    runts: List[_Runtime] = [_Runtime(spec=s) for s in specs]
    if not runts:
        return ExecutionTrace()
    now = (
        min(r.q.submit_time for r in runts) if start_time is None else start_time
    )
    trace = ExecutionTrace()
    rr_counter = 0

    for _ in range(max_steps):
        # -- admissions & deletions happen only between batches (§4.2:
        #    "the scheduler takes the new query at the end of the batch").
        for rt in runts:
            if not rt.admitted and rt.q.submit_time <= now + _EPS:
                rt.admitted = True
                rt.rr_seq = rr_counter
                rr_counter += 1
                rt.min_batch = find_min_batch_size(
                    rt.est_total(now) or 1,
                    rt.q.cost_model,
                    delta_rsf,
                    c_max,
                    rt.spec.num_groups,
                )
            if (
                rt.spec.delete_time is not None
                and not rt.deleted
                and rt.spec.delete_time <= now + _EPS
                and not rt.completed
            ):
                rt.deleted = True

        active = [r for r in runts if r.admitted and not (r.completed or r.deleted)]
        if not active and all(r.admitted or r.deleted for r in runts):
            break

        ready = [r for r in active if r.ready(now)]
        if not ready:
            nxt = min(
                [r.next_ready_time(now) for r in runts if not (r.completed or r.deleted)],
                default=math.inf,
            )
            if not math.isfinite(nxt):
                break
            now = nxt
            continue

        ready.sort(key=lambda r: _priority(r, now, strategy))
        rt = ready[0]
        rt.rr_seq = rr_counter  # rotate to the back for RR fairness
        rr_counter += 1

        take = min(rt.avail(now), rt.min_batch)
        cost = rt.q.cost_model.cost(take)
        ex = BatchExecution(rt.q.query_id, now, now + cost, take)
        trace.executions.append(ex)
        if on_batch:
            on_batch(ex)
        now += cost
        rt.processed += take
        rt.batches_done += 1

        # -- completion: everything that will ever arrive has been processed.
        done = (
            rt.processed >= rt.spec.truth.num_tuples_total
            if rt.spec.total_known
            else (
                now >= rt.spec.truth.wind_end - _EPS and rt.avail(now) == 0
            )
        )
        if done:
            agg = (
                rt.q.cost_model.agg_cost(rt.batches_done)
                if rt.batches_done > 1
                else 0.0
            )
            if agg > 0:
                ex = BatchExecution(rt.q.query_id, now, now + agg, 0, kind="final_agg")
                trace.executions.append(ex)
                if on_batch:
                    on_batch(ex)
                now += agg
            rt.completed = True
            trace.outcomes.append(
                QueryOutcome(
                    query_id=rt.q.query_id,
                    completion_time=now,
                    deadline=rt.q.deadline,
                    total_cost=sum(
                        e.end - e.start
                        for e in trace.executions
                        if e.query_id == rt.q.query_id
                    ),
                    num_batches=rt.batches_done,
                )
            )
    return trace
