"""Cost models (paper §2.2, Fig 1; §6.2 piecewise-linear fit).

A cost model answers three questions the planners need:

* ``cost(n)``            — cost (== time, in the paper's units) of processing
                           ``n`` tuples in ONE batch.  Eq. (1) for the linear
                           model: ``n * tupleProcCost + overheadCost``.
* ``tuples_processable(d)`` — ``EstTuplesProcessed``: max tuples one batch can
                           process within duration ``d`` (inverse of ``cost``).
* ``agg_cost(b)``        — final-aggregation cost when partials from ``b``
                           batches are combined (Eq. (4) context; §6.2 models
                           it as piecewise linear in the number of batches).

All models must be monotone non-decreasing in ``n``; the Algorithm-1 planner
works for ANY such model (§3.1 closing remark), which we exercise in tests.

Zero-batch convention (shared by every model): ``cost(0)`` is the fixed
per-batch overhead — the n->0 limit of the model, i.e. what dispatching an
empty batch would cost.  ``tuples_processable`` relies on it: a duration
below ``cost(0)`` cannot pay the overhead, so no tuples fit.  Negative
``n`` is not a batch; ``cost(n < 0)`` returns 0.0.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import List, Optional, Sequence, Tuple


class CostModelBase:
    """Interface; see module docstring."""

    def cost(self, num_tuples: int) -> float:
        """Cost of one batch of ``num_tuples``; ``cost(0)`` is the per-batch
        overhead (see the module docstring's zero-batch convention)."""
        raise NotImplementedError

    def agg_cost(self, num_batches: int) -> float:
        """Final-aggregation cost. Single-batch runs need no final agg (§2.1)."""
        raise NotImplementedError

    def merge_cost(self, num_panes: int) -> float:
        """Cost of folding ``num_panes`` cached pane partial aggregates into
        a query's running state (pane sharing, ``repro.core.panes``).

        Merging pane partials is the same kind of work as the final
        aggregation's partial combine — the accumulator plus ``num_panes``
        partials — so the default prices it as ``agg_cost(num_panes + 1)``.
        Models whose aggregation is free (the paper's §3.1 worked examples)
        therefore merge for free too.  ``merge_cost(0)`` is 0.
        """
        if num_panes <= 0:
            return 0.0
        return self.agg_cost(num_panes + 1)

    # -- derived ---------------------------------------------------------
    def tuples_processable(self, duration: float, hi: int = 1 << 40) -> int:
        """EstTuplesProcessed(q, duration): largest n with cost(n) <= duration.

        Generic integer bisection so arbitrary monotone models work; linear
        models override with a closed form.
        """
        if duration < 0 or self.cost(0) > duration:
            # Cannot even pay the per-batch overhead.
            return 0
        lo, hi_ = 0, 1
        while hi_ < hi and self.cost(hi_) <= duration:
            lo, hi_ = hi_, hi_ * 2
        # invariant: cost(lo) <= duration < cost(hi_)
        while lo + 1 < hi_:
            mid = (lo + hi_) // 2
            if self.cost(mid) <= duration:
                lo = mid
            else:
                hi_ = mid
        return lo

    def batched_cost(self, num_tuples: int, batch_size: int) -> float:
        """Total cost of processing ``num_tuples`` in chunks of ``batch_size``
        plus the final aggregation (used by MinBatch sizing, §4.1)."""
        if batch_size <= 0:
            raise ValueError("batch_size must be positive")
        full, rem = divmod(num_tuples, batch_size)
        nb = full + (1 if rem else 0)
        c = full * self.cost(batch_size) + (self.cost(rem) if rem else 0.0)
        if nb > 1:
            c += self.agg_cost(nb)
        return c


@dataclasses.dataclass(frozen=True)
class LinearCostModel(CostModelBase):
    """Eq. (1): compCost = n * tuple_cost + overhead  (per batch).

    ``agg_tuple_cost``: final aggregation modelled as linear in the number of
    batches (each batch contributes one partial-aggregate file, §6.1/6.2),
    plus a fixed ``agg_overhead`` — 0 by default so the paper's §3.1 worked
    examples (no aggregation cost) hold exactly.
    """

    tuple_cost: float
    overhead: float = 0.0
    agg_per_batch: float = 0.0
    agg_overhead: float = 0.0

    def cost(self, num_tuples: int) -> float:
        """Eq. (1): ``n * tuple_cost + overhead`` (``cost(0)`` = overhead)."""
        if num_tuples <= 0:
            return self.overhead if num_tuples == 0 else 0.0
        return num_tuples * self.tuple_cost + self.overhead

    def agg_cost(self, num_batches: int) -> float:
        """Linear-in-batches final aggregation; free for single batches."""
        if num_batches <= 1:
            return 0.0
        return num_batches * self.agg_per_batch + self.agg_overhead

    def tuples_processable(self, duration: float, hi: int = 1 << 40) -> int:
        """Closed-form inverse of ``cost`` (caps at ``hi`` for free models)."""
        if duration < self.overhead:
            return 0
        if self.tuple_cost <= 0:
            return hi
        return int(math.floor((duration - self.overhead) / self.tuple_cost + 1e-9))


@dataclasses.dataclass(frozen=True)
class PiecewiseLinearCostModel(CostModelBase):
    """§6.2: measured (batch-size, cost) samples fitted piecewise-linearly.

    ``points`` are (num_tuples, cost) knots sorted by num_tuples; costs are
    linearly interpolated between knots and extrapolated from the last
    segment's slope beyond them.  ``agg_points`` similarly maps
    (num_batches, agg_cost).
    """

    points: Tuple[Tuple[float, float], ...]
    agg_points: Tuple[Tuple[float, float], ...] = ((1, 0.0),)

    def __post_init__(self) -> None:
        self._validate("points", self.points, min_knots=2)
        # agg_points feed the same ``bisect``-based interpolation: unsorted
        # or non-monotone agg knots silently mis-interpolate, so they get
        # the same validation (a single (1, 0.0) knot — "no agg cost" — is
        # the legitimate minimal form).
        self._validate("agg_points", self.agg_points, min_knots=1)

    @staticmethod
    def _validate(
        label: str, points: Sequence[Tuple[float, float]], min_knots: int
    ) -> None:
        xs = [p[0] for p in points]
        if xs != sorted(xs) or len(set(xs)) != len(xs) or len(xs) < min_knots:
            raise ValueError(
                f"{label} must be >={min_knots} knots strictly sorted by x, "
                f"got {tuple(points)!r}"
            )
        cs = [p[1] for p in points]
        if any(b < a - 1e-12 for a, b in zip(cs, cs[1:])):
            raise ValueError(f"{label} cost must be monotone non-decreasing")

    @staticmethod
    def _interp(points: Sequence[Tuple[float, float]], x: float) -> float:
        if len(points) == 1:
            return points[0][1]
        xs = [p[0] for p in points]
        i = bisect.bisect_left(xs, x)
        if i < len(xs) and xs[i] == x:
            return points[i][1]
        if i == 0:
            (x0, y0), (x1, y1) = points[0], points[1]
        elif i == len(xs):
            (x0, y0), (x1, y1) = points[-2], points[-1]
        else:
            (x0, y0), (x1, y1) = points[i - 1], points[i]
        if x1 == x0:
            return y0
        t = (x - x0) / (x1 - x0)
        return y0 + t * (y1 - y0)

    def cost(self, num_tuples: int) -> float:
        """Interpolated batch cost from the fitted knots."""
        if num_tuples < 0:
            return 0.0
        if num_tuples == 0:
            # Zero-batch convention: the fitted model's per-batch overhead is
            # the first segment extrapolated to n=0 (clamped — measured knots
            # can extrapolate below zero).
            return max(0.0, self._interp(self.points, 0.0))
        return max(0.0, self._interp(self.points, float(num_tuples)))

    def agg_cost(self, num_batches: int) -> float:
        """Interpolated final-aggregation cost from the ``agg_points``."""
        if num_batches <= 1:
            return 0.0
        return max(0.0, self._interp(self.agg_points, float(num_batches)))


@dataclasses.dataclass(frozen=True)
class SublinearCostModel(CostModelBase):
    """Fig 1's non-linear curve: cost grows sublinearly with batch size
    (``scale * n**exponent + overhead``, exponent in (0, 1]).  Used in tests to
    show Algorithm 1 handles arbitrary monotone models."""

    scale: float
    exponent: float = 0.85
    overhead: float = 0.0
    agg_per_batch: float = 0.0

    def cost(self, num_tuples: int) -> float:
        """``scale * n**exponent + overhead`` (sublinear in batch size)."""
        if num_tuples < 0:
            return 0.0
        if num_tuples == 0:
            return self.overhead  # zero-batch convention: n->0 limit
        return self.scale * float(num_tuples) ** self.exponent + self.overhead

    def agg_cost(self, num_batches: int) -> float:
        """Linear-in-batches final aggregation; free for single batches."""
        if num_batches <= 1:
            return 0.0
        return num_batches * self.agg_per_batch


class SharedCostModel(CostModelBase):
    """Per-query cost under pane-based shared execution: one scan + k merges.

    ``sharers`` queries subscribe to the same stream; a pane batch of ``n``
    tuples is SCANNED once for all of them and each subscriber folds the
    pane partials into its own state at merge cost.  The per-query charge is
    therefore the amortized share of the scan plus this query's merges::

        cost(n) = base.cost(n) / sharers + base.merge_cost(ceil(n / pane))

    Summed over all ``sharers`` processing the same ``n`` tuples this
    recovers exactly ``base.cost(n) + sharers * merges`` — the shared-batch
    total — while each individual query (and therefore every policy's
    laxity/remaining-cost computation, MinBatch sizing and
    ``admission_check``) sees the CHEAPER shared cost instead of a full
    private scan.  ``agg_cost`` passes through unchanged: the final
    aggregation stays per query.

    ``sharers`` is mutable on purpose: a session updates it as queries join
    or leave a stream, and every window query holding this instance sees the
    new amortization immediately (same pattern as ``CalibratingCostModel``).
    Wrap a ``CalibratingCostModel`` to compose sharing with online
    calibration — observations then calibrate the SHARED per-query cost,
    which is also what the executor charges.
    """

    def __init__(self, base: CostModelBase, sharers: int, pane_tuples: int):
        if sharers < 1:
            raise ValueError(f"sharers must be >= 1, got {sharers}")
        if pane_tuples < 1:
            raise ValueError(f"pane_tuples must be >= 1, got {pane_tuples}")
        self.base = base
        self.sharers = sharers
        self.pane_tuples = pane_tuples

    def cost(self, num_tuples: int) -> float:
        """Amortized shared-batch cost (see class docstring); monotone
        whenever ``base`` is."""
        if num_tuples < 0:
            return 0.0
        scan = self.base.cost(num_tuples) / max(self.sharers, 1)
        if num_tuples == 0:
            return scan  # zero-batch convention: the amortized overhead
        panes = -(-num_tuples // self.pane_tuples)  # ceil
        return scan + self.base.merge_cost(panes)

    def agg_cost(self, num_batches: int) -> float:
        """Final aggregation is per query — delegates to the base model."""
        return self.base.agg_cost(num_batches)

    def merge_cost(self, num_panes: int) -> float:
        """Pane merges are physical work on the base model's terms."""
        return self.base.merge_cost(num_panes)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (
            f"SharedCostModel(sharers={self.sharers}, "
            f"pane_tuples={self.pane_tuples}, base={self.base!r})"
        )


class ShardedCostModel(CostModelBase):
    """Planning view of W-way fused shard dispatch (mesh execution).

    On a device mesh a logical batch of ``n`` tuples is split into W
    near-equal shards that run CONCURRENTLY as one fused ``shard_map``
    call, so its wall time is the cost of one ``ceil(n / ways)``-tuple
    shard — per-batch overhead (dispatch, one compiled call) is paid once
    per GROUP, not once per shard.  Exposing that parallel cost to the
    planners makes Eq. 9's MinBatch ~W times larger: W times fewer logical
    batches, each amortizing its overhead over W shards — the paper's
    overhead-amortization argument applied to dispatch fan-out.

    The modelled executor must NOT advance a single worker's clock by this
    parallel cost for an n-tuple shard; ``shard_cost`` supplies the
    per-shard charge (the base model's cost of the shard's own tuples) and
    ``BaseExecutor._modelled_batch_cost`` prefers it when present.

    ``agg_cost``/``merge_cost`` pass through: partial combination is not
    sharded.  Monotone whenever ``base`` is, so the generic
    ``tuples_processable`` bisection stands.
    """

    def __init__(self, base: CostModelBase, ways: int):
        if ways < 1:
            raise ValueError(f"ways must be >= 1, got {ways}")
        self.base = base
        self.ways = ways

    def cost(self, num_tuples: int) -> float:
        """Parallel wall time of one fused W-way dispatch of ``n`` tuples:
        the largest shard's cost."""
        if num_tuples < 0:
            return 0.0
        if num_tuples == 0:
            return self.base.cost(0)  # zero-batch convention: one overhead
        return self.base.cost(-(-num_tuples // self.ways))

    def shard_cost(self, num_tuples: int) -> float:
        """Per-shard charge for a worker clock: the shard's own tuples at
        the base model's (sequential) cost."""
        return self.base.cost(num_tuples)

    def agg_cost(self, num_batches: int) -> float:
        return self.base.agg_cost(num_batches)

    def merge_cost(self, num_panes: int) -> float:
        return self.base.merge_cost(num_panes)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"ShardedCostModel(ways={self.ways}, base={self.base!r})"


def _isotonic(samples: Sequence[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Sort, dedupe (max y per x — repeated measurements of one size), and
    make costs monotone by cumulative max: measurement noise can otherwise
    produce a locally decreasing cost, which the planners' inversion logic
    and the knot validation reject."""
    by_x: dict = {}
    for x, y in samples:
        x, y = float(x), float(y)
        by_x[x] = max(y, by_x.get(x, y))
    mono: List[Tuple[float, float]] = []
    running = 0.0
    for x in sorted(by_x):
        running = max(running, by_x[x])
        mono.append((x, running))
    return mono


def fit_piecewise_linear(
    samples: Sequence[Tuple[float, float]],
    agg_samples: Sequence[Tuple[float, float]] = ((1, 0.0),),
) -> PiecewiseLinearCostModel:
    """§6.2 cost modelling: fit measured (batch_size, time) samples.

    We keep the measured points as knots after isotonic cleanup — applied to
    BOTH the per-batch samples and the final-aggregation samples, which feed
    the same interpolation.
    """
    mono = _isotonic(samples)
    if len(mono) == 1:
        x, y = mono[0]
        mono.append((x + 1.0, y))
    return PiecewiseLinearCostModel(
        points=tuple(mono), agg_points=tuple(_isotonic(agg_samples))
    )


class CalibratingCostModel(CostModelBase):
    """Self-calibrating wrapper: §6.2's offline fit made CONTINUOUS.

    The paper fits its piecewise-linear cost model once, offline, from
    measured batches; a long-running session cannot afford that — data
    distributions, cluster load and compilation caches shift, so predicted
    batch costs drift away from observed wall times.  This wrapper

    * starts out delegating to ``base`` (the offline fit);
    * records ``(num_tuples, observed_cost)`` pairs from execution feedback
      (``observe``; final-aggregation pairs via ``observe_agg``);
    * refits its knots every ``refit_every`` observations once
      ``min_samples`` have accumulated, through ``fit_piecewise_linear``'s
      isotonic path (same cleanup as the offline fit);
    * exposes ``drift()`` — mean relative |observed - predicted| over the
      last ``window`` observations, where "predicted" is what the model in
      effect AT OBSERVATION TIME said.  A session compares it against its
      drift threshold to trigger replanning of future windows.

    Mutable by design: every Query holding this instance (all windows of a
    recurring query) sees refits immediately — dynamic policies consult
    ``cost``/``agg_cost`` at each decision instant, so refits steer
    priorities and MinBatch re-sizing without object swapping.
    """

    def __init__(
        self,
        base: CostModelBase,
        *,
        min_samples: int = 4,
        refit_every: int = 8,
        window: int = 64,
        max_samples: int = 4096,
    ):
        if min_samples < 2:
            raise ValueError("min_samples must be >= 2 (a fit needs 2 knots)")
        if refit_every < 1:
            raise ValueError("refit_every must be >= 1")
        if window < 1:
            raise ValueError("window must be >= 1")
        if max_samples < 1:
            # 0 would make the `del lst[:-0 or None]` trim wipe the buffer.
            raise ValueError("max_samples must be >= 1")
        self.base = base
        self.min_samples = min_samples
        self.refit_every = refit_every
        self.window = window
        self.max_samples = max_samples
        self._samples: List[Tuple[float, float]] = []
        self._agg_samples: List[Tuple[float, float]] = []
        self._errors: List[float] = []   # relative error per observation
        # worker name -> observed/predicted cost ratios (window-capped):
        # per-device calibration on a heterogeneous mesh.  The pooled fit
        # absorbs the AVERAGE level; these capture each device's deviation
        # from it (see ``worker_scale``).
        self._worker_ratios: dict = {}
        self._fitted: Optional[PiecewiseLinearCostModel] = None
        self._fitted_agg = False  # did the current fit include agg samples?
        self._since_refit = 0
        self.refits = 0

    # -- CostModelBase ---------------------------------------------------
    def cost(self, num_tuples: int) -> float:
        model = self.base if self._fitted is None else self._fitted
        return model.cost(num_tuples)

    def agg_cost(self, num_batches: int) -> float:
        # Agg knots come from the fit only when the FIT saw agg feedback;
        # per-batch refits alone must not zero out the base model's
        # aggregation cost.
        if not self._fitted_agg or self._fitted is None:
            return self.base.agg_cost(num_batches)
        return self._fitted.agg_cost(num_batches)

    # -- feedback --------------------------------------------------------
    @property
    def calibrated(self) -> bool:
        """True once at least one refit has replaced the offline base fit."""
        return self._fitted is not None

    @property
    def num_observations(self) -> int:
        """Per-batch feedback samples currently buffered."""
        return len(self._samples)

    @property
    def samples(self) -> Tuple[Tuple[float, float], ...]:
        """Read-only view of the buffered per-batch feedback, as
        ``(num_tuples, observed_cost)`` pairs in observation order — the
        public face of the calibration history (``Session.history()`` and
        the forecasting subsystem consume this instead of reaching into
        the private buffers)."""
        return tuple(self._samples)

    @property
    def agg_samples(self) -> Tuple[Tuple[float, float], ...]:
        """Read-only view of the buffered final-aggregation feedback, as
        ``(num_batches, observed_cost)`` pairs in observation order."""
        return tuple(self._agg_samples)

    def observe(
        self,
        num_tuples: int,
        observed_cost: float,
        worker: Optional[str] = None,
    ) -> None:
        """Record one executed batch: ``observed_cost`` is the batch's true
        duration (modelled true cost in simulation, wall seconds on a real
        backend — cost units == time units, §1).

        ``worker`` (when the dispatching executor is a pool) additionally
        feeds per-device calibration: each worker accumulates its own
        observed/predicted ratios, so ``worker_scale``/``worker_weights``
        can expose REAL per-shard speed skew to the planners (weighted
        shard extents on a heterogeneous mesh)."""
        if num_tuples <= 0 or observed_cost < 0:
            return
        predicted = self.cost(num_tuples)
        scale = max(abs(observed_cost), abs(predicted), 1e-12)
        self._errors.append(abs(observed_cost - predicted) / scale)
        del self._errors[: -self.window or None]
        if worker is not None and predicted > 1e-12:
            ratios = self._worker_ratios.setdefault(worker, [])
            ratios.append(observed_cost / predicted)
            del ratios[: -self.window or None]
        self._samples.append((float(num_tuples), float(observed_cost)))
        del self._samples[: -self.max_samples or None]
        self._since_refit += 1
        if (
            len(self._samples) >= self.min_samples
            and self._since_refit >= self.refit_every
        ):
            self.refit_now()

    def observe_agg(self, num_batches: int, observed_cost: float) -> None:
        """Record one executed final aggregation (its true duration, like
        ``observe`` for batches)."""
        if num_batches <= 1 or observed_cost < 0:
            return
        self._agg_samples.append((float(num_batches), float(observed_cost)))
        del self._agg_samples[: -self.max_samples or None]
        if self._fitted is not None:
            # Fold the (rare: one per multi-batch query) agg sample straight
            # into the already-calibrated fit.
            self.refit_now()

    def _knots(self, samples, base_fn):
        """Knots for one axis of the refit.

        Rich feedback (>= 3 distinct sizes) fits the raw measurements —
        exactly §6.2 with fresher data.  Sparse feedback (a session that so
        far only ran MinBatch-sized batches) cannot pin down a shape, and
        raw knots would extrapolate FLAT (poisoning ``cost(1)`` and
        therefore MinBatch sizing and C_max checks); instead the BASE
        model's shape is kept and its level corrected by the median
        observed/predicted ratio (a multiplicative drift correction).
        """
        xs = sorted({x for x, _ in samples})
        if len(xs) >= 3:
            return samples
        ratios = sorted(
            y / base_fn(int(x)) for x, y in samples if base_fn(int(x)) > 1e-12
        )
        r = ratios[len(ratios) // 2] if ratios else 1.0
        grid = sorted({1.0, *xs, 2.0 * max(xs)})
        return [(x, r * base_fn(int(x))) for x in grid]

    def refit_now(self) -> bool:
        """Refit immediately (a session's drift trigger); False when there
        are not yet enough samples for a meaningful fit."""
        if len(self._samples) < self.min_samples:
            return False
        if self._agg_samples:
            agg = [(1.0, 0.0),
                   *self._knots(self._agg_samples, self.base.agg_cost)]
        else:
            agg = ((1, 0.0),)
        self._fitted = fit_piecewise_linear(
            self._knots(self._samples, self.base.cost), agg
        )
        self._fitted_agg = bool(self._agg_samples)
        self._since_refit = 0
        self._errors.clear()  # errors measured against the superseded model
        self.refits += 1
        return True

    def drift(self) -> float:
        """Mean relative prediction error SINCE THE LAST REFIT (0 = the
        current model predicted every observed cost exactly).  Resets on
        refit, so a session trigger (`drift() > threshold` -> ``refit_now``)
        does not immediately re-fire."""
        if not self._errors:
            return 0.0
        recent = self._errors[-self.window:]
        return sum(recent) / len(recent)

    # -- per-device calibration ------------------------------------------
    @staticmethod
    def _median(values: List[float]) -> float:
        s = sorted(values)
        n = len(s)
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])

    def worker_scale(self, worker: str) -> float:
        """Relative cost multiplier of ``worker`` vs the pool average:
        >1 means slower than its peers, <1 faster, 1.0 when there is not
        yet enough evidence (fewer than 2 samples for this worker).

        Computed as this worker's median observed/predicted ratio divided
        by the median over ALL per-worker observations, so the pooled fit
        (which absorbs the average level) and the per-device deviations
        compose instead of double-counting drift."""
        ratios = self._worker_ratios.get(worker)
        if not ratios or len(ratios) < 2:
            return 1.0
        pooled = [r for rs in self._worker_ratios.values() for r in rs]
        base = self._median(pooled)
        if base <= 1e-12:
            return 1.0
        return self._median(ratios) / base

    def worker_cost(self, num_tuples: int, worker: str) -> float:
        """Predicted cost of one batch ON ``worker`` — the pooled model's
        prediction scaled by the device's calibrated deviation."""
        return self.cost(num_tuples) * self.worker_scale(worker)

    def worker_weights(self, names: Sequence[str]) -> Tuple[float, ...]:
        """Relative worker SPEEDS aligned with ``names`` (inverse cost
        scales, normalized to mean 1.0) — the shape
        ``weighted_shard_extents`` consumes.  All-1.0 until at least one
        worker has calibrated away from its peers."""
        inv = [1.0 / max(self.worker_scale(n), 1e-12) for n in names]
        if not inv:
            return ()
        mean = sum(inv) / len(inv)
        if mean <= 1e-12:
            return (1.0,) * len(inv)
        return tuple(v / mean for v in inv)
