"""Cost models (paper §2.2, Fig 1; §6.2 piecewise-linear fit).

A cost model answers three questions the planners need:

* ``cost(n)``            — cost (== time, in the paper's units) of processing
                           ``n`` tuples in ONE batch.  Eq. (1) for the linear
                           model: ``n * tupleProcCost + overheadCost``.
* ``tuples_processable(d)`` — ``EstTuplesProcessed``: max tuples one batch can
                           process within duration ``d`` (inverse of ``cost``).
* ``agg_cost(b)``        — final-aggregation cost when partials from ``b``
                           batches are combined (Eq. (4) context; §6.2 models
                           it as piecewise linear in the number of batches).

All models must be monotone non-decreasing in ``n``; the Algorithm-1 planner
works for ANY such model (§3.1 closing remark), which we exercise in tests.

Zero-batch convention (shared by every model): ``cost(0)`` is the fixed
per-batch overhead — the n->0 limit of the model, i.e. what dispatching an
empty batch would cost.  ``tuples_processable`` relies on it: a duration
below ``cost(0)`` cannot pay the overhead, so no tuples fit.  Negative
``n`` is not a batch; ``cost(n < 0)`` returns 0.0.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import List, Sequence, Tuple


class CostModelBase:
    """Interface; see module docstring."""

    def cost(self, num_tuples: int) -> float:
        """Cost of one batch of ``num_tuples``; ``cost(0)`` is the per-batch
        overhead (see the module docstring's zero-batch convention)."""
        raise NotImplementedError

    def agg_cost(self, num_batches: int) -> float:
        """Final-aggregation cost. Single-batch runs need no final agg (§2.1)."""
        raise NotImplementedError

    # -- derived ---------------------------------------------------------
    def tuples_processable(self, duration: float, hi: int = 1 << 40) -> int:
        """EstTuplesProcessed(q, duration): largest n with cost(n) <= duration.

        Generic integer bisection so arbitrary monotone models work; linear
        models override with a closed form.
        """
        if duration < 0 or self.cost(0) > duration:
            # Cannot even pay the per-batch overhead.
            return 0
        lo, hi_ = 0, 1
        while hi_ < hi and self.cost(hi_) <= duration:
            lo, hi_ = hi_, hi_ * 2
        # invariant: cost(lo) <= duration < cost(hi_)
        while lo + 1 < hi_:
            mid = (lo + hi_) // 2
            if self.cost(mid) <= duration:
                lo = mid
            else:
                hi_ = mid
        return lo

    def batched_cost(self, num_tuples: int, batch_size: int) -> float:
        """Total cost of processing ``num_tuples`` in chunks of ``batch_size``
        plus the final aggregation (used by MinBatch sizing, §4.1)."""
        if batch_size <= 0:
            raise ValueError("batch_size must be positive")
        full, rem = divmod(num_tuples, batch_size)
        nb = full + (1 if rem else 0)
        c = full * self.cost(batch_size) + (self.cost(rem) if rem else 0.0)
        if nb > 1:
            c += self.agg_cost(nb)
        return c


@dataclasses.dataclass(frozen=True)
class LinearCostModel(CostModelBase):
    """Eq. (1): compCost = n * tuple_cost + overhead  (per batch).

    ``agg_tuple_cost``: final aggregation modelled as linear in the number of
    batches (each batch contributes one partial-aggregate file, §6.1/6.2),
    plus a fixed ``agg_overhead`` — 0 by default so the paper's §3.1 worked
    examples (no aggregation cost) hold exactly.
    """

    tuple_cost: float
    overhead: float = 0.0
    agg_per_batch: float = 0.0
    agg_overhead: float = 0.0

    def cost(self, num_tuples: int) -> float:
        if num_tuples <= 0:
            return self.overhead if num_tuples == 0 else 0.0
        return num_tuples * self.tuple_cost + self.overhead

    def agg_cost(self, num_batches: int) -> float:
        if num_batches <= 1:
            return 0.0
        return num_batches * self.agg_per_batch + self.agg_overhead

    def tuples_processable(self, duration: float, hi: int = 1 << 40) -> int:
        if duration < self.overhead:
            return 0
        if self.tuple_cost <= 0:
            return hi
        return int(math.floor((duration - self.overhead) / self.tuple_cost + 1e-9))


@dataclasses.dataclass(frozen=True)
class PiecewiseLinearCostModel(CostModelBase):
    """§6.2: measured (batch-size, cost) samples fitted piecewise-linearly.

    ``points`` are (num_tuples, cost) knots sorted by num_tuples; costs are
    linearly interpolated between knots and extrapolated from the last
    segment's slope beyond them.  ``agg_points`` similarly maps
    (num_batches, agg_cost).
    """

    points: Tuple[Tuple[float, float], ...]
    agg_points: Tuple[Tuple[float, float], ...] = ((1, 0.0),)

    def __post_init__(self) -> None:
        self._validate("points", self.points, min_knots=2)
        # agg_points feed the same ``bisect``-based interpolation: unsorted
        # or non-monotone agg knots silently mis-interpolate, so they get
        # the same validation (a single (1, 0.0) knot — "no agg cost" — is
        # the legitimate minimal form).
        self._validate("agg_points", self.agg_points, min_knots=1)

    @staticmethod
    def _validate(
        label: str, points: Sequence[Tuple[float, float]], min_knots: int
    ) -> None:
        xs = [p[0] for p in points]
        if xs != sorted(xs) or len(set(xs)) != len(xs) or len(xs) < min_knots:
            raise ValueError(
                f"{label} must be >={min_knots} knots strictly sorted by x, "
                f"got {tuple(points)!r}"
            )
        cs = [p[1] for p in points]
        if any(b < a - 1e-12 for a, b in zip(cs, cs[1:])):
            raise ValueError(f"{label} cost must be monotone non-decreasing")

    @staticmethod
    def _interp(points: Sequence[Tuple[float, float]], x: float) -> float:
        if len(points) == 1:
            return points[0][1]
        xs = [p[0] for p in points]
        i = bisect.bisect_left(xs, x)
        if i < len(xs) and xs[i] == x:
            return points[i][1]
        if i == 0:
            (x0, y0), (x1, y1) = points[0], points[1]
        elif i == len(xs):
            (x0, y0), (x1, y1) = points[-2], points[-1]
        else:
            (x0, y0), (x1, y1) = points[i - 1], points[i]
        if x1 == x0:
            return y0
        t = (x - x0) / (x1 - x0)
        return y0 + t * (y1 - y0)

    def cost(self, num_tuples: int) -> float:
        if num_tuples < 0:
            return 0.0
        if num_tuples == 0:
            # Zero-batch convention: the fitted model's per-batch overhead is
            # the first segment extrapolated to n=0 (clamped — measured knots
            # can extrapolate below zero).
            return max(0.0, self._interp(self.points, 0.0))
        return max(0.0, self._interp(self.points, float(num_tuples)))

    def agg_cost(self, num_batches: int) -> float:
        if num_batches <= 1:
            return 0.0
        return max(0.0, self._interp(self.agg_points, float(num_batches)))


@dataclasses.dataclass(frozen=True)
class SublinearCostModel(CostModelBase):
    """Fig 1's non-linear curve: cost grows sublinearly with batch size
    (``scale * n**exponent + overhead``, exponent in (0, 1]).  Used in tests to
    show Algorithm 1 handles arbitrary monotone models."""

    scale: float
    exponent: float = 0.85
    overhead: float = 0.0
    agg_per_batch: float = 0.0

    def cost(self, num_tuples: int) -> float:
        if num_tuples < 0:
            return 0.0
        if num_tuples == 0:
            return self.overhead  # zero-batch convention: n->0 limit
        return self.scale * float(num_tuples) ** self.exponent + self.overhead

    def agg_cost(self, num_batches: int) -> float:
        if num_batches <= 1:
            return 0.0
        return num_batches * self.agg_per_batch


def _isotonic(samples: Sequence[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Sort, dedupe (max y per x — repeated measurements of one size), and
    make costs monotone by cumulative max: measurement noise can otherwise
    produce a locally decreasing cost, which the planners' inversion logic
    and the knot validation reject."""
    by_x: dict = {}
    for x, y in samples:
        x, y = float(x), float(y)
        by_x[x] = max(y, by_x.get(x, y))
    mono: List[Tuple[float, float]] = []
    running = 0.0
    for x in sorted(by_x):
        running = max(running, by_x[x])
        mono.append((x, running))
    return mono


def fit_piecewise_linear(
    samples: Sequence[Tuple[float, float]],
    agg_samples: Sequence[Tuple[float, float]] = ((1, 0.0),),
) -> PiecewiseLinearCostModel:
    """§6.2 cost modelling: fit measured (batch_size, time) samples.

    We keep the measured points as knots after isotonic cleanup — applied to
    BOTH the per-batch samples and the final-aggregation samples, which feed
    the same interpolation.
    """
    mono = _isotonic(samples)
    if len(mono) == 1:
        x, y = mono[0]
        mono.append((x + 1.0, y))
    return PiecewiseLinearCostModel(
        points=tuple(mono), agg_points=tuple(_isotonic(agg_samples))
    )
