"""Single-query scheduling policies under static scenarios (paper §3,
Algorithm 1) — the canonical implementations (moved here from
``repro.core.single_query``, whose public functions are now deprecation
shims over these).

Backward construction (function ``ScheduleWithoutAggCost`` in the paper):

    last batch:   fills [windEnd, deadline'] — capacity there decides how many
                  tuples can wait for the end of the window.
    earlier ones: pending tuples get deadline = start of the batch scheduled
                  after them; input availability (InputTime) lower-bounds each
                  batch's start; recurse until all tuples are placed.

``ScheduleWithAggCost`` iterates the assumed batch count until the final-
aggregation allowance is consistent with the produced plan (Eq. (4)).

Works for ANY monotone cost model (closing remark of §3.1) — only
``cost``/``tuples_processable``/``agg_cost`` are used.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

from ..api import register_policy, as_queries
from ..cost_model import CostModelBase
from ..types import Batch, InfeasibleDeadline, Plan, PolicyDecision, Query, Schedule

_MAX_BATCHES = 10_000  # guard against degenerate cost models
_EPS = 1e-9


# ---------------------------------------------------------------------------
# Algorithm implementations
# ---------------------------------------------------------------------------


def plan_without_agg_cost(query: Query, deadline: float) -> Schedule:
    """Backward-greedy optimal plan ignoring final-aggregation cost.

    Returns batches sorted by sched_time (earliest first).
    Raises InfeasibleDeadline if no plan exists under the cost/arrival models.
    """
    cm, arr = query.cost_model, query.arrival
    total = query.num_tuples_total
    if total == 0:
        return Schedule(batches=())

    # Uniform backward recursion.  The first iteration is the paper's "last
    # batch" (its availability bound input_time(N) IS the window end); later
    # iterations are the pre-window batches.  One deliberate repair over the
    # paper's §3.1 prose: every batch — including the last — starts AS LATE AS
    # POSSIBLE (time_pt - cost(k)), the same principle as the paper's Eq. (3)
    # for the single-batch case.  Anchoring the last batch at windowEnd, as
    # the prose states, discards the slack between windEnd + cost(k_last) and
    # the deadline; with per-batch overheads that slack can buy the
    # predecessor batch more room, and hypothesis found instances where the
    # as-stated greedy needs one batch more than the paper's own §3.2
    # constraint solver.  With late starts the two methods agree everywhere
    # we test (as the paper reports for its experiments).  The paper's worked
    # Cases 1-4 are unchanged: their last-batch capacity binds exactly.
    batches_rev: List[Batch] = []
    pending = total
    time_pt = deadline
    while pending > 0:
        if len(batches_rev) >= _MAX_BATCHES:
            raise InfeasibleDeadline(
                f"{query.query_id}: exceeded {_MAX_BATCHES} batches"
            )
        ip_avail = arr.input_time(pending)  # when the last pending tuple lands
        dur = time_pt - ip_avail
        n_proc = min(cm.tuples_processable(dur), pending)
        if n_proc <= 0:
            raise InfeasibleDeadline(
                f"{query.query_id}: cannot place {pending} tuples before "
                f"t={time_pt:.6g} (available only from t={ip_avail:.6g})"
            )
        # Run as late as possible: start = time_pt - cost(n_proc) >= ip_avail.
        start = time_pt - cm.cost(n_proc)
        batches_rev.append(Batch(sched_time=start, num_tuples=n_proc))
        pending -= n_proc
        time_pt = start

    return Schedule(batches=tuple(reversed(batches_rev)))


def plan_with_agg_cost(query: Query) -> Schedule:
    """Fix the (#batches <-> agg-cost) circularity (paper function
    ScheduleWithAggCost, Eq. (4)).

    Assume ``i`` batches, shift the effective deadline earlier by
    ``agg_cost(i)``, plan, and repeat with a larger allowance while the plan
    needs more batches than assumed.
    """
    cm = query.cost_model
    i = 1
    while i <= _MAX_BATCHES:
        eff_deadline = query.deadline - cm.agg_cost(i)
        plan = plan_without_agg_cost(query, eff_deadline)
        if plan.num_batches <= i:
            if plan.num_batches < i:
                # Tighten: fewer batches need less agg allowance; replanning
                # with the exact count can only extend the last-batch window.
                tight = plan_without_agg_cost(
                    query, query.deadline - cm.agg_cost(plan.num_batches)
                )
                if tight.num_batches <= plan.num_batches:
                    return tight
            return plan
        i = max(i + 1, plan.num_batches)
    raise InfeasibleDeadline(f"{query.query_id}: agg-cost iteration diverged")


def plan_single(query: Query) -> Schedule:
    """Algorithm 1's planning phase (ScheduleSingleMain, lines 1-8)."""
    if query.slack_time >= -_EPS:
        # Cases 1-2: one batch, started as late as completion-by-deadline allows.
        return Schedule(
            batches=(
                Batch(
                    sched_time=query.deadline - query.min_comp_cost,
                    num_tuples=query.num_tuples_total,
                ),
            )
        )
    return plan_with_agg_cost(query)


# ---------------------------------------------------------------------------
# Policy classes
# ---------------------------------------------------------------------------


class StaticPolicy:
    """Base for policies that compute a full per-query Plan up front."""

    kind = "static"
    name = "static"

    def plan(
        self,
        queries: Union[Query, Sequence[Query]],
        cost_model: Optional[CostModelBase] = None,
        now: float = 0.0,
    ) -> Plan:
        schedules = {}
        for q in as_queries(queries):
            if cost_model is not None:
                q = dataclasses.replace(q, cost_model=cost_model)
            schedules[q.query_id] = self.plan_query(q)
        return Plan(schedules=schedules, policy=self.name)

    def plan_query(self, query: Query) -> Schedule:
        raise NotImplementedError

    def replan(self, event, state) -> PolicyDecision:
        raise NotImplementedError(
            f"{self.name!r} is a static policy: it plans up front; the "
            "runtime executes its Plan with Algorithm 1's triggers"
        )


@register_policy("single")
class SingleQueryPolicy(StaticPolicy):
    """Algorithm 1 (ScheduleSingleMain): the paper's headline single-query
    scheme — slack test, then backward construction under Eq. (4)."""

    def plan_query(self, query: Query) -> Schedule:
        return plan_single(query)


@register_policy("single-no-agg")
class NoAggCostPolicy(StaticPolicy):
    """Backward construction ignoring final-aggregation cost
    (ScheduleWithoutAggCost).  ``deadline`` overrides the query's own
    deadline when given (the paper calls it with tightened deadlines)."""

    def __init__(self, deadline: Optional[float] = None):
        self.deadline = deadline

    def plan_query(self, query: Query) -> Schedule:
        d = query.deadline if self.deadline is None else self.deadline
        return plan_without_agg_cost(query, d)


@register_policy("single-agg")
class AggCostPolicy(StaticPolicy):
    """The Eq. (4) agg-cost fixpoint (ScheduleWithAggCost), without
    Algorithm 1's positive-slack shortcut."""

    def plan_query(self, query: Query) -> Schedule:
        return plan_with_agg_cost(query)
