"""Built-in scheduling policies.

Importing this package registers every built-in policy with the registry in
``repro.core.api`` (string keys; ``get_policy(name)`` instantiates):

    single          Algorithm 1 (ScheduleSingleMain): slack test, then
                    backward construction with the Eq. (4) agg-cost fixpoint
    single-no-agg   backward construction ignoring final-aggregation cost
                    (paper function ScheduleWithoutAggCost)
    single-agg      the Eq. (4) agg-cost fixpoint (ScheduleWithAggCost)
    constraints     smallest-n feasibility of the §3.2 Eq. (5)-(8) system
                    (linear cost models)
    brute-force     exhaustive composition search (tests/ground truth)
    llf-dynamic     Algorithm 2, least-laxity-first (§4.2, Eq. (10))
    edf-dynamic     Algorithm 2, earliest-deadline-first
    sjf-dynamic     Algorithm 2, shortest-job-first
    rr-dynamic      Algorithm 2, round-robin
"""
from .single import (
    AggCostPolicy,
    NoAggCostPolicy,
    SingleQueryPolicy,
    StaticPolicy,
)
from .constraint import BruteForcePolicy, ConstraintPolicy
from .dynamic import (
    DynamicPolicy,
    EDFPolicy,
    LLFPolicy,
    RRPolicy,
    SJFPolicy,
)

__all__ = [
    "AggCostPolicy",
    "BruteForcePolicy",
    "ConstraintPolicy",
    "DynamicPolicy",
    "EDFPolicy",
    "LLFPolicy",
    "NoAggCostPolicy",
    "RRPolicy",
    "SJFPolicy",
    "SingleQueryPolicy",
    "StaticPolicy",
]
