"""Constraint-based scheduling policies for LINEAR cost models (paper §3.2,
Eqs. 5-8) — canonical implementations (moved here from
``repro.core.constraints``, whose public functions are now deprecation
shims over these).

The paper formulates batch sizing as mixed-integer constraints and solves
them with Google OR-Tools, minimizing the number of batches (fewer batches
== less overhead == less cost under Eq. (1)).  OR-Tools is unavailable
offline, so this module solves the *same* constraint system exactly:

    (5)  sum_i x_i                         == N
    (6)  start_i + dur_i                   <= start_{i+1}        (no overlap)
    (7)  start_n + dur_n                   <= deadline
    (8)  rate * start_i                    >= sum_{j<=i} x_j     (availability)

For a fixed batch count ``n`` the system is a feasibility problem over the
x_i; because cost is affine and arrivals are (piecewise-)linear, the
*latest-start* assignment is extremal: computing it by backward substitution
over the constraint chain either yields a witness or proves infeasibility.
The ``constraints`` policy then takes the smallest feasible ``n`` — exactly
the OR-Tools objective.  The ``brute-force`` enumerator over integer
compositions is provided for cross-validation on small instances (tests
assert all three — Algorithm 1, this solver, brute force — agree, as §3.2
reports).
"""
from __future__ import annotations

import itertools
from typing import List, Optional, Tuple

from ..api import register_policy
from ..cost_model import LinearCostModel
from ..types import Batch, InfeasibleDeadline, Query, Schedule
from .single import StaticPolicy

_EPS = 1e-9


def check_linear(query: Query) -> LinearCostModel:
    cm = query.cost_model
    if not isinstance(cm, LinearCostModel):
        raise TypeError(
            "constraint solver supports only LinearCostModel (paper §3.2); "
            "use Algorithm 1 (policy 'single') for arbitrary models"
        )
    return cm


def feasible_assignment(
    query: Query, n: int, deadline: Optional[float] = None
) -> Optional[Schedule]:
    """Latest-start witness for the Eq. (5)-(8) system with ``n`` batches,
    or None if the system is infeasible for this ``n``."""
    cm = check_linear(query)
    arr = query.arrival
    deadline = query.deadline if deadline is None else deadline
    if n > 1:
        deadline = deadline - cm.agg_cost(n)  # Eq. (4) allowance
    total = query.num_tuples_total

    # Backward substitution: batch i's deadline is start_{i+1} (constraint 6,
    # with start_{n+1} := deadline per constraint 7).  Constraint (8) says the
    # cumulative count through batch i — i.e. `pending` at this point of the
    # backward pass — must have arrived before batch i starts.  Maximizing
    # each batch's size is extremal for feasibility (exchange argument ==
    # the paper's §3.1 optimality proof), so greedy-max yields a witness iff
    # the system is feasible.
    sizes_rev: List[int] = []
    starts_rev: List[float] = []
    time_pt = deadline
    pending = total
    for i in range(n, 0, -1):
        if pending == 0:
            break
        avail = arr.input_time(pending)
        k = min(cm.tuples_processable(time_pt - avail), pending)
        if i == 1 and k < pending:
            return None  # the first batch must absorb everything left
        if k <= 0:
            return None
        start = time_pt - cm.cost(k)  # latest start; >= avail by construction
        if start < avail - _EPS:
            return None
        sizes_rev.append(k)
        starts_rev.append(start)
        pending -= k
        time_pt = start
    if pending > 0:
        return None
    batches = tuple(
        Batch(sched_time=s, num_tuples=x)
        for s, x in sorted(zip(starts_rev, sizes_rev))
    )
    return Schedule(batches=batches)


def plan_via_constraints(query: Query, max_batches: int = 512) -> Schedule:
    """Smallest-``n`` feasible solution of Eqs. (5)-(8) (the OR-Tools
    objective)."""
    check_linear(query)
    for n in range(1, max_batches + 1):
        plan = feasible_assignment(query, n)
        if plan is not None:
            return plan
    raise InfeasibleDeadline(
        f"{query.query_id}: no feasible plan with <= {max_batches} batches"
    )


def brute_force_search(
    query: Query, max_batches: int = 4
) -> Optional[Tuple[int, Tuple[int, ...]]]:
    """Exhaustive ground truth for SMALL instances (tests only).

    Enumerates integer compositions of N into 1..max_batches parts, checks
    Eqs. (5)-(8) directly (with latest-feasible starts), and returns
    (min_num_batches, sizes) or None.
    """
    cm = check_linear(query)
    arr = query.arrival
    total = query.num_tuples_total
    for n in range(1, max_batches + 1):
        deadline = query.deadline - (cm.agg_cost(n) if n > 1 else 0.0)
        for cut in itertools.combinations(range(1, total), n - 1):
            sizes = [b - a for a, b in zip((0,) + cut, cut + (total,))]
            # Latest-start backward check of (6)-(8); (5) holds by
            # construction of the composition.  input_time(N) == wind_end, so
            # the last batch's availability bound is the window end.
            time_pt, done, ok = deadline, total, True
            for i in range(n - 1, -1, -1):
                start = time_pt - cm.cost(sizes[i])
                if start < arr.input_time(done) - _EPS:
                    ok = False
                    break
                time_pt, done = start, done - sizes[i]
            if ok:
                return n, tuple(sizes)
    return None


# ---------------------------------------------------------------------------
# Policy classes
# ---------------------------------------------------------------------------


@register_policy("constraints")
class ConstraintPolicy(StaticPolicy):
    """Smallest-n feasibility of the §3.2 constraint system (linear models)."""

    def __init__(self, max_batches: int = 512):
        self.max_batches = max_batches

    def plan_query(self, query: Query) -> Schedule:
        return plan_via_constraints(query, self.max_batches)


@register_policy("brute-force")
class BruteForcePolicy(StaticPolicy):
    """Exhaustive composition enumeration with latest-feasible starts.

    Exponential — ground truth for small instances only."""

    def __init__(self, max_batches: int = 4):
        self.max_batches = max_batches

    def plan_query(self, query: Query) -> Schedule:
        found = brute_force_search(query, self.max_batches)
        if found is None:
            raise InfeasibleDeadline(
                f"{query.query_id}: no feasible composition with "
                f"<= {self.max_batches} batches"
            )
        n, sizes = found
        cm = query.cost_model
        deadline = query.deadline - (cm.agg_cost(n) if n > 1 else 0.0)
        # Latest-start witness for the winning composition (same backward
        # pass the checker used to prove it feasible).
        starts: List[float] = []
        time_pt = deadline
        for size in reversed(sizes):
            time_pt -= cm.cost(size)
            starts.append(time_pt)
        starts.reverse()
        return Schedule(
            batches=tuple(
                Batch(sched_time=s, num_tuples=x)
                for s, x in zip(starts, sizes)
            )
        )
