"""Dynamic multi-query scheduling policies (paper §4, Algorithm 2).

Non-idling, non-preemptive (NINP) time-shared executor: whenever the
executor is free, every active query whose MinBatch is ready (or which is
past its estimated readiness time — §4.4 jitter handling) competes under the
chosen strategy (LLF / EDF / SJF / RR); the winner runs ONE MinBatch to
completion.  Batch cost is bounded by C_max at MinBatch-sizing time, which
bounds the blocking period any newly arrived urgent query can suffer
(§4.2-4.3).

The event loop itself lives in ``repro.core.runtime`` (shared with the
static policies and every executor); these classes contribute exactly the
paper's per-decision-instant logic: MinBatch sizing at admission (§4.1,
Eq. 9) and the strategy's priority order (§4.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

from ..api import SchedulingEvent, as_queries, register_policy
from ..cost_model import CostModelBase
from ..minbatch import find_min_batch_size
from ..types import (
    Batch,
    BatchShard,
    Plan,
    PolicyDecision,
    Query,
    QueryTable,
    Schedule,
    Strategy,
)

# Ready sets smaller than this aren't worth the numpy packing overhead
# (measured crossover ~64 rows — see benchmarks/bench_scheduler_overhead);
# the winner is identical either way (see ``DynamicPolicy.select``).
_VECTOR_MIN = 64


def make_shards(
    state: "RuntimeState", take: int, ways: int, now: float  # noqa: F821
) -> Tuple[BatchShard, ...]:
    """Split ``take`` tuples into up to ``ways`` shards for one dispatch.

    Homogeneous pools (the default — every ``worker_weights`` entry equal,
    or no weights reported) get unnamed, evenly balanced shards via
    ``batch_shard_extents``; the loop assigns workers earliest-free, which
    keeps pre-refactor traces byte-identical.

    Heterogeneous pools (per-device calibration found real speed skew —
    ``repro.dist.mesh.MeshBackend`` reports measured throughput ratios) get
    NAMED shards cut by ``weighted_shard_extents`` so every worker finishes
    its shard at the same instant: the ``ways`` earliest-free workers are
    claimed in the loop's own (clock, declaration order) tie-break, then
    each gets tuples in proportion to its weight.  Zero-sized assignments
    (a worker far slower than its peers) are dropped."""
    weights = state.worker_weights
    if (
        len(weights) == len(state.worker_names)
        and len(weights) == len(state.worker_clocks)
        and len(set(weights)) > 1
    ):
        from ...dist.sharding import weighted_shard_extents

        order = sorted(
            range(len(state.worker_names)),
            key=lambda i: (state.worker_clocks[i], i),
        )[:ways]
        extents = weighted_shard_extents(take, [weights[i] for i in order])
        return tuple(
            BatchShard(num_tuples=size, worker=state.worker_names[i])
            for i, (_, size) in zip(order, extents)
            if size > 0
        )
    from ...dist.sharding import batch_shard_extents

    return tuple(
        BatchShard(num_tuples=size)
        for _, size in batch_shard_extents(take, ways)
    )


class DynamicPolicy:
    """Base for Algorithm-2 policies; subclasses fix the strategy order.

    ``shard_across=k`` (pool runs only) splits each winner's MinBatch into
    up to ``k`` per-worker shards (balanced via
    ``repro.dist.sharding.batch_shard_extents``), trading the extra
    per-batch overhead and final-aggregation partials for parallel wall
    time.  Only workers actually FREE at the decision instant
    (``state.free_workers(now)``) count toward the split — sharding onto a
    busy worker would serialize behind its running batch and finish LATER
    than not sharding.  With one (free) worker — or ``shard_across=1``, the
    default — decisions are exactly Algorithm 2's.
    """

    kind = "dynamic"
    name = "dynamic"
    strategy: Strategy

    def __init__(
        self,
        delta_rsf: float = 0.5,
        c_max: float = 30.0,
        shard_across: int = 1,
    ):
        if shard_across < 1:
            raise ValueError(f"shard_across must be >= 1, got {shard_across}")
        self.delta_rsf = delta_rsf
        self.c_max = c_max
        self.shard_across = shard_across

    # -- runtime hooks ---------------------------------------------------
    def on_admit(self, rt: "QueryRuntime", now: float) -> None:  # noqa: F821
        """FindMinBatchSize at admission (§4.1): Eq.-9 cost bound, C_max
        blocking cap, GROUP-BY floor.  The loop follows up with an
        ``"admission"`` SchedulingEvent at the same decision instant.

        Under pane sharing (the query's cost model is a ``SharedCostModel``)
        the MinBatch is additionally aligned to the stream's pane width, so
        dispatched batches are PANE batches — computed once and fanned out
        to every subscribed query — rather than arbitrary fragments."""
        rt.min_batch = self._pane_align(rt, find_min_batch_size(
            rt.est_total(now) or 1,
            rt.q.cost_model,
            self.delta_rsf,
            self.c_max,
            rt.spec.num_groups,
        ))

    def _pane_align(self, rt: "QueryRuntime", min_batch: int) -> int:  # noqa: F821
        """Round a MinBatch to the shared stream's pane grid.  Rounding UP
        preserves the Eq.-9 cost bound just computed (batched cost is
        non-increasing in batch size), so prefer the next multiple whenever
        it still respects C_max; only when C_max forbids the larger batch
        round DOWN (C_max has precedence over Eq. 9, exactly like the cap
        in ``find_min_batch_size``).  No-op for unshared queries (no
        ``pane_tuples`` on the cost model)."""
        cm = rt.q.cost_model
        pane = getattr(cm, "pane_tuples", 0)
        if not pane or pane <= 1:
            return min_batch
        total = max(rt.q.num_tuples_total, 1)
        up = -(-min_batch // pane) * pane  # ceil to the pane grid
        if cm.cost(min(up, total)) <= self.c_max + 1e-9:
            min_batch = up
        elif min_batch >= pane:
            min_batch = (min_batch // pane) * pane
        # else: even one pane blows C_max — keep the sub-pane MinBatch
        # (fragment batches share less but never violate the blocking bound)
        return max(1, min(min_batch, total))

    def on_withdraw(self, rt: "QueryRuntime", now: float) -> None:  # noqa: F821
        """Query deleted mid-run (§4: "queries may be added or removed at
        any point").  Nothing to unwind for Algorithm 2 — MinBatch state
        dies with the runtime — but custom policies with cross-query state
        override this."""

    def on_recalibrate(self, rt: "QueryRuntime", now: float) -> None:  # noqa: F821
        """Cost-model recalibration (a session detected drift and refitted,
        or a sharer left the stream and the amortized cost jumped): re-run
        MinBatch sizing so future batches of ``rt`` reflect the corrected
        costs.  Only affects batch SIZING going forward — the NINP invariant
        is untouched."""
        self.on_admit(rt, now)

    def on_shed(self, rt: "QueryRuntime", now: float) -> None:  # noqa: F821
        """Load shedding thinned ``rt``'s remaining stream
        (``repro.core.overload``): re-run MinBatch sizing against the new —
        smaller — total so batch sizes track the shed workload (Eq. 9 is
        relative to the single-batch cost of what will actually run)."""
        self.on_admit(rt, now)

    def priority(self, rt: "QueryRuntime", now: float) -> Tuple:  # noqa: F821
        """Sort key among ready queries; smallest wins the executor."""
        raise NotImplementedError

    def select(
        self, ready: Sequence["QueryRuntime"], now: float  # noqa: F821
    ) -> "QueryRuntime":  # noqa: F821
        """The winner among ``ready``: strict tiers, then the strategy's
        priority order.  Equal-key ties resolve to the earliest entry of
        ``ready`` — which the runtime cores pass in runtime-state order, so
        this equals the head of the old stable full sort.

        Large ready sets whose rows all carry a plain ``LinearCostModel``
        evaluate the priority math vectorized over a packed ``QueryTable``
        (argsort-based ordering); everything else — small sets, calibrating
        or shared or piecewise cost models, custom ``priority`` overrides —
        takes the per-query Python keys.  Both paths pick the same winner
        (the parity tests pin this)."""
        if len(ready) >= _VECTOR_MIN:
            i = _vector_select(self, ready, now)
            if i is not None:
                return ready[i]
        return min(ready, key=lambda r: (r.q.tier, *self.priority(r, now)))

    def replan(self, event: SchedulingEvent, state: "RuntimeState") -> PolicyDecision:  # noqa: F821
        """Algorithm 2's decision instant: pick the ready winner, or report
        when readiness can next change, or stop.

        Priority tiers (``Query.tier``, overload control) are STRICT: a
        ready query of a lower tier number always wins over any higher
        tier; the strategy's own order applies within a tier.  With every
        query on the default tier 0 the ordering — hence the trace — is
        byte-identical to the tierless sort.

        Cameo-style latency targets (``Query.latency_target``) slot into
        the strategy order WITHIN a tier: the deadline-flavoured key
        components use the effective target instant (``Query.target_time``)
        and target laxity instead of the raw deadline/laxity, so a query
        whose answer is wanted early outranks an equal-deadline peer.  For
        target-free queries both collapse to the deadline quantities —
        all-``None`` workloads sort, and trace, byte-identically.
        """
        now = event.now
        ready = [r for r in state.active() if r.ready(now)]
        if not ready:
            nxt = min(
                (r.next_ready_time(now) for r in state.unfinished()),
                default=math.inf,
            )
            if not math.isfinite(nxt):
                return PolicyDecision()  # stop: nothing will ever be ready
            return PolicyDecision(wake_at=nxt)
        rt = self.select(ready, now)
        take = min(rt.avail(now), rt.min_batch)
        ways = min(self.shard_across, state.free_workers(now), take)
        if ways > 1:
            return PolicyDecision(
                query_id=rt.q.query_id, num_tuples=take,
                shards=make_shards(state, take, ways, now),
            )
        return PolicyDecision(query_id=rt.q.query_id, num_tuples=take)

    # -- static projection ----------------------------------------------
    def plan(
        self,
        queries: Union[Query, Sequence[Query]],
        cost_model: Optional[CostModelBase] = None,
        now: float = 0.0,
    ) -> Plan:
        """Deterministic projection of the dynamic run under the PREDICTED
        arrival models: simulate and return the realized batches per query.

        Dynamic scheduling decides at runtime, so a static Plan only exists
        relative to an arrival assumption — this uses each query's own
        predicted model (truth == prediction), which is also what parity
        with the legacy ``schedule_dynamic`` means.
        """
        from ..runtime import DynamicQuerySpec, SimulatedExecutor, run

        qs = as_queries(queries)
        if cost_model is not None:
            qs = [dataclasses.replace(q, cost_model=cost_model) for q in qs]
        trace = run(self, [DynamicQuerySpec(query=q) for q in qs],
                    SimulatedExecutor())
        schedules = {
            q.query_id: Schedule(
                batches=tuple(
                    Batch(sched_time=e.start, num_tuples=e.num_tuples)
                    for e in trace.executions
                    if e.query_id == q.query_id and e.kind == "batch"
                )
            )
            for q in qs
        }
        return Plan(schedules=schedules, policy=self.name)


@register_policy("llf-dynamic")
class LLFPolicy(DynamicPolicy):
    """Least laxity first (Eq. 10) — the paper's preferred strategy.

    Laxity is measured to the EFFECTIVE target instant (deadline, tightened
    by any ``latency_target``), so latency-targeted queries gain urgency
    exactly by how much earlier their answer is wanted."""

    strategy = Strategy.LLF

    def priority(self, rt, now):
        return (rt.target_laxity(now), rt.q.target_time, rt.rr_seq)


@register_policy("edf-dynamic")
class EDFPolicy(DynamicPolicy):
    """Earliest deadline first (earliest effective TARGET first when
    latency targets are in play)."""

    strategy = Strategy.EDF

    def priority(self, rt, now):
        return (rt.q.target_time, rt.target_laxity(now), rt.rr_seq)


@register_policy("sjf-dynamic")
class SJFPolicy(DynamicPolicy):
    """Shortest (remaining) job first."""

    strategy = Strategy.SJF

    def priority(self, rt, now):
        return (rt.remaining_cost(now), rt.q.target_time, rt.rr_seq)


@register_policy("rr-dynamic")
class RRPolicy(DynamicPolicy):
    """Round-robin over ready queries (FIFO tickets, rotate-on-run)."""

    strategy = Strategy.RR

    def priority(self, rt, now):
        return (rt.rr_seq,)


# Per-strategy lexsort keys over a packed ``QueryTable``.  numpy's lexsort
# orders by the LAST key first, so each tuple lists the Python priority-key
# components reversed, with the strict tier appended as the primary key —
# exactly ``(tier, *priority)``.  Keyed by the (unbound) ``priority``
# function: a subclass overriding ``priority`` drops out of the map and
# falls back to the Python path automatically.
_VECTOR_PRIORITIES = {
    LLFPolicy.priority:
        lambda t, now: (t.rr_seq, t.target_time, t.target_laxity(now)),
    EDFPolicy.priority:
        lambda t, now: (t.rr_seq, t.target_laxity(now), t.target_time),
    SJFPolicy.priority:
        lambda t, now: (t.rr_seq, t.target_time, t.remaining_cost(now)),
    RRPolicy.priority:
        lambda t, now: (t.rr_seq,),
}


def _vector_select(
    policy: DynamicPolicy, ready: Sequence["QueryRuntime"], now: float  # noqa: F821
) -> Optional[int]:
    """Index of the winner via packed-array lexsort, or None to fall back
    (unknown priority override, or a row the ``QueryTable`` can't pack)."""
    keys_for = _VECTOR_PRIORITIES.get(type(policy).priority)
    if keys_for is None:
        return None
    table = QueryTable.pack(ready)
    if table is None:
        return None
    import numpy as np

    order = np.lexsort(keys_for(table, now) + (table.tier,))
    return int(order[0])


def policy_for_strategy(
    strategy: Strategy, delta_rsf: float = 0.5, c_max: float = 30.0
) -> DynamicPolicy:
    """The registered dynamic policy implementing ``strategy``."""
    from ..api import get_policy

    return get_policy(
        f"{strategy.value}-dynamic", delta_rsf=delta_rsf, c_max=c_max
    )
