"""The ONE runtime loop shared by every executor (simulator, JAX analytics,
serving engine).

Before this module, the plan->execute->finalize loop existed three times
with drift: ``core.single_query.execute_single`` (Algorithm 1's trigger
loop), ``core.multi_query.schedule_dynamic`` (Algorithm 2's NINP loop) and
ad-hoc copies in ``repro.serve.analytics``/``repro.serve.engine``.  Now:

* ``run(policy, workload, executor)``   — the loop.  Static policies plan up
  front and execute per query with Algorithm 1's triggers; dynamic policies
  are consulted at every decision instant (``policy.replan``).  The loop —
  not the policy, not the executor — owns deadline checking (QueryOutcome
  recording), C_max straggler re-queue and trace recording.
* ``execute_plan(query, plan, executor)`` — one query's plan against a
  (possibly divergent) true arrival process.  ``strict=False`` is
  Algorithm 1's adaptive while-loop (trigger a batch when its tuple count is
  ready OR its scheduled instant has passed, then process whatever is
  there); ``strict=True`` replays the planned batches verbatim (real
  backends applying a vetted plan to materialized inputs).
* ``BaseExecutor`` / ``SimulatedExecutor`` — the modelled-clock backend.
  Real executors subclass ``BaseExecutor`` and override ``_execute`` /
  ``_finalize`` to do physical work; the MODELLED clock (cost units == time
  units, §7) stays identical across backends, which is what makes traces
  comparable across the simulator and real executors.

Time semantics match the paper's experiments exactly: the executor clock is
the modelled time; real wall seconds are recorded per query on the executor
(``wall_seconds``) and only feed straggler detection (a real batch slower
than C_max is re-queued once — idempotent inputs — and flagged in
``trace.stragglers``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Union

from .api import Executor, SchedulingEvent, SchedulingPolicy
from .arrivals import ArrivalModel
from .types import (
    BatchExecution,
    ExecutionTrace,
    Query,
    QueryOutcome,
    Schedule,
)

_EPS = 1e-9
LARGE_NUMBER = 1e18  # Algorithm 2's sentinel for "not ready"


# ---------------------------------------------------------------------------
# Workload specification
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DynamicQuerySpec:
    """One query as submitted to the runtime.

    ``truth`` is the actual arrival process; planners only ever consult
    ``query.arrival`` (the predicted model).  ``delete_time`` models §4's
    "queries may be added or removed at any point".
    """

    query: Query
    truth: Optional[ArrivalModel] = None
    delete_time: Optional[float] = None
    num_groups: int = 0
    total_known: bool = True

    def __post_init__(self) -> None:
        if self.truth is None:
            self.truth = self.query.arrival


Workload = Sequence[Union[Query, DynamicQuerySpec]]


def as_specs(workload: Union[Query, DynamicQuerySpec, Workload]) -> List[DynamicQuerySpec]:
    if isinstance(workload, (Query, DynamicQuerySpec)):
        workload = [workload]
    return [
        w if isinstance(w, DynamicQuerySpec) else DynamicQuerySpec(query=w)
        for w in workload
    ]


# ---------------------------------------------------------------------------
# Per-query runtime state (Algorithm 2's bookkeeping)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QueryRuntime:
    spec: DynamicQuerySpec
    min_batch: int = 0
    processed: int = 0
    batches_done: int = 0
    admitted: bool = False
    deleted: bool = False
    completed: bool = False
    rr_seq: int = 0  # FIFO ticket for round-robin

    @property
    def q(self) -> Query:
        return self.spec.query

    def est_total(self, now: float) -> int:
        """Total tuples: known, or estimated from the observed rate (§4.4)."""
        if self.spec.total_known:
            return self.q.num_tuples_total
        seen = self.spec.truth.tuples_available(now)
        span = max(now - self.q.wind_start, _EPS)
        window = max(self.q.wind_end - self.q.wind_start, _EPS)
        if now >= self.q.wind_end:
            return seen
        return max(seen, int(math.ceil(seen / span * window)))

    def pending(self, now: float) -> int:
        return max(self.est_total(now) - self.processed, 0)

    def avail(self, now: float) -> int:
        return max(self.spec.truth.tuples_available(now) - self.processed, 0)

    def remaining_cost(self, now: float) -> float:
        """FindMinCompCost: pending tuples in MinBatch chunks + final agg."""
        pend = self.pending(now)
        if pend == 0:
            return 0.0
        cm = self.q.cost_model
        full, rem = divmod(pend, max(self.min_batch, 1))
        nb = full + (1 if rem else 0)
        c = full * cm.cost(self.min_batch) + (cm.cost(rem) if rem else 0.0)
        total_batches = self.batches_done + nb
        if total_batches > 1:
            c += cm.agg_cost(total_batches)
        return c

    def laxity(self, now: float) -> float:
        """Eq. (10): deadline - now - remaining cost."""
        return self.q.deadline - now - self.remaining_cost(now)

    def ready(self, now: float) -> bool:
        """MinBatch ready, or past the *predicted* readiness instant with
        something to process, or window over with a tail remainder (§4.4)."""
        if self.completed or self.deleted or not self.admitted:
            return False
        a = self.avail(now)
        if a <= 0:
            return False
        if a >= self.min_batch:
            return True
        est_ready = self.q.arrival.input_time(self.processed + self.min_batch)
        if now >= est_ready - _EPS:
            return True
        return now >= self.q.wind_end - _EPS and self.processed + a >= self.est_total(now)

    def next_ready_time(self, now: float) -> float:
        """Earliest future instant at which ``ready`` can flip true (sim only)."""
        if self.completed or self.deleted:
            return math.inf
        if not self.admitted:
            return self.q.submit_time
        truth = self.spec.truth
        want = self.processed + self.min_batch
        cands = [self.q.arrival.input_time(want)]  # predicted readiness (§4.4)
        if want <= truth.num_tuples_total:
            cands.append(truth.input_time(want))  # actual count-readiness
        elif truth.tuples_available(truth.wind_end) > self.processed:
            cands.append(max(self.q.wind_end, truth.input_time(truth.num_tuples_total)))
        t = min(cands)
        return t if t > now + _EPS else now + _EPS

    def done(self, now: float) -> bool:
        """Everything that will ever arrive has been processed."""
        if self.spec.total_known:
            return self.processed >= self.spec.truth.num_tuples_total
        return now >= self.spec.truth.wind_end - _EPS and self.avail(now) == 0


@dataclasses.dataclass
class RuntimeState:
    """What a dynamic policy sees at a decision instant."""

    runtimes: List[QueryRuntime]
    trace: ExecutionTrace
    rr_counter: int = 0

    def by_id(self, query_id: str) -> QueryRuntime:
        for rt in self.runtimes:
            if rt.q.query_id == query_id:
                return rt
        raise KeyError(query_id)

    def active(self) -> List[QueryRuntime]:
        return [
            r for r in self.runtimes
            if r.admitted and not (r.completed or r.deleted)
        ]

    def unfinished(self) -> List[QueryRuntime]:
        return [r for r in self.runtimes if not (r.completed or r.deleted)]


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


class BaseExecutor:
    """Modelled-clock implementation of the ``Executor`` protocol.

    Subclasses override ``_execute``/``_finalize`` to do REAL work and return
    measured wall seconds (or None); the modelled clock advances by cost-model
    time either way, so all backends produce comparable traces.
    """

    def __init__(self) -> None:
        self._now = 0.0
        self.wall_seconds: Dict[str, float] = {}
        self.last_batch_wall: Optional[float] = None

    # -- protocol --------------------------------------------------------
    def clock(self) -> float:
        return self._now

    def advance(self, t: float) -> None:
        if t > self._now:
            self._now = t

    def reset(self, t: float) -> None:
        """Rewind/initialize the modelled clock (start of a run/timeline)."""
        self._now = t

    def submit_batch(self, query: Query, num_tuples: int, offset: int) -> float:
        dur = query.cost_model.cost(num_tuples)
        self.last_batch_wall = self._execute(query, num_tuples, offset)
        if self.last_batch_wall is not None:
            self.wall_seconds[query.query_id] = (
                self.wall_seconds.get(query.query_id, 0.0) + self.last_batch_wall
            )
        self._now += dur
        return dur

    def finalize(self, query: Query, num_batches: int) -> float:
        agg = (
            query.cost_model.agg_cost(num_batches) if num_batches > 1 else 0.0
        )
        wall = self._finalize(query, num_batches)
        if wall is not None:
            self.wall_seconds[query.query_id] = (
                self.wall_seconds.get(query.query_id, 0.0) + wall
            )
        self._now += agg
        return agg

    def requeue_batch(self, query: Query, num_tuples: int, offset: int) -> None:
        """Straggler re-dispatch: redo the REAL work of an idempotent batch
        without touching the modelled clock."""
        wall = self._execute(query, num_tuples, offset)
        if wall is not None:
            self.wall_seconds[query.query_id] = (
                self.wall_seconds.get(query.query_id, 0.0) + wall
            )

    # -- backend hooks ---------------------------------------------------
    def _execute(
        self, query: Query, num_tuples: int, offset: int
    ) -> Optional[float]:
        """Physically process tuples [offset, offset+num_tuples); return wall
        seconds, or None when there is no physical work (simulation)."""
        return None

    def _finalize(self, query: Query, num_batches: int) -> Optional[float]:
        return None


class SimulatedExecutor(BaseExecutor):
    """Pure discrete-event backend: the paper's §7 experiment harness."""


# ---------------------------------------------------------------------------
# Trace recording helpers (the loop owns these, not the executors)
# ---------------------------------------------------------------------------


def _record_batch(
    trace: ExecutionTrace,
    executor: Executor,
    query: Query,
    num_tuples: int,
    offset: int,
    on_batch: Optional[Callable[[BatchExecution], None]],
    c_max: Optional[float],
) -> BatchExecution:
    start = executor.clock()
    dur = executor.submit_batch(query, num_tuples, offset)
    ex = BatchExecution(query.query_id, start, start + dur, num_tuples)
    trace.executions.append(ex)
    if on_batch:
        on_batch(ex)
    wall = getattr(executor, "last_batch_wall", None)
    if c_max is not None and wall is not None and wall > c_max:
        # C_max straggler: the batch's REAL execution blew the blocking
        # bound of §4.2-4.3.  Re-dispatch the (idempotent) batch once and
        # flag the event; modelled time is unaffected.
        trace.stragglers.append(query.query_id)
        requeue = getattr(executor, "requeue_batch", None)
        if requeue is not None:
            requeue(query, num_tuples, offset)
    return ex


def _record_final_agg(
    trace: ExecutionTrace,
    executor: Executor,
    query: Query,
    num_batches: int,
    on_batch: Optional[Callable[[BatchExecution], None]],
) -> float:
    start = executor.clock()
    agg = executor.finalize(query, num_batches)
    if agg > 0:
        ex = BatchExecution(query.query_id, start, start + agg, 0, kind="final_agg")
        trace.executions.append(ex)
        if on_batch:
            on_batch(ex)
    return agg


def _record_outcome(
    trace: ExecutionTrace, query: Query, num_batches: int, completion: float
) -> QueryOutcome:
    out = QueryOutcome(
        query_id=query.query_id,
        completion_time=completion,
        deadline=query.deadline,
        total_cost=sum(
            e.end - e.start
            for e in trace.executions
            if e.query_id == query.query_id
        ),
        num_batches=num_batches,
    )
    trace.outcomes.append(out)
    return out


# ---------------------------------------------------------------------------
# Plan execution (Algorithm 1's while-loop — the single static-path copy)
# ---------------------------------------------------------------------------


def execute_plan(
    query: Query,
    plan: Schedule,
    executor: Optional[Executor] = None,
    truth: Optional[ArrivalModel] = None,
    *,
    strict: bool = False,
    trace: Optional[ExecutionTrace] = None,
    on_batch: Optional[Callable[[BatchExecution], None]] = None,
    c_max: Optional[float] = None,
) -> ExecutionTrace:
    """Execute one query's plan on ``executor`` (simulated by default).

    ``strict=False``: Algorithm 1's adaptive loop — trigger a batch when
    EITHER its planned tuple count is available OR its planned time point is
    reached, then process whatever is there (absorbs input-rate
    mispredictions against the ``truth`` arrival process).

    ``strict=True``: replay the planned batches verbatim (sizes and order) at
    ``max(clock, sched_time)`` — the mode real backends use to apply a vetted
    plan to fully materialized inputs.
    """
    executor = SimulatedExecutor() if executor is None else executor
    trace = ExecutionTrace() if trace is None else trace
    executor.reset(query.submit_time)  # each query gets its own timeline

    n_batches = 0
    if strict:
        offset = 0
        for b in plan.batches:
            if b.num_tuples <= 0:
                continue
            executor.advance(b.sched_time)
            _record_batch(
                trace, executor, query, b.num_tuples, offset,
                on_batch=on_batch, c_max=c_max,
            )
            offset += b.num_tuples
            n_batches += 1
    else:
        if not plan.batches and query.num_tuples_total > 0:
            raise ValueError(
                f"{query.query_id}: empty plan for {query.num_tuples_total} "
                "tuples — plan the query first (Planner.plan)"
            )
        arr = truth if truth is not None else query.arrival
        pending = query.num_tuples_total
        processed = 0
        ptr = 0
        required = plan.batches[0].num_tuples if plan.batches else 0
        while pending > 0:
            now = executor.clock()
            avail = arr.tuples_available(now) - processed
            point = plan.batches[min(ptr, plan.num_batches - 1)].sched_time
            # Algorithm 1 trigger: enough tuples ready, OR the planned
            # instant passed (then "Process the Available Tuples").
            if (avail >= required or now >= point - _EPS) and avail > 0:
                take = min(avail, pending)
                _record_batch(
                    trace, executor, query, take, processed,
                    on_batch=on_batch, c_max=c_max,
                )
                processed += take
                pending -= take
                n_batches += 1
                required -= take
                if ptr < plan.num_batches - 1 and required <= 0:
                    ptr += 1
                    required += plan.batches[ptr].num_tuples
                required = max(required, 0)
            else:
                # Discrete-event jump: earliest instant at which the trigger
                # can fire — the `required`-th outstanding tuple arriving, or
                # the planned time point, whichever first.
                want = processed + max(required, 1)
                next_arrival = (
                    arr.input_time(want)
                    if want <= arr.num_tuples_total
                    else arr.input_time(arr.num_tuples_total)
                )
                nxt = min(next_arrival, max(point, arr.input_time(processed + 1)))
                if nxt <= now + _EPS:  # stream exhausted: nothing will arrive
                    break
                executor.advance(nxt)

    _record_final_agg(trace, executor, query, n_batches, on_batch)
    _record_outcome(trace, query, n_batches, executor.clock())
    return trace


# ---------------------------------------------------------------------------
# The shared runtime loop
# ---------------------------------------------------------------------------


def run(
    policy: SchedulingPolicy,
    workload: Union[Query, DynamicQuerySpec, Workload],
    executor: Optional[Executor] = None,
    *,
    start_time: Optional[float] = None,
    max_steps: Optional[int] = None,
    strict: bool = False,
    on_batch: Optional[Callable[[BatchExecution], None]] = None,
    c_max: Optional[float] = None,
) -> ExecutionTrace:
    """Run ``workload`` under ``policy`` on ``executor`` (simulated when
    omitted) and return the full ExecutionTrace with per-query outcomes.

    ``c_max`` bounds the REAL per-batch execution time for straggler
    detection; it defaults to the policy's own C_max (dynamic policies carry
    one; static policies don't, so pass it explicitly to enable straggler
    re-queue on static runs).  ``strict`` applies only to static policies
    (replay plans verbatim); ``start_time``/``max_steps`` only to dynamic
    ones — passing an inapplicable argument raises."""
    specs = as_specs(workload)
    executor = SimulatedExecutor() if executor is None else executor
    if c_max is None:
        c_max = getattr(policy, "c_max", None)
    if getattr(policy, "kind", "static") == "dynamic":
        if strict:
            raise ValueError(
                "strict= applies to static policies only (dynamic policies "
                "have no up-front plan to replay)"
            )
        return _run_dynamic(
            policy, executor, specs,
            start_time=start_time,
            max_steps=1_000_000 if max_steps is None else max_steps,
            on_batch=on_batch, c_max=c_max,
        )
    if start_time is not None or max_steps is not None:
        raise ValueError(
            "start_time=/max_steps= apply to dynamic policies only (static "
            "runs give each query its own timeline from submit_time)"
        )
    return _run_static(
        policy, executor, specs, strict=strict, on_batch=on_batch, c_max=c_max,
    )


def _run_static(
    policy: SchedulingPolicy,
    executor: Executor,
    specs: List[DynamicQuerySpec],
    *,
    strict: bool,
    on_batch: Optional[Callable[[BatchExecution], None]],
    c_max: Optional[float],
) -> ExecutionTrace:
    """Static policies: plan each query up front, execute independently.

    Each query runs on its own timeline (the paper's single-query scenarios
    assume a dedicated executor per query; §3)."""
    trace = ExecutionTrace()
    for spec in specs:
        plan = policy.plan(spec.query)[spec.query.query_id]
        execute_plan(
            spec.query, plan, executor,
            truth=spec.truth, strict=strict, trace=trace,
            on_batch=on_batch, c_max=c_max,
        )
    return trace


def _run_dynamic(
    policy: SchedulingPolicy,
    executor: Executor,
    specs: List[DynamicQuerySpec],
    *,
    start_time: Optional[float],
    max_steps: int,
    on_batch: Optional[Callable[[BatchExecution], None]],
    c_max: Optional[float],
) -> ExecutionTrace:
    """Algorithm 2's NINP loop, generalized over dynamic policies.

    Admissions/deletions happen only between batches (§4.2: "the scheduler
    takes the new query at the end of the batch"); the policy picks the
    winner at each decision instant; the executor performs the batch."""
    runts = [QueryRuntime(spec=s) for s in specs]
    trace = ExecutionTrace()
    if not runts:
        return trace
    start = (
        min(r.q.submit_time for r in runts) if start_time is None else start_time
    )
    executor.reset(start)
    state = RuntimeState(runtimes=runts, trace=trace)
    event_kind = "start"

    for _ in range(max_steps):
        now = executor.clock()
        # -- admissions & deletions (between batches only, §4.2) ----------
        for rt in runts:
            if not rt.admitted and rt.q.submit_time <= now + _EPS:
                rt.admitted = True
                rt.rr_seq = state.rr_counter
                state.rr_counter += 1
                on_admit = getattr(policy, "on_admit", None)
                if on_admit is not None:
                    on_admit(rt, now)
                elif rt.min_batch <= 0:
                    rt.min_batch = 1  # protocol-minimal policy: no sizing hook
            if (
                rt.spec.delete_time is not None
                and not rt.deleted
                and rt.spec.delete_time <= now + _EPS
                and not rt.completed
            ):
                rt.deleted = True

        if not state.active() and all(r.admitted or r.deleted for r in runts):
            break

        decision = policy.replan(SchedulingEvent(event_kind, now), state)
        if decision.is_stop:
            break
        if decision.is_wait:
            executor.advance(decision.wake_at)
            event_kind = "wake"
            continue

        rt = state.by_id(decision.query_id)
        rt.rr_seq = state.rr_counter  # rotate to the back for RR fairness
        state.rr_counter += 1

        _record_batch(
            trace, executor, rt.q, decision.num_tuples, rt.processed,
            on_batch=on_batch, c_max=c_max,
        )
        rt.processed += decision.num_tuples
        rt.batches_done += 1
        event_kind = "batch_end"

        # -- completion: all that will ever arrive has been processed -----
        if rt.done(executor.clock()):
            _record_final_agg(trace, executor, rt.q, rt.batches_done, on_batch)
            rt.completed = True
            _record_outcome(trace, rt.q, rt.batches_done, executor.clock())
    return trace
