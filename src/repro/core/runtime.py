"""The ONE runtime loop shared by every executor (simulator, JAX analytics,
serving engine).

Before this module, the plan->execute->finalize loop existed three times
with drift: ``core.single_query.execute_single`` (Algorithm 1's trigger
loop), ``core.multi_query.schedule_dynamic`` (Algorithm 2's NINP loop) and
ad-hoc copies in ``repro.serve.analytics``/``repro.serve.engine``.  Now:

* ``run(policy, workload, executor)``   — the loop.  Static policies plan up
  front and execute per query with Algorithm 1's triggers; dynamic policies
  are consulted at every decision instant (``policy.replan``).  The loop —
  not the policy, not the executor — owns deadline checking (QueryOutcome
  recording), C_max straggler re-queue and trace recording.
* ``execute_plan(query, plan, executor)`` — one query's plan against a
  (possibly divergent) true arrival process.  ``strict=False`` is
  Algorithm 1's adaptive while-loop (trigger a batch when its tuple count is
  ready OR its scheduled instant has passed, then process whatever is
  there); ``strict=True`` replays the planned batches verbatim (real
  backends applying a vetted plan to materialized inputs).
* ``BaseExecutor`` / ``SimulatedExecutor`` — the modelled-clock backend.
  Real executors subclass ``BaseExecutor`` and override ``_execute`` /
  ``_finalize`` to do physical work; the MODELLED clock (cost units == time
  units, §7) stays identical across backends, which is what makes traces
  comparable across the simulator and real executors.
* ``ExecutorPool`` — W parallel workers over ONE physical backend.  Each
  worker keeps its own modelled clock (the instant it next frees); the
  pool's ``clock()`` is the earliest-free instant, so decision instants
  fire whenever ANY worker frees and the NINP invariant (one running batch,
  never preempted) holds PER WORKER.  Physical work still flows through the
  single backend, whose offset-keyed partials/results make shard dispatch
  and straggler re-queue idempotent regardless of worker placement.  With
  ``workers=1`` the pool is trace-identical to the bare executor.

Time semantics match the paper's experiments exactly: the executor clock is
the modelled time; real wall seconds are recorded per query on the executor
(``wall_seconds``) and only feed straggler detection (a real batch slower
than C_max is re-queued once — idempotent inputs — and flagged in
``trace.stragglers``).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple, Union

from .api import Executor, SchedulingEvent, SchedulingPolicy
from .arrivals import ArrivalModel
from .cost_model import CostModelBase
from .types import (
    EPS,
    BatchExecution,
    ExecutionTrace,
    PolicyDecision,
    Query,
    QueryOutcome,
    Schedule,
    split_window_id,
)

_EPS = EPS  # the one shared tolerance (see types.EPS)
LARGE_NUMBER = 1e18  # Algorithm 2's sentinel for "not ready"


# ---------------------------------------------------------------------------
# Workload specification
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DynamicQuerySpec:
    """One query as submitted to the runtime.

    ``truth`` is the actual arrival process; planners only ever consult
    ``query.arrival`` (the predicted model).  ``delete_time`` models §4's
    "queries may be added or removed at any point".

    ``shed_fraction``/``error_bound`` record that overload control
    (``repro.core.overload``) thinned this query's stream before/while it
    ran; the loop stamps them onto the ``QueryOutcome`` so degraded answers
    are visibly estimates, not silent truncations.
    """

    query: Query
    truth: Optional[ArrivalModel] = None
    delete_time: Optional[float] = None
    num_groups: int = 0
    total_known: bool = True
    shed_fraction: float = 0.0
    error_bound: float = 0.0

    def __post_init__(self) -> None:
        if self.truth is None:
            self.truth = self.query.arrival


Workload = Sequence[Union[Query, DynamicQuerySpec]]


def as_specs(workload: Union[Query, DynamicQuerySpec, Workload]) -> List[DynamicQuerySpec]:
    if isinstance(workload, (Query, DynamicQuerySpec)):
        workload = [workload]
    return [
        w if isinstance(w, DynamicQuerySpec) else DynamicQuerySpec(query=w)
        for w in workload
    ]


# ---------------------------------------------------------------------------
# Per-query runtime state (Algorithm 2's bookkeeping)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QueryRuntime:
    spec: DynamicQuerySpec
    min_batch: int = 0
    processed: int = 0
    batches_done: int = 0
    admitted: bool = False
    deleted: bool = False
    completed: bool = False
    rr_seq: int = 0  # FIFO ticket for round-robin

    @property
    def q(self) -> Query:
        return self.spec.query

    def est_total(self, now: float) -> int:
        """Total tuples: known, or estimated from the observed rate (§4.4)."""
        if self.spec.total_known:
            return self.q.num_tuples_total
        seen = self.spec.truth.tuples_available(now)
        span = max(now - self.q.wind_start, _EPS)
        window = max(self.q.wind_end - self.q.wind_start, _EPS)
        if now >= self.q.wind_end:
            return seen
        return max(seen, int(math.ceil(seen / span * window)))

    def pending(self, now: float) -> int:
        return max(self.est_total(now) - self.processed, 0)

    def avail(self, now: float) -> int:
        return max(self.spec.truth.tuples_available(now) - self.processed, 0)

    def remaining_cost(self, now: float) -> float:
        """FindMinCompCost: pending tuples in MinBatch chunks + final agg."""
        pend = self.pending(now)
        if pend == 0:
            return 0.0
        cm = self.q.cost_model
        full, rem = divmod(pend, max(self.min_batch, 1))
        nb = full + (1 if rem else 0)
        c = full * cm.cost(self.min_batch) + (cm.cost(rem) if rem else 0.0)
        total_batches = self.batches_done + nb
        if total_batches > 1:
            c += cm.agg_cost(total_batches)
        return c

    def laxity(self, now: float) -> float:
        """Eq. (10): deadline - now - remaining cost."""
        return self.q.deadline - now - self.remaining_cost(now)

    def target_laxity(self, now: float) -> float:
        """Laxity against the query's EFFECTIVE target instant
        (``Query.target_time`` — Cameo-style latency target, capped by the
        deadline).  Identical to ``laxity`` for target-free queries, so
        policies ordering by it stay byte-identical on the default
        workload."""
        return self.laxity(now) - (self.q.deadline - self.q.target_time)

    def ready(self, now: float) -> bool:
        """MinBatch ready, or past the *predicted* readiness instant with
        something to process, or window over with a tail remainder (§4.4)."""
        if self.completed or self.deleted or not self.admitted:
            return False
        a = self.avail(now)
        if a <= 0:
            return False
        if a >= self.min_batch:
            return True
        est_ready = self.q.arrival.input_time(self.processed + self.min_batch)
        if now >= est_ready - _EPS:
            return True
        return now >= self.q.wind_end - _EPS and self.processed + a >= self.est_total(now)

    def next_ready_time(self, now: float) -> float:
        """Earliest future instant at which ``ready`` can flip true (sim only)."""
        if self.completed or self.deleted:
            return math.inf
        if not self.admitted:
            return self.q.submit_time
        truth = self.spec.truth
        want = self.processed + self.min_batch
        est_ready = self.q.arrival.input_time(want)  # predicted readiness (§4.4)
        cands = []
        if est_ready > now + _EPS:
            cands.append(est_ready)
        elif self.processed + 1 <= truth.num_tuples_total:
            # Predicted readiness already passed: ``ready`` now flips the
            # moment the truth stream delivers its NEXT tuple (avail 0 -> 1
            # past est_ready).  A stale predicted instant must not stay a
            # candidate, or a truth burst arriving later than predicted
            # degenerates the wait loop into eps-stepping until it lands.
            cands.append(truth.input_time(self.processed + 1))
        if want <= truth.num_tuples_total:
            cands.append(truth.input_time(want))  # actual count-readiness
        elif truth.tuples_available(truth.wind_end) > self.processed:
            cands.append(max(self.q.wind_end, truth.input_time(truth.num_tuples_total)))
        t = min(cands) if cands else now + _EPS
        return t if t > now + _EPS else now + _EPS

    def done(self, now: float) -> bool:
        """Everything that will ever arrive has been processed."""
        if self.spec.total_known:
            return self.processed >= self.spec.truth.num_tuples_total
        return now >= self.spec.truth.wind_end - _EPS and self.avail(now) == 0


@dataclasses.dataclass
class RuntimeState:
    """What a dynamic policy sees at a decision instant.

    ``num_workers``/``worker_names``/``worker_clocks`` describe the executor
    pool (1 / ``()`` / ``()`` outside a pool), so policies can emit
    worker-targeted or sharded decisions only when the capacity actually
    exists.  ``worker_clocks`` aligns with ``worker_names`` and is refreshed
    by the loop before every ``replan`` call: each entry is the instant that
    worker next frees, so a policy can tell free workers (clock <= now) from
    busy ones instead of assuming the whole pool is idle.
    """

    runtimes: List[QueryRuntime]
    trace: ExecutionTrace
    rr_counter: int = 0
    num_workers: int = 1
    worker_names: Tuple[str, ...] = ()
    worker_clocks: Tuple[float, ...] = ()
    # Relative worker speeds aligned with worker_names (1.0 = nominal; ()
    # outside a pool or when the backend reports none).  Heterogeneous
    # weights let policies cut weighted shard extents so every device
    # finishes its shard at the same instant.
    worker_weights: Tuple[float, ...] = ()
    # Lazily built query_id -> runtime index (first match wins, like the
    # linear scan it replaces; new runtimes appended mid-run are absorbed
    # on the next lookup).
    _index: Dict[str, "QueryRuntime"] = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    _indexed: int = dataclasses.field(default=0, repr=False, compare=False)

    def free_workers(self, now: float) -> int:
        """Workers free to start a batch at ``now`` (>= 1: the decision
        instant IS some worker freeing; 1 outside a pool)."""
        if not self.worker_clocks:
            return 1
        return max(1, sum(1 for c in self.worker_clocks if c <= now + _EPS))

    def by_id(self, query_id: str) -> QueryRuntime:
        n = len(self.runtimes)
        if self._indexed < n:
            for rt in self.runtimes[self._indexed:]:
                self._index.setdefault(rt.q.query_id, rt)
            self._indexed = n
        rt = self._index.get(query_id)
        if rt is None:
            raise KeyError(query_id)
        return rt

    def active(self) -> List[QueryRuntime]:
        return [
            r for r in self.runtimes
            if r.admitted and not (r.completed or r.deleted)
        ]

    def unfinished(self) -> List[QueryRuntime]:
        return [r for r in self.runtimes if not (r.completed or r.deleted)]


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


class BaseExecutor:
    """Modelled-clock implementation of the ``Executor`` protocol.

    Subclasses override ``_execute``/``_finalize`` to do REAL work and return
    measured wall seconds (or None); the modelled clock advances by cost-model
    time either way, so all backends produce comparable traces.
    """

    def __init__(self) -> None:
        self._now = 0.0
        self.wall_seconds: Dict[str, float] = {}
        self.last_batch_wall: Optional[float] = None
        self.last_agg_wall: Optional[float] = None

    # -- protocol --------------------------------------------------------
    def clock(self) -> float:
        return self._now

    def advance(self, t: float) -> None:
        if t > self._now:
            self._now = t

    def reset(self, t: float) -> None:
        """Rewind/initialize the modelled clock (start of a run/timeline)."""
        self._now = t

    def submit_batch(self, query: Query, num_tuples: int, offset: int) -> float:
        dur = self._modelled_batch_cost(query, num_tuples)
        self.last_batch_wall = self._execute(query, num_tuples, offset)
        if self.last_batch_wall is not None:
            self.wall_seconds[query.query_id] = (
                self.wall_seconds.get(query.query_id, 0.0) + self.last_batch_wall
            )
        self._now += dur
        return dur

    def finalize(self, query: Query, num_batches: int) -> float:
        agg = self._modelled_agg_cost(query, num_batches)
        wall = self._finalize(query, num_batches)
        self.last_agg_wall = wall
        if wall is not None:
            self.wall_seconds[query.query_id] = (
                self.wall_seconds.get(query.query_id, 0.0) + wall
            )
        self._now += agg
        return agg

    def requeue_batch(self, query: Query, num_tuples: int, offset: int) -> None:
        """Straggler re-dispatch: redo the REAL work of an idempotent batch
        without touching the modelled clock.  ``last_batch_wall`` is updated
        to the re-execution's wall time — the loop requeues BEFORE invoking
        ``on_batch`` observers, so downstream consumers (calibration
        feedback) see exactly one settled measurement per batch, not the
        straggling outlier."""
        wall = self._execute(query, num_tuples, offset)
        if wall is not None:
            self.wall_seconds[query.query_id] = (
                self.wall_seconds.get(query.query_id, 0.0) + wall
            )
            self.last_batch_wall = wall

    # -- backend hooks ---------------------------------------------------
    def _modelled_batch_cost(self, query: Query, num_tuples: int) -> float:
        """TRUE modelled duration of one batch — what the clock advances by.
        Default: the query's own cost model (prediction == truth).  Override
        to inject cost drift (see ``OracleCostExecutor``).

        A cost model with a ``shard_cost`` hook (``ShardedCostModel``) is a
        PLANNING view of a W-way fused dispatch: its ``cost(n)`` is the
        parallel wall time of n tuples split W ways, which must not be what
        a single worker's clock advances by for an n-tuple shard.  The hook
        supplies the per-shard charge (the base model's cost), so the
        modelled clock stays in per-worker work units."""
        shard_cost = getattr(query.cost_model, "shard_cost", None)
        if shard_cost is not None:
            return shard_cost(num_tuples)
        return query.cost_model.cost(num_tuples)

    def _modelled_agg_cost(self, query: Query, num_batches: int) -> float:
        """TRUE modelled duration of the final aggregation."""
        return query.cost_model.agg_cost(num_batches) if num_batches > 1 else 0.0

    def _execute(
        self, query: Query, num_tuples: int, offset: int
    ) -> Optional[float]:
        """Physically process tuples [offset, offset+num_tuples); return wall
        seconds, or None when there is no physical work (simulation)."""
        return None

    def _finalize(self, query: Query, num_batches: int) -> Optional[float]:
        return None


class SimulatedExecutor(BaseExecutor):
    """Pure discrete-event backend: the paper's §7 experiment harness."""


class OracleCostExecutor(SimulatedExecutor):
    """Simulated backend whose TRUE batch costs come from per-query oracle
    models: the modelled clock advances by the oracle's cost while planners
    keep consulting ``query.cost_model`` (the fitted — possibly calibrating —
    model).  This is the cost-side analogue of ``DynamicQuerySpec.truth`` for
    arrivals: §6.2's measured model can be wrong, and a continuously running
    session must detect and absorb that.

    ``true_models`` is keyed by query id; per-window session ids
    ("<base>#w<k>") fall back to their base id, so one entry covers every
    window of a recurring query.  Unkeyed queries use ``default`` (when
    given) or their own cost model (no drift).
    """

    def __init__(
        self,
        true_models: Optional[Dict[str, CostModelBase]] = None,
        default: Optional[CostModelBase] = None,
    ):
        super().__init__()
        self.true_models = dict(true_models or {})
        self.default = default

    def true_model(self, query: Query) -> CostModelBase:
        m = self.true_models.get(query.query_id)
        if m is None:
            m = self.true_models.get(split_window_id(query.query_id)[0])
        if m is None:
            m = self.default
        return query.cost_model if m is None else m

    def _modelled_batch_cost(self, query: Query, num_tuples: int) -> float:
        return self.true_model(query).cost(num_tuples)

    def _modelled_agg_cost(self, query: Query, num_batches: int) -> float:
        if num_batches <= 1:
            return 0.0
        return self.true_model(query).agg_cost(num_batches)


@dataclasses.dataclass(frozen=True)
class Dispatch:
    """Where/when the pool placed the last batch (read by the loop's trace
    recording, which must use the WORKER timeline, not the pool minimum)."""

    worker: str
    start: float
    end: float


class WorkerBackend:
    """Dispatch seam of ``ExecutorPool``: owns the per-worker clocks and
    physically runs batches on its workers.

    The pool keeps the Executor protocol, worker selection
    (``earliest_free``) and the final-aggregation barrier; HOW a batch runs
    and WHAT a worker's clock means is the backend's business:

    * ``ModelledWorkerBackend`` (the default) — W modelled clocks over one
      shared physical ``Executor``; a batch occupies [clock, clock +
      modelled cost) on its worker.  This is PR 2's pool, bit for bit.
    * ``repro.dist.mesh.MeshBackend`` — one worker per jax device; clocks
      are stitched from MEASURED device wall seconds, and a shard group is
      dispatched as ONE fused ``shard_map`` call across the mesh
      (``prefers_group_dispatch``).

    Subclasses must implement ``run_batch``/``run_agg`` (and may implement
    ``run_shard_group``); the clock bookkeeping here is shared.
    """

    #: when True, the runtime loop hands a whole PolicyDecision.shards group
    #: to ``ExecutorPool.submit_shard_group`` as one fused dispatch instead
    #: of one ``submit_batch`` per shard.
    prefers_group_dispatch = False

    def __init__(self, names: Sequence[str]):
        self.worker_names: Tuple[str, ...] = tuple(names)
        self._clocks: Dict[str, float] = {n: 0.0 for n in self.worker_names}
        self.last_batch_wall: Optional[float] = None
        self.last_agg_wall: Optional[float] = None
        self.wall_seconds: Dict[str, float] = {}

    # -- clocks ----------------------------------------------------------
    def worker_clock(self, name: str) -> float:
        return self._clocks[name]

    def clock(self) -> float:
        return min(self._clocks.values())

    def advance(self, t: float) -> None:
        for n, c in self._clocks.items():
            if t > c:
                self._clocks[n] = t

    def reset(self, t: float) -> None:
        for n in self._clocks:
            self._clocks[n] = t

    @property
    def worker_weights(self) -> Tuple[float, ...]:
        """Relative worker speeds (1.0 = nominal) for weighted shard
        splits; homogeneous by default."""
        return (1.0,) * len(self.worker_names)

    # -- dispatch hooks ---------------------------------------------------
    def run_batch(
        self, query: Query, num_tuples: int, offset: int, worker: str
    ) -> Tuple[Dispatch, float]:
        """Run one batch on ``worker``; returns (dispatch, duration) where
        duration is what the Executor protocol's ``submit_batch`` returns."""
        raise NotImplementedError

    def run_agg(
        self,
        query: Query,
        num_batches: int,
        worker: str,
        start: float,
        barrier: float,
    ) -> Tuple[Dispatch, float]:
        """Run the final aggregation on ``worker`` beginning at ``start``
        (already >= both the worker clock and the last-partial ``barrier``).
        Zero-duration aggregations occupy no worker and complete at the
        barrier."""
        raise NotImplementedError

    def run_shard_group(
        self,
        query: Query,
        sizes: Tuple[int, ...],
        base_offset: int,
        workers: Tuple[str, ...],
    ) -> Tuple[Dispatch, ...]:
        """Run one logical batch's shard group, one shard per worker.
        Default: sequential ``run_batch`` calls (semantically identical to
        the loop's per-shard dispatch); fused backends override this to run
        the whole [base_offset, base_offset + sum(sizes)) range as one mesh
        call and return per-shard Dispatches sharing its start/end."""
        dispatches = []
        offset = base_offset
        for size, worker in zip(sizes, workers):
            disp, _ = self.run_batch(query, size, offset, worker)
            dispatches.append(disp)
            offset += size
        return tuple(dispatches)

    def requeue_batch(self, query: Query, num_tuples: int, offset: int) -> None:
        """Straggler re-dispatch of an idempotent batch (no clock motion)."""


class ModelledWorkerBackend(WorkerBackend):
    """W modelled per-worker clocks over ONE shared physical backend — the
    pre-refactor ``ExecutorPool`` dispatch arithmetic, verbatim: physical
    work flows through ``backend`` (whose own modelled clock prices the
    batch), and the named worker's clock advances by that modelled cost."""

    def __init__(self, backend: Executor, names: Sequence[str]):
        super().__init__(names)
        self.backend = backend

    def reset(self, t: float) -> None:
        super().reset(t)
        self.backend.reset(t)

    def run_batch(
        self, query: Query, num_tuples: int, offset: int, worker: str
    ) -> Tuple[Dispatch, float]:
        start = self._clocks[worker]
        dur = self.backend.submit_batch(query, num_tuples, offset)
        end = start + dur
        self._clocks[worker] = end
        return Dispatch(worker=worker, start=start, end=end), dur

    def run_agg(
        self,
        query: Query,
        num_batches: int,
        worker: str,
        start: float,
        barrier: float,
    ) -> Tuple[Dispatch, float]:
        agg = self.backend.finalize(query, num_batches)
        if agg > 0:
            self._clocks[worker] = start + agg
            return Dispatch(worker=worker, start=start, end=start + agg), agg
        # No aggregation work: the result is ready the instant the last
        # partial lands; no worker is occupied.
        return Dispatch(worker=worker, start=barrier, end=barrier), agg

    def requeue_batch(self, query: Query, num_tuples: int, offset: int) -> None:
        requeue = getattr(self.backend, "requeue_batch", None)
        if requeue is not None:
            requeue(query, num_tuples, offset)

    # -- wall-clock bookkeeping lives on the physical backend -------------
    @property
    def last_batch_wall(self) -> Optional[float]:
        return getattr(self.backend, "last_batch_wall", None)

    @last_batch_wall.setter
    def last_batch_wall(self, value: Optional[float]) -> None:
        pass  # the physical backend owns it (base __init__ assigns None)

    @property
    def last_agg_wall(self) -> Optional[float]:
        return getattr(self.backend, "last_agg_wall", None)

    @last_agg_wall.setter
    def last_agg_wall(self, value: Optional[float]) -> None:
        pass

    @property
    def wall_seconds(self) -> Dict[str, float]:
        return getattr(self.backend, "wall_seconds", {})

    @wall_seconds.setter
    def wall_seconds(self, value: Dict[str, float]) -> None:
        pass


class ExecutorPool:
    """W parallel workers with independent modelled clocks over one backend.

    The pool implements the ``Executor`` protocol so the shared runtime loop
    and trace helpers drive it unchanged:

    * ``clock()``  — the earliest-free worker's clock: the next decision
      instant (Algorithm 2's "executor is free" generalizes to "SOME worker
      is free").
    * ``advance``  — idle every worker forward (busy workers, whose clocks
      are already past ``t``, are unaffected).
    * ``submit_batch`` — dispatch to the named worker, or to the
      earliest-free one; the batch occupies [worker clock, worker clock +
      modelled cost) on that worker only.
    * ``finalize`` — final aggregation runs on the worker that can start it
      earliest WITHOUT preceding the query's last batch end (partials from
      all workers must exist first, exactly like combining segagg partials).

    Physical work (``_execute``/``_finalize``) runs on the single shared
    ``backend``, so offset-keyed results combine across workers and
    straggler re-queue stays idempotent.  ``workers=1`` is trace-identical
    to running the bare backend.

    ``worker_backend=`` swaps the whole dispatch seam for an explicit
    ``WorkerBackend`` (e.g. ``repro.dist.mesh.MeshBackend``: one worker per
    jax device, clocks from measured device wall time, shard groups fused
    into one ``shard_map`` call).  Without it the pool builds the
    ``ModelledWorkerBackend`` over ``backend`` — the PR 2 semantics,
    byte-identical.
    """

    is_pool = True

    def __init__(
        self,
        backend: Optional[Executor] = None,
        workers: int = 1,
        names: Optional[Sequence[str]] = None,
        worker_backend: Optional[WorkerBackend] = None,
    ):
        if worker_backend is not None:
            if backend is not None:
                raise TypeError(
                    "pass either backend= (modelled dispatch over one "
                    "physical executor) or worker_backend=, not both"
                )
            if names is not None or workers != 1:
                raise ValueError(
                    "workers=/names= conflict with worker_backend= (the "
                    "worker backend declares its own workers)"
                )
            self._wb = worker_backend
            # The physical executor, for callers that reach through the
            # pool (results, calibration); a mesh backend IS its own
            # physical layer.
            self.backend = getattr(worker_backend, "backend", worker_backend)
        else:
            if getattr(backend, "is_pool", False):
                raise TypeError("cannot nest ExecutorPools")
            if names is not None:
                names = tuple(names)
                if len(set(names)) != len(names):
                    raise ValueError(f"duplicate worker names: {names}")
                if not names:
                    raise ValueError("names must be non-empty")
                if workers not in (1, len(names)):
                    # workers=1 is the constructor default, i.e. "unspecified".
                    raise ValueError(
                        f"workers={workers} conflicts with {len(names)} names"
                    )
            else:
                if workers < 1:
                    raise ValueError(f"need at least one worker, got {workers}")
                names = tuple(f"w{i}" for i in range(workers))
            self.backend = SimulatedExecutor() if backend is None else backend
            self._wb = ModelledWorkerBackend(self.backend, names)
        self.worker_names: Tuple[str, ...] = self._wb.worker_names
        self._rank: Dict[str, int] = {
            n: i for i, n in enumerate(self.worker_names)
        }
        # query_id -> (end, worker) of the query's LAST-ENDING batch so far:
        # its final aggregation cannot start before ``end``.
        self._q_last: Dict[str, Tuple[float, str]] = {}
        self.last_dispatch: Optional[Dispatch] = None

    # -- pool introspection ----------------------------------------------
    @property
    def num_workers(self) -> int:
        return len(self.worker_names)

    @property
    def worker_backend(self) -> WorkerBackend:
        return self._wb

    @property
    def worker_weights(self) -> Tuple[float, ...]:
        return self._wb.worker_weights

    @property
    def prefers_group_dispatch(self) -> bool:
        return self._wb.prefers_group_dispatch

    def worker_clock(self, name: str) -> float:
        return self._wb.worker_clock(name)

    def earliest_free(self, exclude: Sequence[str] = ()) -> str:
        """Name of the earliest-free worker (ties: declaration order).
        ``exclude`` skips workers already claimed by sibling shards — unless
        that would leave none, in which case shards may share a worker."""
        pool = [n for n in self.worker_names if n not in exclude]
        if not pool:
            pool = list(self.worker_names)
        return min(pool, key=lambda n: (self._wb.worker_clock(n), self._rank[n]))

    # -- Executor protocol -----------------------------------------------
    def clock(self) -> float:
        return self._wb.clock()

    def advance(self, t: float) -> None:
        self._wb.advance(t)

    def reset(self, t: float) -> None:
        self._wb.reset(t)
        self._q_last.clear()
        self.last_dispatch = None

    def _note_last(self, query: Query, end: float, name: str) -> None:
        prev = self._q_last.get(query.query_id)
        if prev is None or end >= prev[0]:
            self._q_last[query.query_id] = (end, name)

    def submit_batch(
        self,
        query: Query,
        num_tuples: int,
        offset: int,
        worker: Optional[str] = None,
    ) -> float:
        name = self.earliest_free() if worker is None else worker
        if name not in self._rank:
            raise KeyError(
                f"unknown worker {name!r}; pool workers: {self.worker_names}"
            )
        disp, dur = self._wb.run_batch(query, num_tuples, offset, name)
        self._note_last(query, disp.end, name)
        self.last_dispatch = disp
        return dur

    def submit_shard_group(
        self,
        query: Query,
        sizes: Sequence[int],
        base_offset: int,
    ) -> Tuple[Dispatch, ...]:
        """One logical batch's shard group as a SINGLE fused dispatch
        (worker backends with ``prefers_group_dispatch``): claims one worker
        per shard in earliest-free order and hands the whole group to the
        backend, which runs it as one mesh call.  Returns one Dispatch per
        shard (they share the fused call's start/end)."""
        names: List[str] = []
        for _ in sizes:
            names.append(self.earliest_free(exclude=names))
        dispatches = self._wb.run_shard_group(
            query, tuple(sizes), base_offset, tuple(names)
        )
        end = max(d.end for d in dispatches)
        self._note_last(query, end, dispatches[-1].worker)
        self.last_dispatch = dispatches[-1]
        return dispatches

    def finalize(self, query: Query, num_batches: int) -> float:
        barrier = self._q_last.get(query.query_id, (self.clock(), None))[0]
        # Earliest admissible start: max(worker free, last partial ready).
        name = min(
            self.worker_names,
            key=lambda n: (max(self._wb.worker_clock(n), barrier), self._rank[n]),
        )
        start = max(self._wb.worker_clock(name), barrier)
        disp, agg = self._wb.run_agg(query, num_batches, name, start, barrier)
        self.last_dispatch = disp
        return agg

    # -- optional loop members, proxied to the worker backend -------------
    @property
    def last_batch_wall(self) -> Optional[float]:
        return self._wb.last_batch_wall

    @property
    def last_agg_wall(self) -> Optional[float]:
        return self._wb.last_agg_wall

    @property
    def wall_seconds(self) -> Dict[str, float]:
        return self._wb.wall_seconds

    def requeue_batch(self, query: Query, num_tuples: int, offset: int) -> None:
        self._wb.requeue_batch(query, num_tuples, offset)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (
            f"ExecutorPool(workers={self.num_workers}, "
            f"backend={type(self._wb).__name__})"
        )


# ---------------------------------------------------------------------------
# Trace recording helpers (the loop owns these, not the executors)
# ---------------------------------------------------------------------------


def _record_batch(
    trace: ExecutionTrace,
    executor: Executor,
    query: Query,
    num_tuples: int,
    offset: int,
    on_batch: Optional[Callable[[BatchExecution], None]],
    c_max: Optional[float],
    worker: Optional[str] = None,
) -> BatchExecution:
    start = executor.clock()
    if worker is None:
        dur = executor.submit_batch(query, num_tuples, offset)
    else:
        dur = executor.submit_batch(query, num_tuples, offset, worker=worker)
    disp = getattr(executor, "last_dispatch", None)
    if disp is not None:
        # Pool dispatch: record on the WORKER timeline (its start can be
        # later than the pool minimum when a named worker was requested).
        ex = BatchExecution(
            query.query_id, disp.start, disp.end, num_tuples, worker=disp.worker
        )
    else:
        ex = BatchExecution(query.query_id, start, start + dur, num_tuples)
    trace.executions.append(ex)
    wall = getattr(executor, "last_batch_wall", None)
    if c_max is not None and wall is not None and wall > c_max:
        # C_max straggler: the batch's REAL execution blew the blocking
        # bound of §4.2-4.3.  Re-dispatch the (idempotent) batch once and
        # flag the event; modelled time is unaffected.  The requeue runs
        # BEFORE ``on_batch`` so observers see only the settled batch: a
        # SharedBook would otherwise release/evict the batch's panes first
        # and force the re-execution into a full rescan (and re-deposit) of
        # partials it had already shared, and calibration feedback would
        # sample the straggling outlier instead of the final execution.
        trace.stragglers.append(query.query_id)
        requeue = getattr(executor, "requeue_batch", None)
        if requeue is not None:
            requeue(query, num_tuples, offset)
    if on_batch:
        on_batch(ex)
    return ex


def _record_shard_group(
    trace: ExecutionTrace,
    executor: "ExecutorPool",
    query: Query,
    sizes: Sequence[int],
    base_offset: int,
    on_batch: Optional[Callable[[BatchExecution], None]],
    c_max: Optional[float],
) -> List[BatchExecution]:
    """Fused-dispatch analogue of ``_record_batch`` for one shard group:
    the pool hands the whole group to its worker backend as ONE call (e.g.
    one ``shard_map`` over the mesh) and returns per-shard Dispatches that
    share the fused call's timeline.  One BatchExecution is recorded per
    shard so traces stay shaped like per-shard dispatch; the C_max check
    applies to the fused call's measured wall time, and a straggling group
    is requeued as a single covering batch (idempotent offset-keyed redo)."""
    dispatches = executor.submit_shard_group(query, sizes, base_offset)
    exs = [
        BatchExecution(query.query_id, d.start, d.end, size, worker=d.worker)
        for d, size in zip(dispatches, sizes)
    ]
    trace.executions.extend(exs)
    wall = getattr(executor, "last_batch_wall", None)
    if c_max is not None and wall is not None and wall > c_max:
        trace.stragglers.append(query.query_id)
        executor.requeue_batch(query, sum(sizes), base_offset)
    if on_batch:
        for ex in exs:
            on_batch(ex)
    return exs


def _record_final_agg(
    trace: ExecutionTrace,
    executor: Executor,
    query: Query,
    num_batches: int,
    on_batch: Optional[Callable[[BatchExecution], None]],
) -> float:
    """Run the final aggregation and return the query's COMPLETION instant
    (end of the aggregation on whichever timeline ran it)."""
    start = executor.clock()
    agg = executor.finalize(query, num_batches)
    disp = getattr(executor, "last_dispatch", None)
    if disp is not None:
        start, end, worker = disp.start, disp.end, disp.worker
    else:
        end, worker = start + agg, ""
    if agg > 0:
        ex = BatchExecution(
            query.query_id, start, end, 0, kind="final_agg", worker=worker
        )
        trace.executions.append(ex)
        if on_batch:
            on_batch(ex)
    return end


def _record_outcome(
    trace: ExecutionTrace,
    query: Query,
    num_batches: int,
    completion: float,
    *,
    tuples_processed: int = -1,
    shed_fraction: float = 0.0,
    error_bound: float = 0.0,
) -> QueryOutcome:
    out = QueryOutcome(
        query_id=query.query_id,
        completion_time=completion,
        deadline=query.deadline,
        total_cost=sum(
            e.end - e.start
            for e in trace.executions
            if e.query_id == query.query_id
        ),
        num_batches=num_batches,
        tuples_processed=tuples_processed,
        num_tuples_total=query.num_tuples_total,
        shed_fraction=shed_fraction,
        error_bound=error_bound,
        latency_target=query.latency_target,
        target_time=(query.target_time
                     if query.latency_target is not None else None),
        tenant=query.tenant,
    )
    trace.outcomes.append(out)
    return out


# ---------------------------------------------------------------------------
# Plan execution (Algorithm 1's while-loop — the single static-path copy)
# ---------------------------------------------------------------------------


def execute_plan(
    query: Query,
    plan: Schedule,
    executor: Optional[Executor] = None,
    truth: Optional[ArrivalModel] = None,
    *,
    strict: bool = False,
    trace: Optional[ExecutionTrace] = None,
    on_batch: Optional[Callable[[BatchExecution], None]] = None,
    c_max: Optional[float] = None,
    carryover: bool = False,
    shed_fraction: float = 0.0,
    error_bound: float = 0.0,
) -> ExecutionTrace:
    """Execute one query's plan on ``executor`` (simulated by default).

    ``strict=False``: Algorithm 1's adaptive loop — trigger a batch when
    EITHER its planned tuple count is available OR its planned time point is
    reached, then process whatever is there (absorbs input-rate
    mispredictions against the ``truth`` arrival process).

    ``strict=True``: replay the planned batches verbatim (sizes and order) at
    ``max(clock, sched_time)`` — the mode real backends use to apply a vetted
    plan to fully materialized inputs.

    ``carryover=True``: keep the executor's running clock (a continuous
    session timeline, where one executor serves many window queries back to
    back) instead of resetting it to the query's ``submit_time``; the clock
    only ever moves forward.

    With an ``ExecutorPool`` both modes dispatch each triggered batch to the
    earliest-free worker (``pool.clock()`` IS the earliest-free instant), so
    consecutive batches of one query overlap across workers; the final
    aggregation waits for the last partial.
    """
    executor = SimulatedExecutor() if executor is None else executor
    trace = ExecutionTrace() if trace is None else trace
    if carryover:
        executor.advance(query.submit_time)
    else:
        executor.reset(query.submit_time)  # each query gets its own timeline

    n_batches = 0
    if strict:
        offset = 0
        for b in plan.batches:
            if b.num_tuples <= 0:
                continue
            executor.advance(b.sched_time)
            _record_batch(
                trace, executor, query, b.num_tuples, offset,
                on_batch=on_batch, c_max=c_max,
            )
            offset += b.num_tuples
            n_batches += 1
        processed = offset
    else:
        if not plan.batches and query.num_tuples_total > 0:
            raise ValueError(
                f"{query.query_id}: empty plan for {query.num_tuples_total} "
                "tuples — plan the query first (Planner.plan)"
            )
        arr = truth if truth is not None else query.arrival
        pending = query.num_tuples_total
        processed = 0
        ptr = 0
        required = plan.batches[0].num_tuples if plan.batches else 0
        while pending > 0:
            now = executor.clock()
            avail = arr.tuples_available(now) - processed
            point = plan.batches[min(ptr, plan.num_batches - 1)].sched_time
            # Algorithm 1 trigger: enough tuples ready, OR the planned
            # instant passed (then "Process the Available Tuples").
            if (avail >= required or now >= point - _EPS) and avail > 0:
                take = min(avail, pending)
                _record_batch(
                    trace, executor, query, take, processed,
                    on_batch=on_batch, c_max=c_max,
                )
                processed += take
                pending -= take
                n_batches += 1
                required -= take
                if ptr < plan.num_batches - 1 and required <= 0:
                    ptr += 1
                    required += plan.batches[ptr].num_tuples
                required = max(required, 0)
            else:
                # Discrete-event jump: earliest instant at which the trigger
                # can fire — the `required`-th outstanding tuple arriving, or
                # the planned time point, whichever first.  When the truth
                # stream ends before the plan's next full batch, no further
                # arrival helps, but Algorithm 1's "planned instant passed ->
                # process the available tuples" path must still fire at the
                # time point for the arrived tail.
                want = processed + max(required, 1)
                next_arrival = (
                    arr.input_time(want)
                    if want <= arr.num_tuples_total
                    else math.inf
                )
                wait_for = min(processed + 1, arr.num_tuples_total)
                nxt = min(next_arrival, max(point, arr.input_time(wait_for)))
                if not math.isfinite(nxt) or nxt <= now + _EPS:
                    # Nothing further will arrive or trigger: the truth
                    # stream under-delivered against the plan.  The outcome
                    # below records the shortfall (``pending`` tuples never
                    # materialized) instead of posing as a completion.
                    break
                executor.advance(nxt)

    completion = _record_final_agg(trace, executor, query, n_batches, on_batch)
    _record_outcome(
        trace, query, n_batches, completion, tuples_processed=processed,
        shed_fraction=shed_fraction, error_bound=error_bound,
    )
    return trace


# ---------------------------------------------------------------------------
# The shared runtime loop
# ---------------------------------------------------------------------------


def run(
    policy: SchedulingPolicy,
    workload: Union[Query, DynamicQuerySpec, Workload],
    executor: Optional[Executor] = None,
    *,
    start_time: Optional[float] = None,
    max_steps: Optional[int] = None,
    strict: bool = False,
    on_batch: Optional[Callable[[BatchExecution], None]] = None,
    c_max: Optional[float] = None,
    sharing: Optional["SharedBook"] = None,  # noqa: F821  (panes.py)
    runtime: Optional[str] = None,
) -> ExecutionTrace:
    """Run ``workload`` under ``policy`` on ``executor`` (simulated when
    omitted) and return the full ExecutionTrace with per-query outcomes.

    ``c_max`` bounds the REAL per-batch execution time for straggler
    detection; it defaults to the policy's own C_max (dynamic policies carry
    one; static policies don't, so pass it explicitly to enable straggler
    re-queue on static runs).  ``strict`` applies only to static policies
    (replay plans verbatim); ``start_time``/``max_steps`` only to dynamic
    ones — passing an inapplicable argument raises.

    ``runtime`` selects the dynamic decision core: ``"scan"`` (default) is
    the O(n)-per-instant walk; ``"heap"`` is the event-heap core
    (``HeapLoopCore``) — same decisions, byte-identical traces, O(log n)
    per instant.  The heap engages only for policies whose ``replan`` is
    ``DynamicPolicy``'s (see ``heap_capable``); custom-replan and static
    policies fall back to the scan path unchanged.

    ``sharing`` attaches a ``repro.core.panes.SharedBook`` whose pane
    bookkeeping observes every executed batch (deposits the first coverage
    of each pane, counts reuse, releases refcounts).  The workload must
    already be share-transformed (``panes.share_workload`` — which is what
    assigns the shared cost models); ``panes.run_shared`` bundles the
    transform, this call and the book teardown.  ``sharing=None`` (the
    default) leaves the loop byte-identical to the unshared runtime."""
    specs = as_specs(workload)
    executor = SimulatedExecutor() if executor is None else executor
    if sharing is not None:
        on_batch = sharing.chain(on_batch)
    if c_max is None:
        c_max = getattr(policy, "c_max", None)
    if getattr(policy, "kind", "static") == "dynamic":
        if strict:
            raise ValueError(
                "strict= applies to static policies only (dynamic policies "
                "have no up-front plan to replay)"
            )
        return _run_dynamic(
            policy, executor, specs,
            start_time=start_time,
            max_steps=1_000_000 if max_steps is None else max_steps,
            on_batch=on_batch, c_max=c_max, runtime=runtime,
        )
    if runtime not in (None, "scan", "heap"):
        raise ValueError(f"runtime must be 'scan' or 'heap', got {runtime!r}")
    if start_time is not None or max_steps is not None:
        raise ValueError(
            "start_time=/max_steps= apply to dynamic policies only (static "
            "runs give each query its own timeline from submit_time)"
        )
    return _run_static(
        policy, executor, specs, strict=strict, on_batch=on_batch, c_max=c_max,
    )


def _run_static(
    policy: SchedulingPolicy,
    executor: Executor,
    specs: List[DynamicQuerySpec],
    *,
    strict: bool,
    on_batch: Optional[Callable[[BatchExecution], None]],
    c_max: Optional[float],
) -> ExecutionTrace:
    """Static policies: plan each query up front, execute independently.

    Each query runs on its own timeline (the paper's single-query scenarios
    assume a dedicated executor per query; §3)."""
    trace = ExecutionTrace()
    for spec in specs:
        plan = policy.plan(spec.query)[spec.query.query_id]
        execute_plan(
            spec.query, plan, executor,
            truth=spec.truth, strict=strict, trace=trace,
            on_batch=on_batch, c_max=c_max,
            shed_fraction=spec.shed_fraction, error_bound=spec.error_bound,
        )
    return trace


class DynamicLoopCore:
    """One-decision-instant stepping core of Algorithm 2's NINP loop.

    ``run()`` drives it to exhaustion for a fixed workload; a ``Session``
    drives it incrementally (``tick(horizon=...)``) on a CONTINUOUS timeline,
    appending new ``QueryRuntime``s between ticks as windows roll over or
    queries are admitted mid-run.  Admissions/deletions happen only between
    batches (§4.2: "the scheduler takes the new query at the end of the
    batch"); the policy picks the winner at each decision instant; the
    executor performs the batch.  When an admission happens, the next
    ``replan`` receives an ``"admission"`` SchedulingEvent naming the
    admitted query — the decision instant §4.2 introduces for new arrivals.
    """

    def __init__(
        self,
        policy: SchedulingPolicy,
        executor: Executor,
        state: RuntimeState,
        *,
        on_batch: Optional[Callable[[BatchExecution], None]] = None,
        c_max: Optional[float] = None,
    ):
        self.policy = policy
        self.executor = executor
        self.state = state
        self.on_batch = on_batch
        self.c_max = c_max
        self.is_pool = getattr(executor, "is_pool", False)
        self._event_kind = "start"
        self._event_qid: Optional[str] = None

    @property
    def runts(self) -> List[QueryRuntime]:
        return self.state.runtimes

    # -- heap-core hooks (no-ops on the scan core) -----------------------
    def _register_new(self) -> None:
        """Absorb runtimes appended to ``state.runtimes`` since last tick."""

    def notify(self, rt: QueryRuntime) -> None:
        """A runtime's readiness-relevant state changed outside the loop
        (withdraw set ``delete_time``, shed/recalibrate resized MinBatch,
        overload thinned the stream).  The scan core re-derives everything
        each tick; the heap core re-indexes the runtime."""

    def _note_completed(self, rt: QueryRuntime) -> None:
        """``rt`` just completed inside ``tick``."""

    def _admit_and_delete(self, now: float) -> Optional[str]:
        """Flip admissions/deletions due at ``now``; return the last admitted
        query id (None when no admission happened)."""
        admitted: Optional[str] = None
        for rt in self.runts:
            if not rt.admitted and rt.q.submit_time <= now + _EPS:
                rt.admitted = True
                rt.rr_seq = self.state.rr_counter
                self.state.rr_counter += 1
                on_admit = getattr(self.policy, "on_admit", None)
                if on_admit is not None:
                    on_admit(rt, now)
                elif rt.min_batch <= 0:
                    rt.min_batch = 1  # protocol-minimal policy: no sizing hook
                admitted = rt.q.query_id
            if (
                rt.spec.delete_time is not None
                and not rt.deleted
                and rt.spec.delete_time <= now + _EPS
                and not rt.completed
            ):
                rt.deleted = True
                on_withdraw = getattr(self.policy, "on_withdraw", None)
                if on_withdraw is not None:
                    on_withdraw(rt, now)
        return admitted

    def drained(self) -> bool:
        """No active work and nothing pending admission."""
        return not self.state.active() and all(
            r.admitted or r.deleted for r in self.runts
        )

    def tick(self, horizon: float = math.inf) -> str:
        """Process ONE decision instant.  Returns:

        * ``"done"``    — drained: every runtime completed or deleted;
        * ``"stop"``    — the policy declared nothing will ever be ready;
        * ``"wait"``    — idled forward to the policy's wake instant;
        * ``"ran"``     — dispatched one batch (or shard group);
        * ``"horizon"`` — the next actionable instant lies beyond
          ``horizon`` (the clock was advanced exactly to it; only a session
          passes a finite horizon).
        """
        executor, state, trace = self.executor, self.state, self.state.trace
        self._register_new()
        now = executor.clock()
        if now > horizon + _EPS:
            return "horizon"
        admitted = self._admit_and_delete(now)
        if admitted is not None:
            self._event_kind, self._event_qid = "admission", admitted
        if self.drained():
            return "done"

        if self.is_pool:
            state.worker_clocks = tuple(
                executor.worker_clock(n) for n in state.worker_names
            )
            state.worker_weights = tuple(
                getattr(executor, "worker_weights", None) or ()
            )
        decision = self._decide(now)
        if decision.is_stop:
            return "stop"
        if decision.is_wait:
            self._event_kind, self._event_qid = "wake", None
            if decision.wake_at > horizon + _EPS:
                executor.advance(horizon)
                return "horizon"
            executor.advance(decision.wake_at)
            return "wait"

        rt = state.by_id(decision.query_id)
        rt.rr_seq = state.rr_counter  # rotate to the back for RR fairness
        state.rr_counter += 1

        if (decision.worker is not None or decision.shards) and not self.is_pool:
            raise ValueError(
                f"policy {getattr(self.policy, 'name', self.policy)!r} "
                "emitted a worker-targeted decision but the executor is not "
                "an ExecutorPool"
            )
        if decision.shards:
            if (
                getattr(executor, "prefers_group_dispatch", False)
                and all(s.worker is None for s in decision.shards)
            ):
                # Fused group dispatch: the whole shard group runs as ONE
                # backend call (e.g. one shard_map over the mesh) — the
                # dispatch-overhead amortization the modelled per-shard
                # path cannot express.
                sizes = [s.num_tuples for s in decision.shards]
                _record_shard_group(
                    trace, executor, rt.q, sizes, rt.processed,
                    on_batch=self.on_batch, c_max=self.c_max,
                )
                rt.processed += sum(sizes)
                rt.batches_done += len(sizes)
            else:
                # One logical batch split across workers: each shard becomes
                # its own offset-keyed partial (combined in finalize),
                # dispatched to its named worker or the next unclaimed
                # earliest-free one.
                claimed: List[str] = []
                for shard in decision.shards:
                    name = shard.worker
                    if name is None:
                        name = executor.earliest_free(exclude=claimed)
                    claimed.append(name)
                    _record_batch(
                        trace, executor, rt.q, shard.num_tuples, rt.processed,
                        on_batch=self.on_batch, c_max=self.c_max, worker=name,
                    )
                    rt.processed += shard.num_tuples
                    rt.batches_done += 1
        else:
            _record_batch(
                trace, executor, rt.q, decision.num_tuples, rt.processed,
                on_batch=self.on_batch, c_max=self.c_max,
                worker=decision.worker,
            )
            rt.processed += decision.num_tuples
            rt.batches_done += 1
        self._event_kind, self._event_qid = "batch_end", rt.q.query_id

        # -- completion: all that will ever arrive has been processed -----
        if rt.done(executor.clock()):
            completion = _record_final_agg(
                trace, executor, rt.q, rt.batches_done, self.on_batch
            )
            rt.completed = True
            _record_outcome(
                trace, rt.q, rt.batches_done, completion,
                tuples_processed=rt.processed,
                shed_fraction=rt.spec.shed_fraction,
                error_bound=rt.spec.error_bound,
            )
            self._note_completed(rt)
        return "ran"

    def _decide(self, now: float) -> "PolicyDecision":
        """One decision: consult the policy over the full runtime state."""
        return self.policy.replan(
            SchedulingEvent(self._event_kind, now, self._event_qid), self.state
        )


class HeapLoopCore(DynamicLoopCore):
    """Event-heap decision core: O(log n) per decision instant.

    Same decisions, same traces, different bookkeeping.  The scan core
    re-derives everything from scratch each tick — O(n) walks for
    admissions, drain detection and the wait-instant ``min`` over every
    unfinished runtime.  This core replaces the walks with event heaps:

    * **admit heap** ``(submit_time, idx)`` — pending admissions pop in due
      order; due batches are applied in runtime-list order, so ``rr_seq``
      tickets are assigned exactly as the scan's in-order walk assigns them.
    * **delete heap** ``(delete_time, idx)`` — lazy-deletion: ``withdraw``
      just pushes an event (via ``notify``); stale/duplicate entries are
      skipped on pop.  Deletions are processed after the tick's admissions
      (they never touch the rr counter, so relative ticket order — the only
      thing policies compare — matches the scan walk; see the parity tests).
    * **ready heap** ``(wake_time, seq, idx)`` — lower bounds on each
      runtime's ``next_ready_time``.  Due entries pop into a **ready pool**
      whose members are (re)validated with ``QueryRuntime.ready`` at each
      decision instant; validation failures are pushed back at their fresh
      ``next_ready_time``.  When nothing is ready, the wake instant is found
      by peek-revalidate: pop the top, recompute its exact readiness, and
      stop as soon as the recomputed instant is <= every remaining (lower
      bound) entry — which makes it the global minimum, i.e. exactly the
      scan loop's ``min(next_ready_time)``.

    Liveness counters (`admitted & !completed & !deleted`, and
    `!admitted & !deleted`) replace the ``drained`` walks.  One scan
    behaviour is intentionally NOT replicated: the scan walk "admits"
    already-deleted runtimes (consuming an rr ticket for a runtime that can
    never compete); the heap skips those phantom admissions.  Ticket
    *values* then differ, but ticket *order* among live runtimes — the only
    observable — does not, and traces stay byte-identical.

    Winner selection mirrors ``DynamicPolicy.replan`` exactly (the core is
    only engaged for policies whose ``replan`` IS DynamicPolicy's —
    see ``heap_capable``): strict tiers, then ``policy.priority``, with the
    pool's vectorized ``DynamicPolicy.select`` doing the ordering.
    """

    def __init__(
        self,
        policy: SchedulingPolicy,
        executor: Executor,
        state: RuntimeState,
        *,
        on_batch: Optional[Callable[[BatchExecution], None]] = None,
        c_max: Optional[float] = None,
    ):
        super().__init__(policy, executor, state, on_batch=on_batch,
                         c_max=c_max)
        self._registered = 0
        self._rt_index: Dict[int, int] = {}  # id(rt) -> runtimes index
        self._admit_heap: List[Tuple[float, int]] = []
        self._delete_heap: List[Tuple[float, int]] = []
        self._ready_heap: List[Tuple[float, int, int]] = []
        self._ready_pool: Set[int] = set()
        self._seq = 0  # push order: stable tiebreak inside the ready heap
        self._num_active = 0
        self._num_unadmitted = 0
        self._register_new()

    # -- registration and external-change notifications ------------------
    def _register_new(self) -> None:
        runts = self.state.runtimes
        clock = self.executor.clock()
        while self._registered < len(runts):
            idx = self._registered
            rt = runts[idx]
            self._rt_index[id(rt)] = idx
            if not (rt.completed or rt.deleted):
                if rt.admitted:
                    self._num_active += 1
                    self._push_ready(idx, clock)
                else:
                    self._num_unadmitted += 1
                    heapq.heappush(self._admit_heap, (rt.q.submit_time, idx))
            if rt.spec.delete_time is not None and not rt.deleted:
                heapq.heappush(self._delete_heap, (rt.spec.delete_time, idx))
            self._registered = idx + 1

    def notify(self, rt: QueryRuntime) -> None:
        idx = self._rt_index.get(id(rt))
        if idx is None:
            return  # not registered yet; _register_new will index it
        if (rt.spec.delete_time is not None
                and not (rt.deleted or rt.completed)):
            heapq.heappush(self._delete_heap, (rt.spec.delete_time, idx))
        if (rt.admitted and not (rt.completed or rt.deleted)
                and idx not in self._ready_pool):
            # The current clock is always a safe lower bound on the (possibly
            # changed) readiness instant; the stale entry stays in the heap
            # and is lazily revalidated.
            self._push_ready(idx, self.executor.clock())

    def _note_completed(self, rt: QueryRuntime) -> None:
        idx = self._rt_index[id(rt)]
        self._num_active -= 1
        self._ready_pool.discard(idx)

    def _push_ready(self, idx: int, t: float) -> None:
        self._seq += 1
        heapq.heappush(self._ready_heap, (t, self._seq, idx))

    # -- tick bookkeeping -------------------------------------------------
    def _admit_and_delete(self, now: float) -> Optional[str]:
        runts = self.state.runtimes
        due: List[int] = []
        while self._admit_heap and self._admit_heap[0][0] <= now + _EPS:
            _, idx = heapq.heappop(self._admit_heap)
            rt = runts[idx]
            if not rt.admitted and not rt.deleted:
                due.append(idx)
        due.sort()  # runtime-list order: rr tickets match the scan walk
        admitted: Optional[str] = None
        for idx in due:
            rt = runts[idx]
            rt.admitted = True
            rt.rr_seq = self.state.rr_counter
            self.state.rr_counter += 1
            on_admit = getattr(self.policy, "on_admit", None)
            if on_admit is not None:
                on_admit(rt, now)
            elif rt.min_batch <= 0:
                rt.min_batch = 1  # protocol-minimal policy: no sizing hook
            admitted = rt.q.query_id
            self._num_unadmitted -= 1
            self._num_active += 1
            self._ready_pool.add(idx)  # validated at the decision instant
        while self._delete_heap and self._delete_heap[0][0] <= now + _EPS:
            _, idx = heapq.heappop(self._delete_heap)
            rt = runts[idx]
            if (rt.deleted or rt.completed or rt.spec.delete_time is None
                    or rt.spec.delete_time > now + _EPS):
                continue  # stale/duplicate lazy-deletion entry
            rt.deleted = True
            on_withdraw = getattr(self.policy, "on_withdraw", None)
            if on_withdraw is not None:
                on_withdraw(rt, now)
            if rt.admitted:
                self._num_active -= 1
            else:
                self._num_unadmitted -= 1
            self._ready_pool.discard(idx)
        return admitted

    def drained(self) -> bool:
        return self._num_active == 0 and self._num_unadmitted == 0

    # -- the decision ----------------------------------------------------
    def _collect_ready(self, now: float) -> List[int]:
        """Due heap entries join the pool; the pool is then (re)validated.
        Returns the validated ready set in runtime-list order."""
        runts = self.state.runtimes
        heap, pool = self._ready_heap, self._ready_pool
        while heap and heap[0][0] <= now + _EPS:
            _, _, idx = heapq.heappop(heap)
            rt = runts[idx]
            if rt.admitted and not (rt.completed or rt.deleted):
                pool.add(idx)
        ready: List[int] = []
        stale: List[int] = []
        for idx in pool:
            if runts[idx].ready(now):
                ready.append(idx)
            else:
                stale.append(idx)
        for idx in stale:
            pool.discard(idx)
            self._push_ready(idx, runts[idx].next_ready_time(now))
        ready.sort()
        return ready

    def _next_wake(self, now: float) -> float:
        """Exact ``min(next_ready_time)`` over unfinished runtimes, found by
        peek-revalidating the event heaps instead of walking the world."""
        runts = self.state.runtimes
        best = math.inf
        while self._admit_heap:
            t, idx = self._admit_heap[0]
            rt = runts[idx]
            if rt.admitted or rt.deleted:
                heapq.heappop(self._admit_heap)
                continue
            best = t  # an unadmitted runtime wakes at its submit_time
            break
        heap = self._ready_heap
        while heap:
            if heap[0][0] >= best:
                break  # every (lower-bound) entry is at/past the admit wake
            t, seq, idx = heapq.heappop(heap)
            rt = runts[idx]
            if rt.completed or rt.deleted or not rt.admitted:
                continue
            fresh = rt.next_ready_time(now)
            heapq.heappush(heap, (fresh, seq, idx))
            if fresh <= heap[0][0]:
                best = min(best, fresh)
                break
        return best

    def _decide(self, now: float) -> PolicyDecision:
        ready_idx = self._collect_ready(now)
        if not ready_idx:
            nxt = self._next_wake(now)
            if not math.isfinite(nxt):
                return PolicyDecision()  # stop: nothing will ever be ready
            return PolicyDecision(wake_at=nxt)
        runts = self.state.runtimes
        rt = self.policy.select([runts[i] for i in ready_idx], now)
        take = min(rt.avail(now), rt.min_batch)
        ways = min(self.policy.shard_across, self.state.free_workers(now),
                   take)
        if ways > 1:
            from .policies.dynamic import make_shards

            return PolicyDecision(
                query_id=rt.q.query_id, num_tuples=take,
                shards=make_shards(self.state, take, ways, now),
            )
        return PolicyDecision(query_id=rt.q.query_id, num_tuples=take)


def heap_capable(policy: SchedulingPolicy) -> bool:
    """True when ``policy``'s decisions are exactly ``DynamicPolicy.replan``
    — the contract the heap core mirrors.  Policies overriding ``replan``
    (custom decision logic the heap cannot see) silently fall back to the
    scan core."""
    if getattr(policy, "kind", "static") != "dynamic":
        return False
    from .policies.dynamic import DynamicPolicy

    return (isinstance(policy, DynamicPolicy)
            and type(policy).replan is DynamicPolicy.replan)


def _core_class(policy: SchedulingPolicy, runtime: Optional[str]):
    if runtime not in (None, "scan", "heap"):
        raise ValueError(
            f"runtime must be 'scan' or 'heap', got {runtime!r}"
        )
    if runtime == "heap" and heap_capable(policy):
        return HeapLoopCore
    return DynamicLoopCore


def _run_dynamic(
    policy: SchedulingPolicy,
    executor: Executor,
    specs: List[DynamicQuerySpec],
    *,
    start_time: Optional[float],
    max_steps: int,
    on_batch: Optional[Callable[[BatchExecution], None]],
    c_max: Optional[float],
    runtime: Optional[str] = None,
) -> ExecutionTrace:
    """Algorithm 2's NINP loop over a fixed workload (see DynamicLoopCore)."""
    runts = [QueryRuntime(spec=s) for s in specs]
    trace = ExecutionTrace()
    if not runts:
        return trace
    start = (
        min(r.q.submit_time for r in runts) if start_time is None else start_time
    )
    executor.reset(start)
    state = RuntimeState(
        runtimes=runts,
        trace=trace,
        num_workers=getattr(executor, "num_workers", 1),
        worker_names=tuple(getattr(executor, "worker_names", ())),
    )
    core = _core_class(policy, runtime)(policy, executor, state,
                                        on_batch=on_batch, c_max=c_max)
    for _ in range(max_steps):
        if core.tick() in ("done", "stop"):
            break
    return trace
