"""Pane-based shared execution for overlapping multi-query windows.

The paper schedules each intermittent query as if it owned its input: k
queries over the same stream with overlapping windows pay k scans over the
shared tuples.  Window-based stream processing solved exactly this with
pane/slice sharing (Li et al.'s panes; Cutty/Scotty slices; Mayer et al.'s
window-based parallel CEP): decompose every window into aligned panes —
width = GCD of the subscribed windows' ranges and slides, in tuples — keep
one partial aggregate per pane, and assemble each window's result by
MERGING its panes' partials.  The shared tuples are scanned once, total.

This module is that layer for ``repro.core``:

* ``pane_width``        — the GCD decomposition (window ranges + slides ->
  pane width in stream tuples).
* ``PaneStore``         — the partial-aggregate cache: panes are
  subscribed by every query whose window contains them, deposited once
  (the first scan), reused by later subscribers, and EVICTED by reference
  count the moment the last subscriber has consumed them — the cache's
  resident set is bounded by the windows still in flight, not by stream
  length.
* ``SharedBook``        — runtime-side bookkeeping: it watches the shared
  loop's ``BatchExecution`` stream (``observe`` plugs into the loop's
  ``on_batch`` hook) and advances per-query watermarks, depositing and
  releasing panes as batches cover them.  Physical executors (e.g.
  ``repro.serve.analytics.SharedAnalyticsExecutor``) share the same
  ``PaneStore`` to deduplicate REAL work; in pure simulation the store
  carries no data and the book alone keeps the counts honest.
* ``share_workload``    — the enabling transform: group a workload by
  ``Query.stream``, wrap each shared query's cost model in
  ``SharedCostModel`` (one scan + k merges, amortized per query — so
  policies, MinBatch sizing and ``admission_check`` all see the cheaper
  shared cost), and subscribe every query's panes.
* ``run_shared``        — ``runtime.run`` with sharing enabled end to end.

Sharing is a POLICY-VISIBLE choice, not a runtime fork: the loop itself is
unchanged, decisions still come from the same nine policies, and with
sharing disabled (the default everywhere) traces are byte-identical to the
unshared runtime.  What changes when it is on: per-query cost models (and
therefore laxities, MinBatch sizes and admission verdicts) reflect the
shared cost, dynamic policies align MinBatches to pane boundaries, and the
pane store deduplicates the physical work.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from .cost_model import SharedCostModel
from .types import BatchExecution, ExecutionTrace, PaneSpec, Query

__all__ = [
    "PaneStats",
    "PaneStore",
    "SharedBook",
    "pane_width",
    "panes_in",
    "run_shared",
    "share_workload",
]


def pane_width(ranges: Iterable[int], slides: Iterable[int] = ()) -> int:
    """Pane width in stream tuples: GCD of the window ranges and slides.

    With this width every subscribed window starts and ends exactly on a
    pane boundary (a window range is a multiple of the width, and so is
    every offset between window starts), so windows are exact unions of
    panes.  Zero slides (fully aligned windows) contribute nothing; an
    empty input yields 1.
    """
    g = 0
    for v in ranges:
        g = math.gcd(g, int(v))
    for v in slides:
        g = math.gcd(g, int(v))
    return max(g, 1)


def panes_in(stream: str, width: int, lo: int, hi: int) -> List[PaneSpec]:
    """The panes of ``stream`` fully contained in global tuple range
    ``[lo, hi)``.  With GCD-aligned windows this is an exact cover; with an
    explicit (smaller/misaligned) width the uncovered fragments simply stay
    unshared."""
    if hi <= lo:
        return []
    first = -(-lo // width)  # ceil: first pane starting at/after lo
    out = []
    idx = first
    while (idx + 1) * width <= hi:
        out.append(PaneSpec(stream=stream, index=idx, offset=idx * width,
                            num_tuples=width))
        idx += 1
    return out


@dataclasses.dataclass
class PaneStats:
    """Aggregate counters of one ``PaneStore``.

    ``scans`` — panes computed (deposited) for the first time;
    ``hits`` — pane consumptions served from the cache (a subscriber other
    than the depositor folded a cached partial instead of rescanning);
    ``fragment_scans`` — panes a query covered across MULTIPLE batches
    (batch boundary inside the pane): the tuples were scanned privately as
    fragments, so no reusable partial exists and the pane stays
    undeposited for later subscribers to compute wholesale;
    ``evictions`` — cached panes dropped after their last subscriber
    released them; ``peak_resident`` — high-water mark of simultaneously
    cached panes (the cache's memory bound, in panes).

    Speculative (forecast-driven) pre-warming keeps its own books:
    ``speculative_deposits`` — panes computed AHEAD of demand during idle
    capacity (``SharedBook.prewarm``; not counted in ``scans`` — the work
    was free wrt the loaded period); ``speculative_hits`` — pre-warmed
    panes a real subscriber later consumed from cache (the gamble paid
    off); ``speculative_misses`` — pre-warmed panes discarded unconsumed
    (the forecast was wrong; the idle work is written off).
    """

    scans: int = 0
    hits: int = 0
    fragment_scans: int = 0
    evictions: int = 0
    peak_resident: int = 0
    speculative_deposits: int = 0
    speculative_hits: int = 0
    speculative_misses: int = 0

    @property
    def reuse_ratio(self) -> float:
        """Fraction of pane consumptions served from cache (0 when nothing
        was consumed)."""
        total = self.scans + self.hits
        return self.hits / total if total else 0.0


@dataclasses.dataclass
class PaneEntry:
    """One pane's cache slot: who still needs it, who computed it, and the
    (optional) physical partial aggregate."""

    spec: PaneSpec
    refs: set = dataclasses.field(default_factory=set)
    computed: bool = False
    depositor: str = ""
    data: Optional[object] = None
    speculative: bool = False  # pre-warmed on a forecast, not yet consumed


class PaneStore:
    """Reference-counted pane partial-aggregate cache.

    Lifecycle of a pane: ``subscribe`` (each query whose window contains it
    takes a reference, at share/plan time) -> ``deposit`` (the first
    subscriber to scan it stores the partial; idempotent — later deposits
    are no-ops) -> ``release`` (a subscriber consumed it; when the last
    reference goes, the pane is EVICTED and its data dropped).  Panes
    nobody subscribed to are never cached; panes released before being
    computed vanish silently (the window was withdrawn first).

    The store is executor-agnostic: ``data`` is whatever the physical
    backend wants to cache (a ``(num_groups, V)`` numpy partial for the
    segagg executor, ``None`` in pure simulation where only the
    bookkeeping matters).
    """

    def __init__(self) -> None:
        self._entries: Dict[Tuple[str, int], PaneEntry] = {}
        self.stats = PaneStats()

    # -- introspection ---------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def entry(self, stream: str, index: int) -> Optional[PaneEntry]:
        """The live entry for (stream, index), or None."""
        return self._entries.get((stream, index))

    @property
    def resident(self) -> int:
        """Panes currently cached (computed and not yet evicted)."""
        return sum(1 for e in self._entries.values() if e.computed)

    def refcount(self, stream: str, index: int) -> int:
        """Outstanding subscriber references of one pane (0 when absent)."""
        e = self._entries.get((stream, index))
        return len(e.refs) if e is not None else 0

    # -- lifecycle -------------------------------------------------------
    def subscribe(self, pane: PaneSpec, query_id: str) -> None:
        """Take a reference: ``query_id``'s window contains ``pane``."""
        e = self._entries.get(pane.key)
        if e is None:
            e = self._entries[pane.key] = PaneEntry(spec=pane)
        e.refs.add(query_id)

    def deposit(self, stream: str, index: int, *, by: str,
                data: Optional[object] = None,
                speculative: bool = False) -> bool:
        """Store the pane's partial aggregate (the first scan).  Returns
        True when this call computed the pane, False when it was already
        cached (idempotent: straggler re-queues and the book's
        watermark-level deposit after a physical deposit are no-ops).

        ``speculative=True`` marks a forecast-driven pre-warm deposit
        (``SharedBook.prewarm``): counted under ``speculative_deposits``
        rather than ``scans`` — the pane was computed from idle capacity,
        not charged to any subscriber's demand scan."""
        e = self._entries.get((stream, index))
        if e is None:
            # Unsubscribed pane: nobody else will ever need it — don't cache.
            return False
        if e.computed:
            return False
        e.computed = True
        e.depositor = by
        e.data = data
        e.speculative = speculative
        if speculative:
            self.stats.speculative_deposits += 1
        else:
            self.stats.scans += 1
        self.stats.peak_resident = max(self.stats.peak_resident, self.resident)
        return True

    def release(self, stream: str, index: int, query_id: str) -> None:
        """Drop ``query_id``'s reference; evict the pane when it was the
        last one."""
        e = self._entries.get((stream, index))
        if e is None:
            return
        e.refs.discard(query_id)
        if not e.refs:
            if e.computed:
                self.stats.evictions += 1
            e.data = None
            del self._entries[(stream, index)]

    def record_hit(self) -> None:
        """Count one cache-served pane consumption (called by the book)."""
        self.stats.hits += 1

    def record_fragment_scan(self) -> None:
        """Count one pane consumed as private fragments (no reusable
        partial produced; called by the book)."""
        self.stats.fragment_scans += 1


@dataclasses.dataclass
class _QuerySub:
    """Per-query subscription state inside a ``SharedBook``."""

    query_id: str
    stream: str
    lo: int               # global stream index of the window's first tuple
    hi: int               # one past the window's last tuple
    panes: List[PaneSpec]
    watermark: int        # global stream index processed so far
    next_pane: int = 0    # position in ``panes`` not yet consumed/released
    done: bool = False


class SharedBook:
    """Runtime-side pane bookkeeping shared by the loop and the executors.

    The book owns the ``PaneStore`` plus the per-stream pane widths and
    per-query subscriptions.  It learns about progress purely from the
    loop's trace stream: ``observe`` is an ``on_batch`` callback — batches
    advance the query's stream watermark, and every pane the watermark
    passes is deposited (first coverage) or counted as a cache hit
    (previously deposited by another query), then released.  The loop and
    executors never need pane-aware control flow; physical executors that
    want to deduplicate REAL work read and write ``book.store`` directly
    inside ``_execute``/``_finalize``.
    """

    def __init__(self, pane_tuples: Optional[int] = None):
        self.store = PaneStore()
        self.widths: Dict[str, int] = {}
        self._subs: Dict[str, _QuerySub] = {}
        self._default_width = pane_tuples
        self._prewarms: Dict[str, List[PaneSpec]] = {}

    # -- registration ----------------------------------------------------
    def register_stream(self, stream: str, width: int) -> int:
        """Fix ``stream``'s pane width (first registration wins — panes of
        a live stream cannot be re-gridded mid-run).  The book's explicit
        ``pane_tuples`` override, when given, beats the caller's derived
        width.  Returns the width in effect."""
        if self._default_width is not None:
            width = self._default_width
        if width < 1:
            raise ValueError(f"pane width must be >= 1, got {width}")
        return self.widths.setdefault(stream, width)

    def peek_width(self, stream: str, derived: int) -> int:
        """The width that WOULD govern ``stream``: the registered one, else
        the book's explicit override, else ``derived`` — without
        registering anything (callers gate registration on admission and
        compatibility checks first)."""
        got = self.widths.get(stream)
        if got is not None:
            return got
        return self._default_width if self._default_width is not None else derived

    def knows(self, query_id: str) -> bool:
        """True when ``query_id`` has a pane subscription in this book."""
        return query_id in self._subs

    def register(self, query: Query) -> Optional[_QuerySub]:
        """Subscribe ``query``'s window panes.  The stream must have been
        registered (``register_stream``); non-stream queries are ignored."""
        if query.stream is None:
            return None
        width = self.widths.get(query.stream)
        if width is None:
            width = self.register_stream(
                query.stream,
                self._default_width or max(query.num_tuples_total, 1),
            )
        lo = query.stream_offset
        hi = lo + query.num_tuples_total
        panes = panes_in(query.stream, width, lo, hi)
        sub = _QuerySub(query_id=query.query_id, stream=query.stream,
                        lo=lo, hi=hi, panes=panes, watermark=lo)
        self._subs[query.query_id] = sub
        for p in panes:
            self.store.subscribe(p, query.query_id)
        return sub

    def sharers(self, stream: str) -> int:
        """Live (not withdrawn) subscriptions on ``stream``."""
        return sum(1 for s in self._subs.values()
                   if s.stream == stream and not s.done)

    # -- speculative pre-warming (forecast-driven) -----------------------
    def prewarm(self, query: Query, tag: str) -> int:
        """Speculatively compute ``query``'s window panes from idle
        capacity, on a forecast that the window WILL be demanded.

        Every pane of the window not yet cached is deposited with
        ``speculative=True`` under ``tag`` (the forecaster's identity — a
        ``\"?\"``-prefixed pseudo-subscriber so it can never collide with a
        real query id).  The tag holds a keep-alive reference per pane so
        an eviction by departing real subscribers cannot throw the warm
        partial away before the forecast resolves.  When real demand later
        consumes a pane, ``observe`` converts it into a ``speculative_hit``
        and drops the tag reference; panes still speculative when the
        forecast is judged wrong are written off via ``discard_prewarm``.

        Returns the number of panes actually pre-warmed (0 when the stream
        has no registered pane grid yet, the window is empty, or everything
        was already cached).  Idempotent per tag."""
        if query.stream is None or tag in self._prewarms:
            return 0
        width = self.widths.get(query.stream)
        if width is None:
            return 0
        lo = query.stream_offset
        panes = panes_in(query.stream, width, lo, lo + query.num_tuples_total)
        warmed: List[PaneSpec] = []
        for p in panes:
            e = self.store.entry(p.stream, p.index)
            if e is not None and e.computed:
                continue  # already cached by real demand — nothing to warm
            self.store.subscribe(p, tag)
            if self.store.deposit(p.stream, p.index, by=tag,
                                  speculative=True):
                warmed.append(p)
            else:
                self.store.release(p.stream, p.index, tag)
        if warmed:
            self._prewarms[tag] = warmed
        return len(warmed)

    def discard_prewarm(self, tag: str) -> int:
        """Write off ``tag``'s pre-warm: every pane still speculative is a
        forecast miss (counted, then released — evicting it unless real
        subscribers hold it).  Panes already converted to hits were
        released by ``observe`` and are skipped.  Returns the miss count;
        idempotent."""
        missed = 0
        for p in self._prewarms.pop(tag, []):
            e = self.store.entry(p.stream, p.index)
            if e is not None and e.speculative and e.depositor == tag:
                e.speculative = False
                self.store.stats.speculative_misses += 1
                missed += 1
                self.store.release(p.stream, p.index, tag)
        return missed

    # -- observation (the loop's on_batch hook) --------------------------
    def observe(self, ex: BatchExecution) -> None:
        """Advance ``ex.query_id``'s watermark by one executed batch and
        deposit/consume/release every pane the watermark fully passed.

        Batches of one query are sequential over its window (the loop
        dispatches them in offset order), so cumulative ``num_tuples`` IS
        the watermark — the book needs no offsets in the trace rows.
        """
        sub = self._subs.get(ex.query_id)
        if sub is None or sub.done or ex.kind != "batch":
            return
        batch_start = sub.watermark
        sub.watermark += ex.num_tuples
        while sub.next_pane < len(sub.panes):
            pane = sub.panes[sub.next_pane]
            if pane.end > sub.watermark:
                break
            entry = self.store.entry(pane.stream, pane.index)
            if entry is not None and entry.computed:
                if entry.depositor != ex.query_id:
                    self.store.record_hit()
                # depositor == query_id: the scan was already counted at
                # deposit time (by this very query's physical _execute or a
                # previous observe call) — nothing more to count.
                if entry.speculative:
                    # A pre-warmed pane met real demand: the forecast paid
                    # off.  Hand ownership to the demand path and drop the
                    # prewarm tag's keep-alive reference.
                    entry.speculative = False
                    self.store.stats.speculative_hits += 1
                    self.store.release(pane.stream, pane.index,
                                       entry.depositor)
            elif pane.offset >= batch_start:
                # This batch covered the whole pane: a reusable partial
                # exists (real executors deposited data just before this
                # callback; in simulation the bookkeeping alone matters).
                self.store.deposit(pane.stream, pane.index, by=ex.query_id)
            else:
                # The pane straddled a batch boundary: this query scanned
                # it as private fragments, so there is NO whole-pane
                # partial to reuse.  Leave the entry uncomputed — a later
                # subscriber covering it in one batch deposits it properly
                # — and never count phantom cache activity for it.
                self.store.record_fragment_scan()
            self.store.release(pane.stream, pane.index, ex.query_id)
            sub.next_pane += 1
        if sub.watermark >= sub.hi:
            sub.done = True

    # -- teardown --------------------------------------------------------
    def withdraw(self, query_id: str) -> None:
        """Release every pane ``query_id`` still holds (the query was
        withdrawn mid-run or under-delivered); idempotent."""
        sub = self._subs.get(query_id)
        if sub is None:
            return
        while sub.next_pane < len(sub.panes):
            pane = sub.panes[sub.next_pane]
            self.store.release(pane.stream, pane.index, query_id)
            sub.next_pane += 1
        sub.done = True

    def close(self) -> None:
        """End of run: release every outstanding reference so the store
        drains (shortfalls and withdrawn queries would otherwise pin
        panes).  Unresolved pre-warms are written off as forecast misses —
        the demand they anticipated never ran."""
        for tag in list(self._prewarms):
            self.discard_prewarm(tag)
        for qid in list(self._subs):
            self.withdraw(qid)

    def chain(
        self, on_batch: Optional[Callable[[BatchExecution], None]]
    ) -> Callable[[BatchExecution], None]:
        """``on_batch`` callback that first feeds the book, then the
        caller's own callback (if any)."""
        if on_batch is None:
            return self.observe

        def chained(ex: BatchExecution) -> None:
            self.observe(ex)
            on_batch(ex)

        return chained


# ---------------------------------------------------------------------------
# Workload transform + one-call runner
# ---------------------------------------------------------------------------


def share_workload(
    workload,
    *,
    pane_tuples: Optional[int] = None,
    book: Optional[SharedBook] = None,
) -> Tuple[List["DynamicQuerySpec"], SharedBook]:  # noqa: F821
    """Enable pane sharing on a workload: returns ``(specs, book)``.

    Queries naming the same ``Query.stream`` (two or more of them) become a
    share group: each one's cost model is wrapped in ``SharedCostModel``
    (amortized one-scan-+-k-merges, with the stream's pane width) and its
    window panes are subscribed in the book's ``PaneStore``.  Queries with
    ``stream=None`` — or alone on their stream — pass through UNTOUCHED, so
    a mixed workload shares only where sharing helps.  Input specs/queries
    are never mutated; shared ones are replaced copies.

    ``pane_tuples`` overrides the per-stream GCD width (the default derives
    it from every group member's window range and start-offset deltas, which
    makes windows exact unions of panes).  Pass an existing ``book`` to
    accumulate several submissions into one cache (what a Session does —
    cache carry-over across recurring windows).
    """
    from .runtime import as_specs

    specs = as_specs(workload)
    book = SharedBook(pane_tuples=pane_tuples) if book is None else book

    groups: Dict[str, List[int]] = {}
    for i, spec in enumerate(specs):
        if spec.query.stream is not None:
            groups.setdefault(spec.query.stream, []).append(i)

    out = list(specs)
    for stream, idxs in groups.items():
        if len(idxs) < 2:
            continue  # nothing to share with
        qs = [specs[i].query for i in idxs]
        if pane_tuples is not None:
            width = pane_tuples
        else:
            # ABSOLUTE offsets, not deltas: panes are anchored at global
            # stream index 0 (``panes_in``), so the width must divide every
            # window's start offset too — otherwise no window lands on the
            # pane grid and nothing is physically shared while the wrapped
            # cost models still promise amortization.
            width = pane_width(
                (q.num_tuples_total for q in qs),
                (q.stream_offset for q in qs if q.stream_offset),
            )
        width = book.register_stream(stream, width)
        # Per-query amortization from ACTUAL pane overlap, not group size:
        # each query's ``sharers`` is the mean subscriber count over its own
        # panes, so staggered windows amortize by their true overlap and a
        # window disjoint from every other stays unshared (k < 2) instead
        # of being priced against sharing that never happens.
        spans = {
            i: panes_in(stream, width, specs[i].query.stream_offset,
                        specs[i].query.stream_offset
                        + specs[i].query.num_tuples_total)
            for i in idxs
        }
        counts: Dict[int, int] = {}
        for panes in spans.values():
            for p in panes:
                counts[p.index] = counts.get(p.index, 0) + 1
        for i in idxs:
            panes = spans[i]
            if not panes:
                continue
            mean = sum(counts[p.index] for p in panes) / len(panes)
            k = max(1, int(round(mean)))
            if k < 2:
                continue  # no real overlap for this window: run unshared
            q = specs[i].query
            shared_q = dataclasses.replace(
                q, cost_model=SharedCostModel(q.cost_model, sharers=k,
                                              pane_tuples=width),
            )
            out[i] = dataclasses.replace(specs[i], query=shared_q)
            book.register(shared_q)
    return out, book


def run_shared(
    policy,
    workload,
    executor=None,
    *,
    pane_tuples: Optional[int] = None,
    on_batch: Optional[Callable[[BatchExecution], None]] = None,
    **runtime_kw,
) -> Tuple[ExecutionTrace, SharedBook]:
    """``runtime.run`` with pane sharing enabled end to end.

    Transforms the workload (``share_workload``), chains the book's
    observer into the loop's ``on_batch`` hook, runs, and closes the book
    (releasing any references a shortfall left behind).  Returns the trace
    plus the book — ``book.store.stats`` has the scan/hit/eviction counts
    a benchmark or operator dashboard wants.
    """
    from .runtime import run

    specs, book = share_workload(workload, pane_tuples=pane_tuples)
    trace = run(policy, specs, executor, on_batch=on_batch, sharing=book,
                **runtime_kw)
    book.close()
    return trace, book
