"""Discrete-event comparison harness (paper §7 experiments).

Implements the baselines the paper compares against, in the same cost-model
time units the paper's figures use:

* ``micro_batch_trace``  — Spark-streaming analogue: a batch every ``interval``
                           time units over the window (Fig 5's batch intervals;
                           ``interval -> 0`` degenerates to tuple-by-tuple).
* ``one_shot_trace``     — Spark "trigger once": everything in one batch at
                           window end, regardless of the deadline (Fig 5 /
                           Table 2's OneShot row).
* ``batched_cost_curve`` — cost as a function of the number of batches
                           (Fig 4's normalized curves).
* ``MemoryModel``        — resident-set accounting that reproduces the paper's
                           out-of-memory observations for streaming joins
                           (§7.2: Q10 OOMs at window 4500s in streaming mode,
                           succeeds in batch mode).
* ``staggered_deadlines``— the §7.4 multi-query workload generator (delta-
                           staggered deadlines over a shared window).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from .types import BatchExecution, ExecutionTrace, Query, QueryOutcome


def micro_batch_trace(query: Query, interval: float) -> ExecutionTrace:
    """Process arrivals every ``interval`` time units (eager streaming).

    Each trigger processes whatever arrived since the last trigger; the final
    aggregation combines all micro-batch partials.  Triggers that find no new
    tuples are skipped (Spark schedules-but-noops them; their overhead is
    negligible next to non-empty batches and charging it would only flatter
    our method).
    """
    arr, cm = query.arrival, query.cost_model
    trace = ExecutionTrace()
    t = query.wind_start + interval
    processed = 0
    nb = 0
    now = None
    while processed < query.num_tuples_total:
        t = min(t, arr.wind_end)
        avail = arr.tuples_available(t) - processed
        start = t if now is None else max(t, now)
        if avail > 0:
            c = cm.cost(avail)
            trace.executions.append(
                BatchExecution(query.query_id, start, start + c, avail)
            )
            now = start + c
            processed += avail
            nb += 1
        if t >= arr.wind_end and processed >= query.num_tuples_total:
            break
        t += interval
    agg = cm.agg_cost(nb) if nb > 1 else 0.0
    if agg and now is not None:
        trace.executions.append(
            BatchExecution(query.query_id, now, now + agg, 0, kind="final_agg")
        )
        now += agg
    trace.outcomes.append(
        QueryOutcome(
            query_id=query.query_id,
            completion_time=now if now is not None else query.wind_start,
            deadline=query.deadline,
            total_cost=trace.total_cost,
            num_batches=nb,
        )
    )
    return trace


def one_shot_trace(query: Query) -> ExecutionTrace:
    """Everything in one batch at window end (Spark trigger-once)."""
    cm = query.cost_model
    c = cm.cost(query.num_tuples_total)
    trace = ExecutionTrace()
    trace.executions.append(
        BatchExecution(query.query_id, query.wind_end, query.wind_end + c,
                       query.num_tuples_total)
    )
    trace.outcomes.append(
        QueryOutcome(
            query_id=query.query_id,
            completion_time=query.wind_end + c,
            deadline=query.deadline,
            total_cost=c,
            num_batches=1,
        )
    )
    return trace


def batched_cost_curve(
    query: Query, batch_counts: Sequence[int]
) -> List[Tuple[int, float, float]]:
    """Fig 4: (num_batches, cost, cost normalised to single-batch baseline).

    Tuples are split as evenly as the count allows (the paper splits its 4500
    files into equal batches).
    """
    cm = query.cost_model
    base = cm.cost(query.num_tuples_total)
    out = []
    for nb in batch_counts:
        nb = max(1, min(nb, query.num_tuples_total))
        size = query.num_tuples_total // nb
        rem = query.num_tuples_total - size * nb
        c = sum(cm.cost(size + (1 if i < rem else 0)) for i in range(nb))
        if nb > 1:
            c += cm.agg_cost(nb)
        out.append((nb, c, c / base))
    return out


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Resident-set model for the §7.2 OOM analysis.

    Streaming mode must keep the whole in-flight window RESIDENT (Spark
    holds the input relations of a stream-stream join in executor memory —
    the state cannot spill), so its peak grows with the window and OOMs.
    Batch mode runs offline operators that SPILL (sort-merge/shuffle to
    disk; host buffers in our TPU executor): its resident set is bounded by
    the executor's working budget no matter the batch size — "allowing the
    use of algorithms that do not require the entire data to be memory
    resident" (paper §1).  That asymmetry is the paper's whole memory
    argument.
    """

    bytes_per_tuple: float
    capacity_bytes: float
    partial_bytes_per_batch: float = 0.0
    working_budget_frac: float = 0.8   # batch operators spill beyond this

    def streaming_peak(self, window_tuples: int) -> float:
        return window_tuples * self.bytes_per_tuple

    def batch_peak(self, max_batch_tuples: int, num_batches: int) -> float:
        resident = min(max_batch_tuples * self.bytes_per_tuple,
                       self.working_budget_frac * self.capacity_bytes)
        return resident + num_batches * self.partial_bytes_per_batch

    def streaming_oom(self, window_tuples: int) -> bool:
        return self.streaming_peak(window_tuples) > self.capacity_bytes

    def batch_oom(self, max_batch_tuples: int, num_batches: int) -> bool:
        return self.batch_peak(max_batch_tuples, num_batches) > self.capacity_bytes


def staggered_deadlines(
    queries: Sequence[Query],
    delta: float,
    c_max: float,
    seed: int = 0,
) -> List[Query]:
    """§7.4 workload generator: deadlines staggered so overlapping queries
    leave each other room::

        deadline_1 = windEnd_1 + delta * compCost_1 + C_max
        deadline_i = windEnd_i + delta * compCost_i + C_max      if windEnd_i > deadline_{i-1}
                     deadline_{i-1} + delta * compCost_i         otherwise

    ``delta`` scales slack (the paper sweeps 1.0 down to 0.1).  The first
    query is chosen by ``seed`` (the paper picks it randomly).
    """
    import dataclasses as _dc
    import random

    qs = list(queries)
    rng = random.Random(seed)
    rng.shuffle(qs)
    out: List[Query] = []
    prev_deadline: Optional[float] = None
    for q in qs:
        c1 = q.cost_model.cost(q.num_tuples_total)
        if prev_deadline is None or q.wind_end > prev_deadline:
            d = q.wind_end + delta * c1 + c_max
        else:
            d = prev_deadline + delta * c1
        out.append(_dc.replace(q, deadline=d))
        prev_deadline = d
    return out
