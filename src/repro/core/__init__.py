"""repro.core — deadline-aware intermittent batch scheduling (Saranya &
Sudarshan, "Scheduling of Intermittent Query Processing", 2023), organized
around three first-class pieces:

* **SchedulingPolicy** — one scheme of the paper's family, behind a string
  key: ``single`` (Algorithm 1), ``single-no-agg`` / ``single-agg`` (§3.1
  components), ``constraints`` / ``brute-force`` (§3.2), ``llf-dynamic`` /
  ``edf-dynamic`` / ``sjf-dynamic`` / ``rr-dynamic`` (Algorithm 2).  Look up
  with ``get_policy(name)`` / ``list_policies()``; add your own with
  ``@register_policy("my-policy")`` — no executor changes needed.
* **Planner** — the facade: ``Planner(policy="single").plan(queries)``
  returns a ``Plan``; ``.run(workload, executor)`` executes end to end.
* **Executor** — the backend protocol (``submit_batch`` / ``finalize`` /
  ``clock``) implemented by the discrete-event simulator
  (``runtime.SimulatedExecutor``), the TPU analytics executor
  (``repro.serve.analytics``) and the model-serving engine
  (``repro.serve.engine``).  All executors share ONE runtime loop
  (``repro.core.runtime.run``) that owns deadline checking, C_max straggler
  re-queue and trace recording.  Any backend scales out via
  ``ExecutorPool`` — W workers with independent modelled clocks over one
  physical backend; ``workers=1`` is trace-identical to the bare executor.

Pure-Python/numpy and executor-agnostic; the legacy ``schedule_*`` free
functions remain as deprecation shims (see docs/API.md for the migration
table).
"""
from .api import (
    Executor,
    Planner,
    SchedulingEvent,
    SchedulingPolicy,
    get_policy,
    list_policies,
    register_policy,
)
from .arrivals import (
    ArrivalModel,
    ConstantRateArrival,
    TraceArrival,
    UniformWindowArrival,
    jittered_trace,
)
from .cost_model import (
    CostModelBase,
    LinearCostModel,
    PiecewiseLinearCostModel,
    SublinearCostModel,
    fit_piecewise_linear,
)
from .constraints import (
    brute_force_optimal,
    feasible_assignment,
    schedule_via_constraints,
)
from .minbatch import find_min_batch_size
from .multi_query import (
    LARGE_NUMBER,
    DynamicQuerySpec,
    schedule_dynamic,
)
from .runtime import (
    BaseExecutor,
    ExecutorPool,
    QueryRuntime,
    RuntimeState,
    SimulatedExecutor,
    execute_plan,
    run,
)
from .schedulability import (
    FeasibilityReport,
    check as check_schedulability,
    min_post_window_work,
    post_window_condition,
)
from .simulator import (
    MemoryModel,
    batched_cost_curve,
    micro_batch_trace,
    one_shot_trace,
    staggered_deadlines,
)
from .single_query import (
    execute_single,
    plan_cost,
    schedule_single,
    schedule_with_agg_cost,
    schedule_without_agg_cost,
    validate_schedule,
)
from .types import (
    Batch,
    BatchExecution,
    BatchShard,
    ExecutionTrace,
    InfeasibleDeadline,
    Plan,
    PolicyDecision,
    Query,
    QueryOutcome,
    Schedule,
    Strategy,
)

__all__ = [
    "ArrivalModel",
    "BaseExecutor",
    "Batch",
    "BatchExecution",
    "BatchShard",
    "ConstantRateArrival",
    "CostModelBase",
    "DynamicQuerySpec",
    "ExecutionTrace",
    "Executor",
    "ExecutorPool",
    "FeasibilityReport",
    "InfeasibleDeadline",
    "LARGE_NUMBER",
    "LinearCostModel",
    "MemoryModel",
    "PiecewiseLinearCostModel",
    "Plan",
    "Planner",
    "PolicyDecision",
    "Query",
    "QueryOutcome",
    "QueryRuntime",
    "RuntimeState",
    "Schedule",
    "SchedulingEvent",
    "SchedulingPolicy",
    "SimulatedExecutor",
    "Strategy",
    "SublinearCostModel",
    "TraceArrival",
    "UniformWindowArrival",
    "batched_cost_curve",
    "brute_force_optimal",
    "check_schedulability",
    "execute_plan",
    "execute_single",
    "feasible_assignment",
    "find_min_batch_size",
    "fit_piecewise_linear",
    "get_policy",
    "jittered_trace",
    "list_policies",
    "micro_batch_trace",
    "min_post_window_work",
    "one_shot_trace",
    "plan_cost",
    "post_window_condition",
    "register_policy",
    "run",
    "schedule_dynamic",
    "schedule_single",
    "schedule_via_constraints",
    "schedule_with_agg_cost",
    "schedule_without_agg_cost",
    "staggered_deadlines",
    "validate_schedule",
]
