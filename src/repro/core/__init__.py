"""repro.core — the paper's contribution: deadline-aware intermittent batch
scheduling (Saranya & Sudarshan, "Scheduling of Intermittent Query
Processing", 2023).

Pure-Python/numpy, executor-agnostic.  Consumed by the discrete-event
simulator (paper experiments), the TPU analytics executor
(``repro.serve.analytics``) and the model-serving engine
(``repro.serve.engine``).
"""
from .arrivals import (
    ArrivalModel,
    ConstantRateArrival,
    TraceArrival,
    UniformWindowArrival,
    jittered_trace,
)
from .cost_model import (
    CostModelBase,
    LinearCostModel,
    PiecewiseLinearCostModel,
    SublinearCostModel,
    fit_piecewise_linear,
)
from .constraints import (
    brute_force_optimal,
    feasible_assignment,
    schedule_via_constraints,
)
from .minbatch import find_min_batch_size
from .multi_query import (
    LARGE_NUMBER,
    DynamicQuerySpec,
    schedule_dynamic,
)
from .schedulability import (
    FeasibilityReport,
    check as check_schedulability,
    min_post_window_work,
    post_window_condition,
)
from .simulator import (
    MemoryModel,
    batched_cost_curve,
    micro_batch_trace,
    one_shot_trace,
    staggered_deadlines,
)
from .single_query import (
    execute_single,
    plan_cost,
    schedule_single,
    schedule_with_agg_cost,
    schedule_without_agg_cost,
    validate_schedule,
)
from .types import (
    Batch,
    BatchExecution,
    ExecutionTrace,
    InfeasibleDeadline,
    Query,
    QueryOutcome,
    Schedule,
    Strategy,
)

__all__ = [
    "ArrivalModel",
    "Batch",
    "BatchExecution",
    "ConstantRateArrival",
    "CostModelBase",
    "DynamicQuerySpec",
    "ExecutionTrace",
    "FeasibilityReport",
    "InfeasibleDeadline",
    "LARGE_NUMBER",
    "LinearCostModel",
    "MemoryModel",
    "PiecewiseLinearCostModel",
    "Query",
    "QueryOutcome",
    "Schedule",
    "Strategy",
    "SublinearCostModel",
    "TraceArrival",
    "UniformWindowArrival",
    "batched_cost_curve",
    "brute_force_optimal",
    "check_schedulability",
    "execute_single",
    "micro_batch_trace",
    "one_shot_trace",
    "staggered_deadlines",
    "feasible_assignment",
    "find_min_batch_size",
    "fit_piecewise_linear",
    "jittered_trace",
    "min_post_window_work",
    "plan_cost",
    "post_window_condition",
    "schedule_dynamic",
    "schedule_single",
    "schedule_via_constraints",
    "schedule_with_agg_cost",
    "schedule_without_agg_cost",
    "validate_schedule",
]
