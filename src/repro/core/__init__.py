"""repro.core — deadline-aware intermittent batch scheduling (Saranya &
Sudarshan, "Scheduling of Intermittent Query Processing", 2023), organized
around three first-class pieces:

* **SchedulingPolicy** — one scheme of the paper's family, behind a string
  key: ``single`` (Algorithm 1), ``single-no-agg`` / ``single-agg`` (§3.1
  components), ``constraints`` / ``brute-force`` (§3.2), ``llf-dynamic`` /
  ``edf-dynamic`` / ``sjf-dynamic`` / ``rr-dynamic`` (Algorithm 2).  Look up
  with ``get_policy(name)`` / ``list_policies()``; add your own with
  ``@register_policy("my-policy")`` — no executor changes needed.
* **Planner** — the facade: ``Planner(policy="single").plan(queries)``
  returns a ``Plan``; ``.run(workload, executor)`` executes end to end.
* **Executor** — the backend protocol (``submit_batch`` / ``finalize`` /
  ``clock``) implemented by the discrete-event simulator
  (``runtime.SimulatedExecutor``), the TPU analytics executor
  (``repro.serve.analytics``) and the model-serving engine
  (``repro.serve.engine``).  All executors share ONE runtime loop
  (``repro.core.runtime.run``) that owns deadline checking, C_max straggler
  re-queue and trace recording.  Any backend scales out via
  ``ExecutorPool`` — W workers with independent modelled clocks over one
  physical backend; ``workers=1`` is trace-identical to the bare executor.
* **Session** — the CONTINUOUS counterpart of ``Planner.run``: recurring
  windows (``RecurringQuerySpec``) roll over on one carried-over executor
  timeline, queries are admitted online (schedulability pre-flight) or
  withdrawn mid-run, and ``calibrate=True`` refits cost models from
  execution feedback (``CalibratingCostModel``), replanning future windows
  when drift crosses the threshold (docs/API.md "Sessions & recurring
  queries").
* **Pane sharing** — opt-in shared execution for overlapping windows over
  a common stream (``repro.core.panes``): windows decompose into GCD-width
  panes, partial aggregates are computed once into a reference-counted
  ``PaneStore`` and fanned out to every subscriber at merge cost, and the
  amortized ``SharedCostModel`` makes the cheaper shared cost visible to
  every policy and to ``admission_check`` (``Planner.run(share=True)``,
  ``Session(sharing=True)``, ``run_shared`` — docs/API.md "Pane sharing").
* **Overload control** — opt-in handling of the INFEASIBLE regime
  (``repro.core.overload``): strict priority tiers (``Query.tier``),
  bounded-error load shedding (minimum uniform-sample drop restoring the
  schedulability conditions, lowest tiers first; answers become scaled
  estimates with reported ``QueryOutcome.shed_fraction``/``error_bound``)
  and deadline renegotiation for ``shed=False`` queries
  (``Session(overload=..., on_renegotiate=...)`` — docs/API.md "Overload
  control").
* **Predictive scheduling** — opt-in arrival forecasting
  (``repro.core.forecast``): every closed window feeds a per-spec
  Holt-style ``ArrivalForecaster`` (level + trend, confidence bands,
  burstiness); sessions with ``forecast=`` replan at window roll-over
  against the FORECAST burst — shedding proactively before it lands, with
  a mid-window miss check that refunds premature sheds — and pre-warm the
  pane cache for forecast future windows during idle capacity.  The
  per-spec observation record is public via ``Session.history()``
  (``SpecHistory``), and Cameo-style per-query latency targets
  (``Query.latency_target``) tighten the dynamic policies' urgency order
  within tiers (docs/API.md "Predictive scheduling").

Pure-Python/numpy and executor-agnostic; the legacy ``schedule_*`` free
functions remain as deprecation shims (see docs/API.md for the migration
table).
"""
from .api import (
    Executor,
    Planner,
    SchedulingEvent,
    SchedulingPolicy,
    Session,
    get_policy,
    list_policies,
    register_policy,
)
from .arrivals import (
    ArrivalModel,
    ConstantRateArrival,
    ShiftedArrival,
    ThinnedArrival,
    TraceArrival,
    UniformWindowArrival,
    jittered_trace,
    partition_stream,
)
from .cost_model import (
    CalibratingCostModel,
    CostModelBase,
    LinearCostModel,
    PiecewiseLinearCostModel,
    SharedCostModel,
    ShardedCostModel,
    SublinearCostModel,
    fit_piecewise_linear,
)
from .forecast import (
    ArrivalForecast,
    ArrivalForecaster,
    ArrivalObservation,
    ForecastConfig,
    SpecHistory,
    forecast_query,
    observe_arrival,
    offered_arrival,
)
from .session import AdmissionResult, SessionRuntime
# Canonical homes only below: the legacy shim modules (constraints,
# single_query, multi_query) are imported LAST, purely for the deprecated
# schedule_* names — canonical symbols never route through them.
from .policies.constraint import feasible_assignment
from .minbatch import find_min_batch_size, find_min_batch_sizes
from .panes import (
    PaneStats,
    PaneStore,
    SharedBook,
    pane_width,
    run_shared,
    share_workload,
)
from .overload import (
    OverloadConfig,
    RenegotiationProposal,
    SheddingPlan,
    apply_shed,
    min_deadline_extension,
    overload_check,
    plan_shedding,
    shed_error_bound,
    tiered_work_demand_condition,
)
from .plans import plan_cost, validate_schedule
from .runtime import (
    LARGE_NUMBER,
    BaseExecutor,
    DynamicLoopCore,
    DynamicQuerySpec,
    ExecutorPool,
    HeapLoopCore,
    OracleCostExecutor,
    QueryRuntime,
    RuntimeState,
    SimulatedExecutor,
    execute_plan,
    heap_capable,
    run,
)
from .schedulability import (
    DemandLedger,
    FeasibilityReport,
    admission_check,
    check as check_schedulability,
    edf_order,
    min_post_window_work,
    post_window_condition,
    work_demand_condition,
)
from .tenancy import (
    TenancyConfig,
    TenantQuota,
    demand_by_tenant,
    fair_shares,
    tenant_quota_condition,
    tenant_summary,
    zipf_counts,
    zipf_shares,
    zipf_traffic,
)
from .simulator import (
    MemoryModel,
    batched_cost_curve,
    micro_batch_trace,
    one_shot_trace,
    staggered_deadlines,
)
from .types import (
    EPS,
    Batch,
    BatchExecution,
    BatchShard,
    ExecutionTrace,
    InfeasibleDeadline,
    Plan,
    PaneSpec,
    PolicyDecision,
    Query,
    QueryOutcome,
    QueryTable,
    RecurringQuerySpec,
    Schedule,
    SessionEvent,
    SessionTrace,
    Strategy,
    split_window_id,
    window_query_id,
)

# Legacy deprecation shims (docs/API.md migration table) — imported last so
# nothing canonical depends on these modules.
from .constraints import brute_force_optimal, schedule_via_constraints
from .multi_query import schedule_dynamic
from .single_query import (
    execute_single,
    schedule_single,
    schedule_with_agg_cost,
    schedule_without_agg_cost,
)

__all__ = [
    "AdmissionResult",
    "ArrivalForecast",
    "ArrivalForecaster",
    "ArrivalModel",
    "ArrivalObservation",
    "BaseExecutor",
    "Batch",
    "BatchExecution",
    "BatchShard",
    "CalibratingCostModel",
    "ConstantRateArrival",
    "CostModelBase",
    "DemandLedger",
    "DynamicLoopCore",
    "DynamicQuerySpec",
    "EPS",
    "ExecutionTrace",
    "Executor",
    "ExecutorPool",
    "FeasibilityReport",
    "ForecastConfig",
    "HeapLoopCore",
    "InfeasibleDeadline",
    "LARGE_NUMBER",
    "LinearCostModel",
    "MemoryModel",
    "OracleCostExecutor",
    "OverloadConfig",
    "PaneSpec",
    "PaneStats",
    "PaneStore",
    "PiecewiseLinearCostModel",
    "Plan",
    "Planner",
    "PolicyDecision",
    "Query",
    "QueryOutcome",
    "QueryRuntime",
    "QueryTable",
    "RecurringQuerySpec",
    "RenegotiationProposal",
    "RuntimeState",
    "Schedule",
    "SchedulingEvent",
    "SchedulingPolicy",
    "Session",
    "SessionEvent",
    "SessionRuntime",
    "SessionTrace",
    "SharedBook",
    "SharedCostModel",
    "ShardedCostModel",
    "SheddingPlan",
    "SimulatedExecutor",
    "SpecHistory",
    "Strategy",
    "TenancyConfig",
    "TenantQuota",
    "ThinnedArrival",
    "SublinearCostModel",
    "TraceArrival",
    "UniformWindowArrival",
    "ShiftedArrival",
    "admission_check",
    "apply_shed",
    "batched_cost_curve",
    "brute_force_optimal",
    "check_schedulability",
    "demand_by_tenant",
    "edf_order",
    "fair_shares",
    "execute_plan",
    "execute_single",
    "feasible_assignment",
    "find_min_batch_size",
    "find_min_batch_sizes",
    "fit_piecewise_linear",
    "forecast_query",
    "get_policy",
    "heap_capable",
    "jittered_trace",
    "list_policies",
    "micro_batch_trace",
    "min_deadline_extension",
    "min_post_window_work",
    "observe_arrival",
    "offered_arrival",
    "one_shot_trace",
    "overload_check",
    "pane_width",
    "partition_stream",
    "plan_cost",
    "plan_shedding",
    "post_window_condition",
    "register_policy",
    "run",
    "run_shared",
    "share_workload",
    "schedule_dynamic",
    "shed_error_bound",
    "schedule_single",
    "schedule_via_constraints",
    "schedule_with_agg_cost",
    "schedule_without_agg_cost",
    "split_window_id",
    "staggered_deadlines",
    "tenant_quota_condition",
    "tenant_summary",
    "tiered_work_demand_condition",
    "validate_schedule",
    "work_demand_condition",
    "window_query_id",
    "zipf_counts",
    "zipf_shares",
    "zipf_traffic",
]
