"""Input-stream arrival models (paper §2.1: "the input data rate can be
modeled"; §4.4 variable-rate handling).

The planners need two primitives:

* ``input_time(k)``        — InputTime(s, k): time at which the k-th tuple of
                             the window has arrived (k in 1..N; k=0 -> wind_start).
* ``tuples_available(t)``  — number of window tuples that have arrived by t.

Both are exact inverses for the deterministic models.  ``JitteredArrival``
wraps a base model with seeded noise to model the *actual* arrival process
diverging from the *predicted* one (§3.1 last paragraphs, §4.4) — planners
always see the base model, executors see the jittered truth.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Tuple

# All arrival-boundary comparisons share the module-level tolerance from
# repro.core.types: a tuple arriving exactly at instant t counts as available
# AT t for every model (see the EPS docstring there).  Historically each model
# carried its own magic epsilon (1e-9 count-scale here, 1e-12 time-scale in
# TraceArrival, another 1e-9 in runtime.py).
from .types import EPS


class ArrivalModel:
    wind_start: float
    wind_end: float
    num_tuples_total: int

    def input_time(self, num_tuples: int) -> float:
        raise NotImplementedError

    def tuples_available(self, t: float) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantRateArrival(ArrivalModel):
    """rate tuples per unit time, uniformly over [wind_start, wind_end].

    Matches the paper's worked example (§3.1): window [1, 10], 1 tuple/s ->
    tuple k available at time wind_start + k/rate ... with their convention
    tuple k arrives at time k (wind_start=1 means arrivals at 1+? they use
    "available from time 6" for 6 tuples) — i.e. the k-th tuple lands at
    ``wind_start + (k - 1)/rate``?  Their numbers: 10 tuples, window [1,10],
    rate 1/s, "8 tuples available by time 8", "6 tuples available from 6":
    tuple k arrives at time k = wind_start + (k-1)/rate.  We therefore use
    ``input_time(k) = wind_start + (k - 1) / rate`` and require
    ``input_time(N) == wind_end``.
    """

    wind_start: float
    rate: float
    num_tuples_total: int

    @property
    def wind_end(self) -> float:  # type: ignore[override]
        return self.input_time(self.num_tuples_total)

    def input_time(self, num_tuples: int) -> float:
        if num_tuples <= 0:
            return self.wind_start
        return self.wind_start + (num_tuples - 1) / self.rate

    def tuples_available(self, t: float) -> int:
        if t < self.wind_start:
            return 0
        k = int((t - self.wind_start) * self.rate + EPS) + 1
        return min(k, self.num_tuples_total)


@dataclasses.dataclass(frozen=True)
class UniformWindowArrival(ArrivalModel):
    """N tuples spread uniformly over an explicitly given [wind_start, wind_end].

    The k-th tuple arrives at ``wind_start + (k-1)/(N-1) * (wind_end-wind_start)``
    (first at window start, last exactly at window end).  This is the default
    for synthetic experiments where the window is given, not the rate.
    """

    wind_start: float
    wind_end: float
    num_tuples_total: int

    def input_time(self, num_tuples: int) -> float:
        n = self.num_tuples_total
        if num_tuples <= 0 or n <= 1:
            return self.wind_start if num_tuples <= 0 else self.wind_end
        k = min(num_tuples, n)
        return self.wind_start + (k - 1) / (n - 1) * (self.wind_end - self.wind_start)

    def tuples_available(self, t: float) -> int:
        n = self.num_tuples_total
        if t < self.wind_start:
            return 0
        if t >= self.wind_end:
            return n
        if n <= 1:
            return n
        frac = (t - self.wind_start) / (self.wind_end - self.wind_start)
        return min(n, int(frac * (n - 1) + EPS) + 1)


@dataclasses.dataclass(frozen=True)
class TraceArrival(ArrivalModel):
    """Arrivals given by an explicit sorted timestamp list (one per tuple).

    Used as the *ground truth* in dynamic/jittered scenarios and by the data
    pipeline (each generated record carries a timestamp, §7.1).
    """

    timestamps: Tuple[float, ...]

    def __post_init__(self) -> None:
        ts = list(self.timestamps)
        if ts != sorted(ts):
            raise ValueError("timestamps must be sorted")
        if not ts:
            raise ValueError("empty trace")

    @property
    def wind_start(self) -> float:  # type: ignore[override]
        return self.timestamps[0]

    @property
    def wind_end(self) -> float:  # type: ignore[override]
        return self.timestamps[-1]

    @property
    def num_tuples_total(self) -> int:  # type: ignore[override]
        return len(self.timestamps)

    def input_time(self, num_tuples: int) -> float:
        if num_tuples <= 0:
            return self.wind_start
        return self.timestamps[min(num_tuples, len(self.timestamps)) - 1]

    def tuples_available(self, t: float) -> int:
        return bisect.bisect_right(self.timestamps, t + EPS)


@dataclasses.dataclass(frozen=True)
class ShiftedArrival(ArrivalModel):
    """``base`` translated ``shift`` time units later: window ``w`` of a
    ``RecurringQuerySpec`` is the base window shifted by ``w * period``.

    Pure time translation — exactly preserves the base model's inverse
    relationship between ``input_time`` and ``tuples_available``.
    """

    base: ArrivalModel
    shift: float

    @property
    def wind_start(self) -> float:  # type: ignore[override]
        return self.base.wind_start + self.shift

    @property
    def wind_end(self) -> float:  # type: ignore[override]
        return self.base.wind_end + self.shift

    @property
    def num_tuples_total(self) -> int:  # type: ignore[override]
        return self.base.num_tuples_total

    def input_time(self, num_tuples: int) -> float:
        return self.base.input_time(num_tuples) + self.shift

    def tuples_available(self, t: float) -> int:
        return self.base.tuples_available(t - self.shift)


@dataclasses.dataclass(frozen=True)
class ThinnedArrival(ArrivalModel):
    """``base`` uniformly thinned past a prefix: load shedding's arrival view
    (``repro.core.overload``).

    The first ``prefix`` base tuples pass through 1:1 (work already processed
    before the shed was applied); of the remaining ``tail = base.N - prefix``
    base tuples only ``keep`` survive, sampled SYSTEMATICALLY — kept tail
    tuple ``j`` (1-based) is base tuple ``prefix + ceil((j*tail - r) / keep)``
    where ``r`` is the sampling phase, so the sample is uniform over the tail
    and the LAST base tuple is always kept (the thinned window ends exactly
    where the base window does).  ``input_time``/``tuples_available`` stay
    exact inverses of each other, which every planner and the runtime's
    readiness logic rely on.

    ``seed`` picks the phase ``r`` (systematic sampling with a seeded random
    start, ``r in [0, keep)``) so repeated runs draw the SAME sample —
    benchmarks thread one explicit seed through every shed they apply.
    ``seed=None`` (the default) fixes ``r = 0``, which is bit-for-bit the
    historical phase-free sampling.

    ``base_index(k)`` exposes the kept->base tuple mapping (1-based both
    sides); real backends use it to fetch the sampled records and scale the
    aggregates by ``tail / keep`` (``repro.serve.analytics`` sampled scans).
    """

    base: ArrivalModel
    keep: int
    prefix: int = 0
    seed: Optional[int] = None

    def __post_init__(self) -> None:
        if self.prefix < 0:
            raise ValueError(f"prefix must be >= 0, got {self.prefix}")
        tail = self.base.num_tuples_total - self.prefix
        if tail < 0:
            raise ValueError(
                f"prefix {self.prefix} exceeds base total "
                f"{self.base.num_tuples_total}"
            )
        if not 0 <= self.keep <= tail:
            raise ValueError(f"keep must be in [0, {tail}], got {self.keep}")
        phase = 0
        if self.seed is not None and self.keep > 1:
            import random

            # Any r < keep keeps the last base tuple (window end anchored)
            # and the first kept index >= 1; see ``base_index``.
            phase = random.Random(self.seed).randrange(self.keep)
        object.__setattr__(self, "_phase", phase)

    @property
    def phase(self) -> int:
        """Systematic-sampling start offset ``r`` (0 without a seed)."""
        return self._phase

    @property
    def tail(self) -> int:
        """Base tuples subject to thinning (everything past the prefix)."""
        return self.base.num_tuples_total - self.prefix

    @property
    def wind_start(self) -> float:  # type: ignore[override]
        return self.base.wind_start

    @property
    def wind_end(self) -> float:  # type: ignore[override]
        return self.input_time(self.num_tuples_total)

    @property
    def num_tuples_total(self) -> int:  # type: ignore[override]
        return self.prefix + self.keep

    def base_index(self, num_tuples: int) -> int:
        """Base-stream index (1-based) of the ``num_tuples``-th kept tuple."""
        if num_tuples <= self.prefix or self.keep == 0:
            return min(num_tuples, self.prefix)
        j = min(num_tuples - self.prefix, self.keep)
        # ceil((j*tail - r) / keep); r < keep so j=keep still maps to tail.
        return self.prefix + -(-(j * self.tail - self._phase) // self.keep)

    def input_time(self, num_tuples: int) -> float:
        if num_tuples <= 0:
            return self.base.input_time(0)
        return self.base.input_time(self.base_index(num_tuples))

    def tuples_available(self, t: float) -> int:
        a = self.base.tuples_available(t)
        if a <= self.prefix:
            return a
        if self.keep == 0:
            return self.prefix
        # Exact inverse of ``base_index``: kept tail tuple j has arrived iff
        # ceil((j*tail - r)/keep) <= a - prefix, i.e.
        # j <= ((a-prefix)*keep + r)/tail.
        return self.prefix + min(
            ((a - self.prefix) * self.keep + self._phase) // self.tail,
            self.keep)


def partition_stream(
    base: ArrivalModel,
    counts: List[int],
    seed: Optional[int] = None,
) -> List[ThinnedArrival]:
    """Split one shared stream across principals: partition ``i`` sees a
    systematic uniform subsample of ``base`` with ``counts[i]`` tuples.

    The multi-tenant traffic generator (``repro.core.tenancy.zipf_counts``
    supplies Zipf-skewed ``counts``) models many tenants filtering the
    SAME eventstream: each tenant's query reads its own thinned view, all
    views anchored to the base window (a ``ThinnedArrival`` always keeps
    the last base tuple, so every partition closes with the stream).
    Partitions are views, not a disjoint cover — two tenants may keep the
    same base tuple, exactly like two filters matching the same record.
    ``seed`` decorrelates the sampling phases (partition ``i`` draws phase
    ``seed + i``); ``None`` keeps every phase 0.
    """
    total = base.num_tuples_total
    out: List[ThinnedArrival] = []
    for i, keep in enumerate(counts):
        if not 0 <= keep <= total:
            raise ValueError(
                f"counts[{i}] = {keep} outside [0, {total}]")
        out.append(ThinnedArrival(
            base=base, keep=keep,
            seed=None if seed is None else seed + i))
    return out


def jittered_trace(
    base: ArrivalModel,
    seed: int,
    jitter_frac: float = 0.1,
    rate_scale: float = 1.0,
) -> TraceArrival:
    """Build a ground-truth trace = predicted model + seeded jitter (§4.4).

    ``rate_scale`` > 1 means the true stream is faster than predicted (arrives
    earlier), < 1 slower.  Per-tuple jitter is uniform in
    ±jitter_frac * inter-arrival.  Monotonicity is restored by sorting.
    """
    import random

    rng = random.Random(seed)
    n = base.num_tuples_total
    ts: List[float] = []
    for k in range(1, n + 1):
        t = base.input_time(k)
        span = (t - base.wind_start) / max(rate_scale, 1e-9)
        t = base.wind_start + span
        if k < n:  # keep the window-end anchor exact for the last tuple
            gap = (base.wind_end - base.wind_start) / max(n - 1, 1)
            t += rng.uniform(-jitter_frac, jitter_frac) * gap
        ts.append(max(t, base.wind_start))
    ts.sort()
    return TraceArrival(timestamps=tuple(ts))
