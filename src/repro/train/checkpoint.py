"""Sharded checkpointing with integrity manifest (fault tolerance, deliv. 2).

Layout: <dir>/step_<N>/
    manifest.json        — step, param paths, shapes, dtypes, checksums
    <escaped-path>.npy   — one file per leaf (gathered to host)

Restore validates shapes/dtypes against the requesting model's specs and
verifies checksums, so a half-written checkpoint (killed node) is detected
and the previous step is used instead (``latest_valid``).  Writes go to a
temp dir + atomic rename, so a crash mid-save never corrupts older steps.

On a real pod each host writes only its local shards (jax.experimental
array_serialization); here (single host) leaves are gathered — the format
and the restart logic are what the fault-tolerance tests exercise.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import shutil
import tempfile
from typing import Dict, Optional, Tuple

import jax
import numpy as np


def _esc(path: str) -> str:
    return path.replace("/", "__")


def save_checkpoint(directory: str | os.PathLike, step: int,
                    state_tree: Dict[str, jax.Array],
                    extra: Optional[Dict] = None) -> pathlib.Path:
    base = pathlib.Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = pathlib.Path(tempfile.mkdtemp(dir=base, prefix=".tmp_ckpt_"))
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    try:
        for key, arr in state_tree.items():
            host = np.asarray(jax.device_get(arr))
            fn = tmp / f"{_esc(key)}.npy"
            np.save(fn, host)
            manifest["leaves"][key] = {
                "shape": list(host.shape),
                "dtype": str(host.dtype),
                "sha256": hashlib.sha256(host.tobytes()).hexdigest()[:16],
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _validate(ckpt: pathlib.Path) -> bool:
    mf = ckpt / "manifest.json"
    if not mf.exists():
        return False
    manifest = json.loads(mf.read_text())
    for key, meta in manifest["leaves"].items():
        fn = ckpt / f"{_esc(key)}.npy"
        if not fn.exists():
            return False
        try:
            arr = np.load(fn)
        except Exception:  # truncated/garbled file from a dying writer
            return False
        if list(arr.shape) != meta["shape"] or str(arr.dtype) != meta["dtype"]:
            return False
        if hashlib.sha256(arr.tobytes()).hexdigest()[:16] != meta["sha256"]:
            return False
    return True


def latest_valid(directory: str | os.PathLike) -> Optional[pathlib.Path]:
    base = pathlib.Path(directory)
    if not base.exists():
        return None
    for ckpt in sorted(base.glob("step_*"), reverse=True):
        if _validate(ckpt):
            return ckpt
    return None


def restore_checkpoint(ckpt: pathlib.Path,
                       shardings: Optional[Dict] = None
                       ) -> Tuple[int, Dict[str, jax.Array], Dict]:
    manifest = json.loads((ckpt / "manifest.json").read_text())
    tree: Dict[str, jax.Array] = {}
    for key in manifest["leaves"]:
        host = np.load(ckpt / f"{_esc(key)}.npy")
        if shardings and key in shardings:
            tree[key] = jax.device_put(host, shardings[key])
        else:
            tree[key] = jax.device_put(host)
    return manifest["step"], tree, manifest.get("extra", {})
