"""AdamW with mixed precision and global-norm clipping (no external deps).

TrainState layout (all flat dicts, matching the param-spec paths):
  params : f32 master weights (sharded like the bf16 param specs)
  m, v   : f32 Adam moments (sharded identically)
  step   : i32 scalar

The loss casts masters to bf16 on entry (``cast_params``), so the HLO carries
the production mixed-precision data flow: bf16 compute, f32 state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


Params = Dict[str, jax.Array]


class TrainState(NamedTuple):
    params: Params   # f32 masters
    m: Params
    v: Params
    step: jax.Array  # i32 scalar


def init_state(params_bf16: Params) -> TrainState:
    f32 = {k: v.astype(jnp.float32) for k, v in params_bf16.items()}
    zeros = {k: jnp.zeros_like(v) for k, v in f32.items()}
    return TrainState(params=f32, m=zeros,
                      v={k: jnp.zeros_like(v) for k, v in f32.items()},
                      step=jnp.zeros((), jnp.int32))


def state_shape_structs(param_structs: Dict[str, jax.ShapeDtypeStruct]) -> TrainState:
    f32 = {k: jax.ShapeDtypeStruct(s.shape, jnp.float32)
           for k, s in param_structs.items()}
    return TrainState(params=f32, m=dict(f32), v=dict(f32),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def cast_params(params: Params, dtype=jnp.bfloat16) -> Params:
    return {k: v.astype(dtype) for k, v in params.items()}


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def apply_updates(state: TrainState, grads: Params,
                  cfg: AdamWConfig) -> Tuple[TrainState, Dict[str, jax.Array]]:
    g32 = {k: g.astype(jnp.float32) for k, g in grads.items()}
    # NB: sum-of-squares per leaf, NOT vdot: vdot flattens, and flattening a
    # 2D-sharded tensor makes XLA all-gather the full gradient (multi-GiB
    # replicated buffers).  jnp.sum reduces in-place across shards.
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in g32.values()))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    new_p, new_m, new_v = {}, {}, {}
    for k, p in state.params.items():
        g = g32[k] * scale
        m = cfg.b1 * state.m[k] + (1 - cfg.b1) * g
        v = cfg.b2 * state.v[k] + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (not norms/biases/gains)
            upd = upd + cfg.weight_decay * p
        new_p[k] = p - lr * upd
        new_m[k] = m
        new_v[k] = v
    metrics = {"grad_norm": gnorm, "lr": lr}
    return TrainState(new_p, new_m, new_v, step), metrics
