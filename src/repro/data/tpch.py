"""Synthetic TPC-H-like record streams (paper §7.1) + the paper's query set.

The paper streams Orders and Lineitem files (1 file of each per second,
4500 s, 25 GB total) with a timestamp column added, against static
Customer/Part/... relations.  Here the streams are seeded numpy structured
batches with the same logical schema, scaled by ``scale`` so tests run in
milliseconds and benchmarks in seconds.

Queries (Table 3 + the TPC-H subset used in §7): each is (filter +)
(join +) GROUP-BY aggregate, expressed against columnar record batches and
executed by ``repro.serve.analytics`` with the segagg kernel.  Group counts
follow the paper (CQ2 ~5 groups, CQ3 ~360K, CQ4 ~1.5M at full scale).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

FULL_SCALE_SUPPKEYS = 360_000
FULL_SCALE_PARTKEYS = 1_500_000
ORDER_PRIORITIES = 5
ORDERS_PER_FILE = 3_300          # ~1.2 MB of orders per file in the paper
LINEITEMS_PER_FILE = 13_000      # ~5 MB of lineitem per file


@dataclasses.dataclass(frozen=True)
class StreamScale:
    """scale=1.0 reproduces the paper's cardinalities; tests use ~1e-3."""

    scale: float = 1.0

    @property
    def orders_per_file(self) -> int:
        return max(int(ORDERS_PER_FILE * self.scale), 8)

    @property
    def lineitems_per_file(self) -> int:
        return max(int(LINEITEMS_PER_FILE * self.scale), 16)

    @property
    def num_suppkeys(self) -> int:
        return max(int(FULL_SCALE_SUPPKEYS * self.scale), 16)

    @property
    def num_partkeys(self) -> int:
        return max(int(FULL_SCALE_PARTKEYS * self.scale), 32)


def orders_batch(rng: np.random.Generator, n: int, t0: float, t1: float,
                 sc: StreamScale) -> Dict[str, np.ndarray]:
    ts = np.sort(rng.uniform(t0, t1, n))
    return {
        "order_id": rng.integers(0, 1 << 31, n, dtype=np.int64),
        "cust_id": rng.integers(0, max(int(1000 * sc.scale), 10), n),
        "order_priority": rng.integers(0, ORDER_PRIORITIES, n),
        "total_price": rng.gamma(2.0, 150.0, n).astype(np.float32),
        "ts": ts,
    }


def lineitem_batch(rng: np.random.Generator, n: int, t0: float, t1: float,
                   sc: StreamScale) -> Dict[str, np.ndarray]:
    ts = np.sort(rng.uniform(t0, t1, n))
    return {
        "order_id": rng.integers(0, 1 << 31, n, dtype=np.int64),
        "supp_key": rng.integers(0, sc.num_suppkeys, n),
        "part_key": rng.integers(0, sc.num_partkeys, n),
        "quantity": rng.integers(1, 50, n).astype(np.float32),
        "price": rng.gamma(2.0, 30.0, n).astype(np.float32),
        "ts": ts,
    }


def stream_files(seed: int, num_files: int, sc: StreamScale,
                 files_per_second: float = 1.0
                 ) -> Iterator[Tuple[float, Dict[str, np.ndarray], Dict[str, np.ndarray]]]:
    """Yield (arrival_time, orders_file, lineitem_file) like §7.1's
    1 orders-file + 1 lineitem-file per second."""
    rng = np.random.default_rng(seed)
    for i in range(num_files):
        t0, t1 = i / files_per_second, (i + 1) / files_per_second
        yield (t1, orders_batch(rng, sc.orders_per_file, t0, t1, sc),
               lineitem_batch(rng, sc.lineitems_per_file, t0, t1, sc))


# ---------------------------------------------------------------------------
# Queries (paper Table 3 + TPC-H subset)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AnalyticsQuery:
    """GROUP-BY aggregate over one of the streams.

    key_fn(batch) -> int group ids; value_fn(batch) -> (N, V) values.
    ``num_groups`` bounds the group-id domain (drives MinBatch sizing and
    the final-aggregation cost, §4.1/§6.2)."""

    query_id: str
    stream: str                    # "orders" | "lineitem"
    num_groups_fn: Callable[[StreamScale], int]
    key_fn: Callable[[Dict[str, np.ndarray]], np.ndarray]
    value_fn: Callable[[Dict[str, np.ndarray]], np.ndarray]
    description: str = ""

    def num_groups(self, sc: StreamScale) -> int:
        return self.num_groups_fn(sc)


def _ones(b: Dict[str, np.ndarray]) -> np.ndarray:
    n = len(next(iter(b.values())))
    return np.ones((n, 1), np.float32)


PAPER_QUERIES: List[AnalyticsQuery] = [
    AnalyticsQuery(
        "CQ1", "orders", lambda sc: 1,
        key_fn=lambda b: np.zeros(len(b["order_id"]), np.int64),
        value_fn=_ones,
        description="SELECT count(*) FROM orders",
    ),
    AnalyticsQuery(
        "CQ2", "orders", lambda sc: ORDER_PRIORITIES,
        key_fn=lambda b: b["order_priority"],
        value_fn=_ones,
        description="count(*) GROUP BY orderPriority (~5 groups)",
    ),
    AnalyticsQuery(
        "CQ3", "lineitem", lambda sc: sc.num_suppkeys,
        key_fn=lambda b: b["supp_key"],
        value_fn=_ones,
        description="count(*) GROUP BY suppKey (~360K groups full scale)",
    ),
    AnalyticsQuery(
        "CQ4", "lineitem", lambda sc: sc.num_partkeys,
        key_fn=lambda b: b["part_key"],
        value_fn=_ones,
        description="count(*) GROUP BY partKey (~1.5M groups full scale)",
    ),
    AnalyticsQuery(
        "TPC-Q6-like", "lineitem", lambda sc: 1,
        key_fn=lambda b: np.zeros(len(b["price"]), np.int64),
        value_fn=lambda b: (b["price"] * b["quantity"]
                            * (b["quantity"] < 24)).astype(np.float32)[:, None],
        description="filtered revenue sum (Q6 shape)",
    ),
    AnalyticsQuery(
        "TPC-Q4-like", "lineitem", lambda sc: ORDER_PRIORITIES,
        key_fn=lambda b: b["order_id"] % ORDER_PRIORITIES,
        value_fn=_ones,
        description="orders x lineitem same-batch join, count by priority "
                    "(§6.1 same-batch join assumption)",
    ),
]


# ---------------------------------------------------------------------------
# Paper-shaped cost models (§6.2, Fig 3): per-file piecewise-linear costs.
# Units: seconds of executor time per FILE (the paper's batch unit), fitted
# to reproduce the relationships reported in §7.2 (e.g. Q10's 60-batch cost
# ~6x its single-batch cost; CQ2 2.7x CQ1 at 60 batches via agg cost).
# ---------------------------------------------------------------------------

def paper_cost_model(query_id: str, regime: str = "fig4"):
    """Linear Eq.-(1) models fitted to the paper's reported relationships:

    * Table 2 file-based single-batch costs: CQ1 17.9s, CQ2 18.9s, CQ3 32s,
      CQ4 32.5s;
    * Fig 4: cost grows with #batches; TPC-Q10 at 60 batches ~6x its
      single-batch cost (highest of the set);
    * §7.2: final-aggregation cost ordering CQ4 > CQ3 >> CQ2 > CQ1
      (group counts 1.5M / 360K / 5 / 1), with CQ3's per-tuple cost higher
      than CQ4's.
    Units: seconds; "tuples" are FILES (the paper's batching unit).

    The final-aggregation model is PIECEWISE linear in the number of batches
    (§6.2: "we fit a piece-wise linear model to estimate the final
    aggregation cost"): shallow below ~5 batches — which is what lets the
    paper's 0.1D single-query cases still schedule 2-3 batches (Fig 6) —
    and steeper toward the 60-batch regime that drives Fig 4's blow-up.
    """
    from ..core import PiecewiseLinearCostModel

    # (per_file_s, per_batch_overhead_s, agg_cost_at_60_batches_s)
    # Derivation from the paper's reported facts:
    #   * Table 2 file-based single-batch costs (CQ1 17.9 .. CQ4 32.5s);
    #   * Fig 4: CQ1 at 60 batches ~2.7x its baseline; TPC-Q10 ~6x;
    #   * §7.2: agg costs at 60 batches ~0.6/1.6/3/7s for CQ1..CQ4 (the
    #     only reading under which "CQ4 only slightly above CQ3 overall"
    #     and the CQ2-vs-CQ1 ratio are simultaneously true);
    #   * Fig 3: the join queries Q3/Q9/Q10 are disproportionately costly
    #     at SMALL batch sizes => high per-batch intercept, which is also
    #     exactly what makes them need 3 batches at the 0.1D deadline
    #     (Fig 6) while every other query needs 2.
    consts = {
        "CQ1": (0.0038, 0.5, 0.6), "CQ2": (0.0040, 0.5, 1.6),
        "CQ3": (0.0070, 0.5, 3.0), "CQ4": (0.0066, 0.5, 7.0),
        "TPC-Q1": (0.0080, 0.6, 1.5), "TPC-Q3": (0.0110, 4.0, 3.0),
        "TPC-Q4": (0.0090, 0.7, 1.2), "TPC-Q6": (0.0040, 0.4, 0.5),
        "TPC-Q9": (0.0120, 4.5, 3.0), "TPC-Q10": (0.0080, 2.7, 3.0),
        "TPC-Q12": (0.0090, 0.7, 1.2), "TPC-Q14": (0.0060, 0.5, 1.0),
        "TPC-Q19": (0.0080, 0.7, 1.5),
        "TPC-Q6-like": (0.0040, 0.4, 0.5), "TPC-Q4-like": (0.0090, 0.7, 1.2),
    }
    per_file, overhead, agg60 = consts.get(query_id, (0.008, 0.7, 1.2))
    join_heavy = query_id in ("TPC-Q3", "TPC-Q9", "TPC-Q10")
    if regime == "spark":
        # Multi-query-experiment regime (§7.4): the paper's own feasibility
        # analysis there (sum of last-batch costs ~105s vs largest deadline
        # windEnd+94) implies per-batch overheads of ~8.5% of the single-
        # batch cost for EVERY query — much larger than the Fig-4-implied
        # overheads.  The two regimes cannot be reconciled by one constant
        # set (see EXPERIMENTS.md "calibration notes"); benchmarks report
        # both.
        overhead = max(overhead, 0.085 * (NUM_FILES * per_file) / (1 - 0.085))
    n = NUM_FILES
    cost_points = ((1.0, overhead + per_file),
                   (float(n), overhead + per_file * n),
                   (float(4 * n), overhead + per_file * 4 * n))
    if join_heavy:
        # startup-dominated final agg (reads many partial files of a join)
        agg_points = ((1.0, 0.0), (2.0, 1.0), (3.0, 1.1), (5.0, 1.3),
                      (60.0, agg60))
    else:
        agg_points = ((1.0, 0.0), (2.0, 0.2), (5.0, 0.2 + agg60 * 0.06),
                      (60.0, agg60))
    return PiecewiseLinearCostModel(points=cost_points, agg_points=agg_points)


PAPER_QUERY_IDS = ["CQ1", "CQ2", "CQ3", "CQ4", "TPC-Q1", "TPC-Q3", "TPC-Q4",
                   "TPC-Q6", "TPC-Q9", "TPC-Q10", "TPC-Q12", "TPC-Q14",
                   "TPC-Q19"]
NUM_FILES = 4500  # §7.1: 4500 files at 1 file/s
