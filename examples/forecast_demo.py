"""Predictive-scheduling walkthrough: learn the burst, shed before it lands.

A recurring query is PREDICTED to deliver its tuples uniformly over each
window, but the TRUE stream dumps everything in the last fifth — a tail
burst the paper's schedulers never see coming because admission checks
consult predicted arrival curves.  Two sessions at equal capacity:

  1. reactive  — plain overload control (PR 5 behavior): the burst is
     invisible until it lands, and every window finishes ~50 time units
     past its deadline;
  2. forecast  — ``Session(forecast=True)``: each closed window feeds an
     ``ArrivalForecaster`` (level + trend + burstiness with confidence
     bands), window roll-over replans against the FORECAST burst, and the
     session sheds proactively — answers degrade into bounded-error
     estimates, but they arrive ON TIME.

Also shown: the public per-spec observation record (``Session.history()``)
and a Cameo-style per-query latency target ordering two equal-deadline
queries.

    PYTHONPATH=src python examples/forecast_demo.py
"""
from repro.core import (
    LinearCostModel,
    Planner,
    Query,
    RecurringQuerySpec,
    Session,
    UniformWindowArrival,
)

SPAN = 100.0
N = 100
WINDOWS = 8
COST = LinearCostModel(tuple_cost=1.0)


def bursty_recurring() -> RecurringQuerySpec:
    base = Query(
        query_id="clicks", wind_start=0.0, wind_end=SPAN,
        deadline=SPAN + 30.0, num_tuples_total=N, cost_model=COST,
        arrival=UniformWindowArrival(wind_start=0.0, wind_end=SPAN,
                                     num_tuples_total=N),
    )

    def truth(w):  # all N tuples in the last 20 time units of window w
        end = (w + 1) * SPAN
        return UniformWindowArrival(wind_start=end - 20.0, wind_end=end,
                                    num_tuples_total=N)

    return RecurringQuerySpec(base=base, period=SPAN, num_windows=WINDOWS,
                              truth_factory=truth)


def run(forecast: bool):
    session = Session(policy="llf-dynamic", overload=True, forecast=forecast)
    session.submit(bursty_recurring())
    session.run()
    return session


def main() -> None:
    # 1. the reactive session: predicted-feasible, truly-bursty -> late
    reactive = run(forecast=False)
    print("reactive (PR 5) session on the bursty stream:")
    for o in reactive.trace.outcome_series("clicks"):
        print(f"  {o.query_id}: finish={o.completion_time:7.2f} "
              f"deadline={o.deadline:6.1f} met={o.met_deadline} "
              f"shed={o.shed_fraction:.2f}")

    # 2. the forecast session: same capacity, sheds BEFORE the burst
    fc = run(forecast=True)
    print("\nforecast session (Session(forecast=True)):")
    for o in fc.trace.outcome_series("clicks"):
        print(f"  {o.query_id}: finish={o.completion_time:7.2f} "
              f"deadline={o.deadline:6.1f} met={o.met_deadline} "
              f"shed={o.shed_fraction:.2f} +-{o.error_bound:.2f}")
    for e in fc.trace.events_for("forecast_shed"):
        print(f"  proactive shed at t={e.time:6.1f} {e.query_id} ({e.detail})")

    # 3. what the session learned: the public observation record
    hist = fc.history("clicks")
    fcr = fc.forecaster("clicks")
    print(f"\nhistory('clicks'): {hist.num_windows_observed} windows, "
          f"burstiness {hist.arrivals[-1].burstiness:.1f}, "
          f"forecaster hits={fcr.hits} misses={fcr.misses}")

    # 4. Cameo-style latency targets: same deadline, different urgency
    mk = lambda qid, lt: Query(
        query_id=qid, wind_start=0.0, wind_end=0.0, deadline=100.0,
        num_tuples_total=10, cost_model=COST,
        arrival=UniformWindowArrival(wind_start=0.0, wind_end=0.0,
                                     num_tuples_total=10),
        latency_target=lt)
    trace = Planner(policy="edf-dynamic").run([mk("loose", None),
                                               mk("tight", 5.0)])
    first = next(e for e in trace.executions if e.kind == "batch")
    outs = {o.query_id: o for o in trace.outcomes}
    print(f"\nlatency targets: {first.query_id!r} ran first; "
          f"tight: met_deadline={outs['tight'].met_deadline} "
          f"met_target={outs['tight'].met_target} "
          f"(target_time={outs['tight'].target_time})")


if __name__ == "__main__":
    main()
