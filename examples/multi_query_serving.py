"""Dynamic multi-job deadline serving with REAL model execution:

three concurrent batch-inference jobs (prompt windows with deadlines) are
time-shared by the paper's Algorithm 2 (the registered ``llf-dynamic``
policy) on one reduced-config model; every scheduled MinBatch runs actual
prefill compute on CPU through the shared runtime loop.

    PYTHONPATH=src python examples/multi_query_serving.py
"""
import dataclasses

import jax
import numpy as np

from repro.core import Strategy, UniformWindowArrival
from repro.models.base import get_config
from repro.models.lm import build_specs
from repro.models.params import init_params, num_params
from repro.serve.engine import PrefillExecutor, WindowJob, serve_multi_jobs

SEQ = 64

cfg = get_config("yi_6b").reduced()
cfg = dataclasses.replace(cfg, vocab_size=1024)
params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
print(f"model: reduced {cfg.name} ({num_params(build_specs(cfg))/1e6:.2f}M params)")

executor = PrefillExecutor(cfg, params, buckets=(1, 2, 4, 8, 16))
cost_model = executor.calibrate(SEQ, cfg.vocab_size)
print(f"calibrated: prefill(1)={cost_model.cost(1)*1e3:.1f} ms, "
      f"prefill(16)={cost_model.cost(16)*1e3:.1f} ms")

rng = np.random.default_rng(0)
jobs = []
for i, (n, window, slack) in enumerate([(24, 30.0, 3.0), (16, 20.0, 2.0),
                                        (32, 40.0, 2.5)]):
    arr = UniformWindowArrival(wind_start=0.0, wind_end=window,
                               num_tuples_total=n)
    jobs.append(WindowJob(
        job_id=f"job{i}",
        prompts=rng.integers(0, cfg.vocab_size, (n, SEQ)).astype(np.int32),
        arrival=arr,
        deadline=window + slack * cost_model.cost(n),
    ))

report = serve_multi_jobs(jobs, executor, cost_model, Strategy.LLF,
                          delta_rsf=0.5, c_max=5.0)
for jid, r in report.items():
    print(f"{jid}: processed {r['processed']} prompts in {r['num_batches']} "
          f"batches; modelled finish {r['completion']:.2f}s vs deadline "
          f"{r['deadline']:.2f}s -> met={r['met_modelled']}; real exec "
          f"{r['wall_exec_seconds']*1e3:.0f} ms")
assert all(r["met_modelled"] for r in report.values())
assert all(report[j.job_id]["processed"] == j.num_requests for j in jobs)
print("all jobs met their deadlines with batched execution.")
