"""Single-query intermittent analytics, end to end with REAL JAX execution:

  1. generate a TPC-H-like record stream (reduced scale),
  2. calibrate the cost model from measured batch runs (paper Section 6.2),
  3. plan batches with the "single" policy (Algorithm 1) against a deadline,
  4. execute the plan on-device (segagg partial aggregation, host spill),
  5. final aggregation; verify the result equals a one-shot run.

Execution uses the dispatched segagg kernel (``backend="auto"``: compiled
Pallas on TPU/GPU, compiled XLA scatter-add on CPU — docs/API.md "Kernel
backends"), so the calibrated cost model describes the compiled kernel's
wall clock, not interpreter overhead.

    PYTHONPATH=src python examples/deadline_analytics.py
"""
import numpy as np

from repro.core import Planner, Query, TraceArrival, plan_cost
from repro.data.tpch import PAPER_QUERIES, StreamScale, stream_files
from repro.kernels.segagg.ops import resolve_backend
from repro.serve.analytics import (
    measure_cost_model, run_batched, run_plan,
)

SCALE = StreamScale(scale=0.01)
NUM_FILES = 96

query = PAPER_QUERIES[2]  # CQ3: count(*) GROUP BY suppKey
files, times = [], []
for t, orders, lineitem in stream_files(seed=11, num_files=NUM_FILES, sc=SCALE):
    files.append(lineitem if query.stream == "lineitem" else orders)
    times.append(t)

print(f"query {query.query_id}: {query.description} "
      f"(segagg backend: {resolve_backend()})")
cost_model = measure_cost_model(query, files, SCALE, use_kernel=True)
print(f"calibrated cost model: cost(1 file)={cost_model.cost(1)*1e3:.2f} ms, "
      f"cost({NUM_FILES})={cost_model.cost(NUM_FILES)*1e3:.1f} ms")

arrival = TraceArrival(timestamps=tuple(times))
deadline = arrival.wind_end + 0.6 * cost_model.cost(NUM_FILES)
q = Query("CQ3-deadline", arrival.wind_start, arrival.wind_end, deadline,
          NUM_FILES, cost_model, arrival)
plan = Planner(policy="single").schedule(q)
print(f"deadline {deadline:.2f}s -> plan: {plan.sch_tuples} files per batch "
      f"at t={[round(p, 2) for p in plan.sch_points]} "
      f"(modelled cost {plan_cost(q, plan)*1e3:.1f} ms)")

result, log, agg_s = run_plan(query, files, plan, SCALE, use_kernel=True)
oneshot, _, _ = run_batched(query, files, NUM_FILES, SCALE)  # jnp ref path
np.testing.assert_allclose(result, oneshot, rtol=1e-5)
print(f"executed {len(log)} real batches "
      f"({[b.num_records for b in log]} records), final agg {agg_s*1e3:.1f} ms")
print("result identical to one-shot run — partial aggregation exact.")
print(f"total rows: {int(result.sum())}, groups touched: "
      f"{int((result > 0).sum())}")
