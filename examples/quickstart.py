"""Quickstart: the paper's scheduling algorithms behind the Planner API.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    ConstantRateArrival, LinearCostModel, Planner, Query,
    list_policies, plan_cost, validate_schedule,
)

print("registered policies:", ", ".join(list_policies()))

# The paper's running example (Section 3.1): 10 tuples arriving at 1/s over
# window [1, 10]; processing runs at 2 tuples per time unit.
arr = ConstantRateArrival(wind_start=1.0, rate=1.0, num_tuples_total=10)
cm = LinearCostModel(tuple_cost=0.5)

planner = Planner(policy="single")  # Algorithm 1
for deadline in (16.0, 15.0, 12.0, 11.0):
    q = Query(f"case(deadline={deadline})", 1.0, 10.0, deadline, 10, cm, arr)
    plan = planner.schedule(q)
    validate_schedule(q, plan)
    print(f"deadline {deadline:>5}: batches {plan.sch_tuples} "
          f"@ t={['%.1f' % p for p in plan.sch_points]} "
          f"cost={plan_cost(q, plan):.2f}")

# The constraint-based formulation (Section 3.2) agrees on linear models:
q = Query("case-3", 1.0, 10.0, 12.0, 10, cm, arr)
print("constraint solver:", Planner(policy="constraints").schedule(q).sch_tuples,
      "== Algorithm 1:", planner.schedule(q).sch_tuples)

# End-to-end: plan AND execute on the shared runtime loop (simulated).
trace = planner.run([q])
out = trace.outcome("case-3")
print(f"executed {out.num_batches} batches, finished t={out.completion_time:.1f} "
      f"(deadline {out.deadline}) -> met={out.met_deadline}")
