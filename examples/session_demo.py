"""Continuous-session walkthrough: submit -> drift -> recalibrate -> withdraw.

A long-running Session serves a recurring query whose TRUE batch costs are
1.5x what the offline §6.2 fit predicted (OracleCostExecutor injects the
drift).  Watch the lifecycle:

  1. submit  — the recurring spec passes the schedulability pre-flight;
  2. drift   — window 0's plan, made with the stale model, finishes LATE;
  3. recalibrate — observed batch durations push the drift metric over the
     threshold; the session refits and plans later windows correctly;
  4. online admission — a second query joins mid-run (and a hopeless one is
     rejected by the pre-flight);
  5. withdraw — the recurring query leaves; the session drains.

    PYTHONPATH=src python examples/session_demo.py
"""
from repro.core import (
    ConstantRateArrival,
    LinearCostModel,
    Query,
    RecurringQuerySpec,
    Session,
)

N, RATE = 40, 2.0
FITTED = LinearCostModel(tuple_cost=0.1, overhead=0.2, agg_per_batch=0.1)
TRUE = LinearCostModel(tuple_cost=0.15, overhead=0.3, agg_per_batch=0.15)
PERIOD = 60.0


def recurring() -> RecurringQuerySpec:
    arr = ConstantRateArrival(wind_start=0.0, rate=RATE, num_tuples_total=N)
    base = Query(
        query_id="sensor-agg",
        wind_start=0.0,
        wind_end=arr.wind_end,
        # tight: forces a multi-batch plan, so stale costs -> a late finish
        deadline=arr.wind_end + 0.5 * FITTED.cost(N),
        num_tuples_total=N,
        cost_model=FITTED,
        arrival=arr,
    )
    return RecurringQuerySpec(base=base, period=PERIOD, num_windows=None,
                              true_cost_model=TRUE)


def main() -> None:
    session = Session(policy="single", calibrate=True, drift_threshold=0.2,
                      min_samples=2, refit_every=1_000_000)

    # 1. submit (gated by the admission pre-flight)
    res = session.submit(recurring())
    print(f"submitted sensor-agg: admitted={res.admitted}")

    # 2./3. run three windows: window 0 misses (stale model), the observed
    # 1.5x durations trigger a recalibration, windows 1-2 meet.
    session.run_until(3 * PERIOD - 1.0)
    for o in session.trace.outcome_series("sensor-agg"):
        print(f"  {o.query_id}: finish={o.completion_time:7.2f} "
              f"deadline={o.deadline:7.2f} met={o.met_deadline}")
    for e in session.trace.events_for("recalibrate"):
        print(f"  recalibrated at t={e.time:.1f} ({e.detail})")
    cal = session.calibrator("sensor-agg")
    print(f"  calibrator: refits={cal.refits} drift={cal.drift():.4f} "
          f"cost(40): fitted={FITTED.cost(40):.2f} "
          f"calibrated={cal.cost(40):.2f} true={TRUE.cost(40):.2f}")

    # 4. online admission at the live clock: one feasible, one hopeless
    now = session.now
    arr = ConstantRateArrival(wind_start=now, rate=RATE, num_tuples_total=20)
    ok = session.submit(Query("adhoc", now, arr.wind_end,
                              arr.wind_end + 3.0 * FITTED.cost(20),
                              20, FITTED, arr))
    bad_cm = LinearCostModel(tuple_cost=3.0, overhead=10.0)
    bad = session.submit(Query("hopeless", now, arr.wind_end,
                               arr.wind_end + 0.5, 20, bad_cm, arr))
    print(f"mid-run admissions at t={now:.1f}: adhoc={ok.admitted} "
          f"hopeless={bad.admitted}")
    if bad.report.reasons:
        print(f"  rejection reason: {bad.report.reasons[0]}")

    # 5. withdraw the open-ended query and drain the rest
    session.withdraw("sensor-agg")
    trace = session.run()
    print(f"withdrawn; session drained at t={session.now:.1f}")
    met = sum(o.met_deadline for o in trace.outcomes)
    print(f"outcomes: {met}/{len(trace.outcomes)} deadlines met; "
          f"events: {[e.kind for e in trace.events]}")


if __name__ == "__main__":
    main()
