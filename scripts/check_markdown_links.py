#!/usr/bin/env python3
"""Offline markdown link checker for the CI docs job.

Scans the given files/directories for ``.md`` files, extracts inline links
and images (``[text](target)`` / ``![alt](target)``), and verifies that
every RELATIVE target resolves to an existing file or directory.  External
schemes (http/https/mailto) are skipped — CI runs offline — and pure
in-page anchors (``#section``) are skipped too; a ``file.md#anchor`` target
is checked for the file part.

    python scripts/check_markdown_links.py README.md docs

Exits non-zero listing every broken link.
"""
from __future__ import annotations

import pathlib
import re
import sys

# Inline links/images; deliberately simple — fenced code blocks are stripped
# first so `[x](y)` inside code samples is not treated as a link.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"```.*?```", re.S)
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files(paths: list[str]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for p in map(pathlib.Path, paths):
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        else:
            out.append(p)
    return out


def broken_links(md: pathlib.Path) -> list[str]:
    text = _FENCE.sub("", md.read_text(encoding="utf-8"))
    bad = []
    for target in _LINK.findall(text):
        if target.startswith(_SKIP_PREFIXES):
            continue
        file_part = target.split("#", 1)[0]
        if not file_part:
            continue
        if not (md.parent / file_part).exists():
            bad.append(target)
    return bad


def main(argv: list[str]) -> int:
    paths = argv or ["README.md", "docs"]
    files = md_files(paths)
    if not files:
        print(f"no markdown files under {paths}", file=sys.stderr)
        return 1
    failures = 0
    for md in files:
        for target in broken_links(md):
            print(f"{md}: broken link -> {target}", file=sys.stderr)
            failures += 1
    print(f"checked {len(files)} markdown files: "
          f"{failures or 'no'} broken link{'s' if failures != 1 else ''}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
