"""Continuous-session tests.

Headline property (ISSUE acceptance): a Session running the same workload
as one-shot ``Planner.run`` calls — single window, no drift, no admissions —
is TRACE-IDENTICAL to the plain runtime for all 9 registered policies.  On
top of that: recurring windows with carried-over clocks, online admission
(pre-flight gated) and withdrawal, and drift-triggered cost recalibration.
"""
import math

import pytest

from repro.core import (
    CalibratingCostModel,
    ConstantRateArrival,
    LinearCostModel,
    OracleCostExecutor,
    Planner,
    Query,
    RecurringQuerySpec,
    Session,
    TraceArrival,
    get_policy,
    list_policies,
    split_window_id,
    window_query_id,
)
from repro.core.policies.dynamic import LLFPolicy

N_TUPLES = 8


def fixed_query(qid: str = "q0", start: float = 0.0, slack: float = 3.0,
                rate: float = 1.0, n: int = N_TUPLES) -> Query:
    arr = ConstantRateArrival(wind_start=start, rate=rate, num_tuples_total=n)
    cm = LinearCostModel(tuple_cost=0.4, overhead=0.3, agg_per_batch=0.2)
    return Query(qid, start, arr.wind_end, arr.wind_end + slack * cm.cost(n),
                 n, cm, arr)


def drift_pair(n: int = 40, rate: float = 2.0):
    """(base query, true 1.5x cost model): deadline tight enough to force
    batching under the fitted model."""
    cm_fit = LinearCostModel(tuple_cost=0.1, overhead=0.2, agg_per_batch=0.1)
    cm_true = LinearCostModel(tuple_cost=0.15, overhead=0.3,
                              agg_per_batch=0.15)
    arr = ConstantRateArrival(wind_start=0.0, rate=rate, num_tuples_total=n)
    deadline = arr.wind_end + 0.5 * cm_fit.cost(n)
    return Query("d", 0.0, arr.wind_end, deadline, n, cm_fit, arr), cm_true


class TestOneShotParity:
    """Session == Planner.run when sessions degenerate to one-shot windows."""

    @pytest.mark.parametrize("policy_name", sorted(list_policies()))
    def test_single_query_trace_identical(self, policy_name):
        base = Planner(policy=policy_name).run([fixed_query()])
        session = Session(policy=policy_name)
        assert session.submit(fixed_query()).admitted
        trace = session.run()
        assert trace.executions == base.executions
        assert trace.outcomes == base.outcomes

    @pytest.mark.parametrize("policy_name",
                             ["llf-dynamic", "edf-dynamic", "sjf-dynamic",
                              "rr-dynamic"])
    def test_overlapping_multi_query_trace_identical(self, policy_name):
        """Dynamic policies: three CONCURRENT one-shot queries time-share
        the session executor exactly like the fixed-workload loop."""
        def queries():
            return [fixed_query(f"q{i}", start=float(i), slack=5.0)
                    for i in range(3)]

        base = Planner(policy=policy_name).run(queries())
        session = Session(policy=policy_name)
        for q in queries():
            assert session.submit(q).admitted
        trace = session.run()
        assert trace.executions == base.executions
        assert trace.outcomes == base.outcomes

    @pytest.mark.parametrize("policy_name", sorted(
        n for n in list_policies()
        if getattr(get_policy(n), "kind", "static") == "static"))
    def test_spaced_multi_query_trace_identical(self, policy_name):
        """Static policies: windows spaced so each plan drains before the
        next submit — the carried-over session clock then coincides with
        the one-shot per-query timelines."""
        def queries():
            return [fixed_query(f"q{i}", start=40.0 * i) for i in range(3)]

        base = Planner(policy=policy_name).run(queries())
        session = Session(policy=policy_name)
        for q in queries():
            assert session.submit(q).admitted
        trace = session.run()
        assert trace.executions == base.executions
        assert trace.outcomes == base.outcomes

    @pytest.mark.parametrize("policy_name", ["single", "llf-dynamic"])
    def test_submit_time_preserved(self, policy_name):
        # A query submitted to the system after its window starts (§4) must
        # behave identically under a session: submit_time survives the
        # per-window Query instantiation.
        import dataclasses

        def q():
            return dataclasses.replace(fixed_query("late", slack=5.0),
                                       submit_time=5.0)

        base = Planner(policy=policy_name).run([q()])
        session = Session(policy=policy_name)
        session.submit(q())
        trace = session.run()
        assert trace.executions == base.executions
        assert trace.outcomes == base.outcomes
        assert min(e.start for e in trace.executions) >= 5.0

    def test_pool_session_matches_pool_run(self):
        def queries():
            return [fixed_query(f"q{i}", slack=5.0) for i in range(4)]

        base = Planner(policy="llf-dynamic").run(queries(), workers=2)
        session = Session(policy="llf-dynamic", workers=2)
        for q in queries():
            session.submit(q)
        trace = session.run()
        assert trace.executions == base.executions
        assert trace.outcomes == base.outcomes


class TestRecurrence:
    def test_windows_roll_over_with_carried_clocks(self):
        session = Session(policy="llf-dynamic")
        session.submit(RecurringQuerySpec(base=fixed_query("r"), period=30.0,
                                          num_windows=3))
        trace = session.run()
        series = trace.outcome_series("r")
        assert [o.query_id for o in series] == [
            window_query_id("r", w) for w in range(3)
        ]
        assert all(o.met_deadline for o in series)
        # one continuous timeline: completions strictly increase and the
        # second window's first batch starts no earlier than window 1 opens
        comps = [o.completion_time for o in series]
        assert comps == sorted(comps)
        w1_rows = [e for e in trace.executions
                   if e.query_id == window_query_id("r", 1)]
        assert min(e.start for e in w1_rows) >= 30.0

    def test_infeasible_static_window_counts_as_miss(self):
        # A window whose plan is infeasible must surface as a missed,
        # fully-short outcome — not silently vanish from the series.
        import dataclasses

        base = fixed_query("r")
        tight = dataclasses.replace(base, deadline=base.wind_end + 1e-3)
        session = Session(policy="single")
        session.submit(RecurringQuerySpec(base=tight, period=30.0,
                                          num_windows=2), force=True)
        trace = session.run()
        series = trace.outcome_series("r")
        assert len(series) == 2
        for o in series:
            assert not o.met_deadline
            assert o.num_batches == 0 and o.shortfall == N_TUPLES
        assert trace.events_for("window_infeasible")

    def test_static_policy_windows(self):
        session = Session(policy="single")
        session.submit(RecurringQuerySpec(base=fixed_query("r"), period=30.0,
                                          num_windows=3))
        trace = session.run()
        assert len(trace.outcome_series("r")) == 3
        assert trace.all_met

    def test_open_ended_requires_horizon(self):
        session = Session(policy="llf-dynamic")
        session.submit(RecurringQuerySpec(base=fixed_query("r"), period=30.0,
                                          num_windows=None))
        with pytest.raises(ValueError, match="open-ended"):
            session.run()
        session.run_until(95.0)
        # windows at 0/30/60 completed; lazy instantiation didn't run ahead
        done = {split_window_id(o.query_id)[1] for o in trace_outcomes(session)}
        assert done >= {0, 1, 2}

    def test_run_until_is_resumable_and_monotone(self):
        session = Session(policy="llf-dynamic")
        session.submit(RecurringQuerySpec(base=fixed_query("r"), period=30.0,
                                          num_windows=4))
        session.run_until(45.0)
        t1 = session.now
        n1 = len(session.trace.outcomes)
        session.run_until(45.0)  # idempotent at the same horizon
        assert session.now == t1
        assert len(session.trace.outcomes) == n1
        session.run_until(200.0)
        assert session.now >= t1
        assert len(session.trace.outcomes) == 4

    def test_window_events_logged(self):
        session = Session(policy="llf-dynamic")
        session.submit(RecurringQuerySpec(base=fixed_query("r"), period=30.0,
                                          num_windows=2))
        trace = session.run()
        kinds = [e.kind for e in trace.events]
        assert kinds.count("window_open") == 2
        assert kinds.count("window_close") == 2
        assert kinds[0] == "submit"

    def test_recurring_spec_validation(self):
        with pytest.raises(ValueError, match="period"):
            RecurringQuerySpec(base=fixed_query(), period=0.0)
        with pytest.raises(ValueError, match="num_windows"):
            RecurringQuerySpec(base=fixed_query(), period=1.0, num_windows=0)
        spec = RecurringQuerySpec(base=fixed_query(), period=5.0,
                                  num_windows=2)
        with pytest.raises(IndexError):
            spec.window_query(2)


def trace_outcomes(session):
    return session.trace.outcomes


class TestAdmission:
    def test_infeasible_submission_rejected_with_reasons(self):
        session = Session(policy="llf-dynamic")
        arr = ConstantRateArrival(wind_start=0.0, rate=1.0,
                                  num_tuples_total=20)
        hopeless = Query("bad", 0.0, arr.wind_end, arr.wind_end + 0.1, 20,
                         LinearCostModel(tuple_cost=2.0, overhead=5.0), arr)
        res = session.submit(hopeless)
        assert not res.admitted and not res
        assert res.report.reasons
        assert [e.kind for e in session.trace.events] == ["reject"]
        # force= overrides the gate (misses become a measured outcome)
        assert session.submit(hopeless, force=True).admitted

    def test_mid_run_admission_between_batches(self):
        session = Session(policy="llf-dynamic")
        session.submit(RecurringQuerySpec(base=fixed_query("a"), period=30.0,
                                          num_windows=3))
        session.run_until(40.0)
        res = session.submit(fixed_query("b", start=45.0, slack=5.0))
        assert res.admitted
        trace = session.run()
        assert trace.outcome("b").met_deadline
        assert len(trace.outcome_series("a")) == 3
        # admission was logged at the session clock, not window time
        sub = [e for e in trace.events if e.kind == "submit"
               and e.query_id == "b"]
        assert sub and sub[0].time >= 40.0

    def test_duplicate_live_id_rejected(self):
        session = Session(policy="llf-dynamic")
        session.submit(fixed_query("a"))
        with pytest.raises(ValueError, match="already used"):
            session.submit(fixed_query("a"))

    def test_window_namespace_collision_rejected(self):
        session = Session(policy="llf-dynamic")
        with pytest.raises(ValueError, match="per-window id namespace"):
            session.submit(fixed_query("load#w2"))
        session.submit(fixed_query("load#windmill"))  # not a window suffix

    def test_dynamic_spec_delete_time_preserved(self):
        # Planner.run deletes the spec at t=4; a Session must do the same.
        from repro.core import DynamicQuerySpec

        def spec():
            return DynamicQuerySpec(query=fixed_query("a", slack=5.0),
                                    delete_time=4.0)

        base = Planner(policy="llf-dynamic").run([spec()])
        session = Session(policy="llf-dynamic")
        session.submit(spec())
        trace = session.run()
        assert trace.executions == base.executions
        assert trace.outcomes == base.outcomes
        assert not trace.outcomes  # deleted mid-window: never completes

    def test_admission_event_reaches_policy(self):
        seen = []

        class Recorder(LLFPolicy):
            def replan(self, event, state):
                seen.append(event.kind)
                return super().replan(event, state)

        session = Session(policy=Recorder())
        session.submit(fixed_query("a"))
        session.run()
        assert "admission" in seen


class TestWithdrawal:
    def test_withdraw_stops_future_windows(self):
        session = Session(policy="llf-dynamic")
        session.submit(RecurringQuerySpec(base=fixed_query("r"), period=30.0,
                                          num_windows=10))
        session.run_until(40.0)
        session.withdraw("r")
        trace = session.run()
        windows = {split_window_id(o.query_id)[1]
                   for o in trace.outcome_series("r")}
        assert max(windows) <= 2
        assert [e.kind for e in trace.events][-1] != "window_open" or True
        assert any(e.kind == "withdraw" for e in trace.events)
        # nothing of r executes after the withdrawal instant + its last batch
        last = max((e.end for e in trace.executions), default=0.0)
        assert last <= 45.0

    def test_withdrawn_id_cannot_be_resubmitted(self):
        # A second incarnation would re-mint the same per-window ids and
        # corrupt first-match-by-id runtime/trace lookups.
        session = Session(policy="llf-dynamic")
        session.submit(RecurringQuerySpec(base=fixed_query("r"), period=30.0,
                                          num_windows=4))
        session.run_until(10.0)
        session.withdraw("r")
        with pytest.raises(ValueError, match="already used"):
            session.submit(fixed_query("r"))

    def test_on_withdraw_hook_called(self):
        calls = []

        class Recorder(LLFPolicy):
            def on_withdraw(self, rt, now):
                calls.append((rt.q.query_id, now))

        session = Session(policy=Recorder())
        session.submit(RecurringQuerySpec(base=fixed_query("r", slack=5.0),
                                          period=30.0, num_windows=4))
        session.run_until(35.0)
        session.withdraw("r")
        session.run_until(70.0)
        assert calls, "policy.on_withdraw never invoked"


class TestCalibration:
    def test_static_model_misses_calibrating_meets(self):
        """The ISSUE acceptance demo in miniature: true cost 1.5x fitted."""
        results = {}
        for calibrate in (False, True):
            base, cm_true = drift_pair()
            spec = RecurringQuerySpec(base=base, period=60.0, num_windows=4,
                                      true_cost_model=cm_true)
            session = Session(policy="single", calibrate=calibrate,
                              drift_threshold=0.2, min_samples=2,
                              refit_every=1_000_000)
            assert session.submit(spec).admitted
            trace = session.run()
            results[calibrate] = trace.outcome_series("d")
        stale = [o.met_deadline for o in results[False]]
        calibrated = [o.met_deadline for o in results[True]]
        assert stale == [False, False, False, False]
        assert calibrated[0] is False       # window 0 pays for discovery
        assert all(calibrated[1:]), calibrated

    def test_recalibrate_event_and_drift_reset(self):
        base, cm_true = drift_pair()
        spec = RecurringQuerySpec(base=base, period=60.0, num_windows=2,
                                  true_cost_model=cm_true)
        session = Session(policy="single", calibrate=True,
                          drift_threshold=0.2, min_samples=2,
                          refit_every=1_000_000)
        session.submit(spec)
        trace = session.run()
        recals = trace.events_for("recalibrate")
        assert recals and "drift=" in recals[0].detail
        cal = session.calibrator("d")
        assert cal.refits >= 1
        assert cal.drift() < 0.2  # post-refit predictions track the oracle

    def test_dynamic_policy_minbatch_resized(self):
        base, cm_true = drift_pair()
        c_max = base.cost_model.cost(5)  # quantum == fitted 5-tuple batch
        sizes = []

        class Recorder(LLFPolicy):
            def on_recalibrate(self, rt, now):
                before = rt.min_batch
                super().on_recalibrate(rt, now)
                sizes.append((before, rt.min_batch))

        spec = RecurringQuerySpec(base=base, period=60.0, num_windows=3,
                                  true_cost_model=cm_true)
        session = Session(policy=Recorder(delta_rsf=0.5, c_max=c_max),
                          calibrate=True, drift_threshold=0.2,
                          min_samples=2, refit_every=1_000_000)
        session.submit(spec)
        session.run()
        assert sizes, "on_recalibrate never invoked"
        assert any(after < before for before, after in sizes), (
            "1.5x true costs must shrink the C_max-capped MinBatch"
        )

    def test_oracle_executor_charges_true_costs(self):
        base, cm_true = drift_pair(n=10)
        ex = OracleCostExecutor({"d": cm_true})
        session = Session(policy="llf-dynamic", executor=ex)
        session.submit(base)
        trace = session.run()
        batch = next(e for e in trace.executions if e.kind == "batch")
        assert batch.end - batch.start == pytest.approx(
            cm_true.cost(batch.num_tuples))

    def test_calibrator_shared_across_windows(self):
        base, cm_true = drift_pair()
        spec = RecurringQuerySpec(base=base, period=60.0, num_windows=2,
                                  true_cost_model=cm_true)
        session = Session(policy="llf-dynamic", calibrate=True,
                          min_samples=2)
        session.submit(spec)
        session.run()
        cal = session.calibrator("d")
        assert isinstance(cal, CalibratingCostModel)
        assert cal.num_observations > 0
        # both windows fed the SAME calibrator
        w0 = sum(1 for e in session.trace.executions
                 if split_window_id(e.query_id)[1] == 0 and e.kind == "batch")
        assert cal.num_observations > w0

    def test_true_cost_model_requires_oracle_backend(self):
        from repro.core import SimulatedExecutor

        base, cm_true = drift_pair()
        session = Session(policy="llf-dynamic", executor=SimulatedExecutor())
        with pytest.raises(TypeError, match="OracleCostExecutor"):
            session.submit(RecurringQuerySpec(base=base, period=60.0,
                                              num_windows=1,
                                              true_cost_model=cm_true))


class TestSessionShortfall:
    def test_underdelivering_truth_flagged_per_window(self):
        ts = tuple(float(i) for i in range(N_TUPLES))
        base = fixed_query("r")
        spec = RecurringQuerySpec(
            base=base, period=30.0, num_windows=2,
            truth_factory=lambda w: TraceArrival(
                timestamps=tuple(t + 30.0 * w for t in ts[:6])),
        )
        session = Session(policy="llf-dynamic")
        session.submit(spec)
        trace = session.run()
        for o in trace.outcome_series("r"):
            assert o.tuples_processed == 6
            assert o.num_tuples_total == N_TUPLES
            assert o.shortfall == 2
            assert not o.complete


class TestSessionMisc:
    def test_now_advances_without_work(self):
        session = Session(policy="llf-dynamic")
        session.submit(fixed_query("a"))
        session.run_until(500.0)
        assert session.now >= 100.0  # idled forward past the drained work

    def test_session_repr_and_live_ids(self):
        session = Session(policy="llf-dynamic")
        session.submit(fixed_query("a"))
        assert session.live_ids == ["a"]
        assert "Session" in repr(session)

    def test_c_max_kwarg_reaches_policy_sizing(self):
        # Session(c_max=x) must size MinBatch with x, exactly like
        # Planner(policy=name, c_max=x) — not the policy's default 30.0.
        session = Session(policy="llf-dynamic", c_max=2.0)
        assert session.policy.c_max == 2.0
        base = Planner(policy="llf-dynamic", c_max=2.0).run([fixed_query()])
        session.submit(fixed_query())
        trace = session.run()
        assert trace.executions == base.executions

    def test_submit_rejects_unknown_type(self):
        session = Session(policy="llf-dynamic")
        with pytest.raises(TypeError):
            session.submit(42)

    def test_run_respects_max_steps(self):
        session = Session(policy="llf-dynamic")
        session.submit(RecurringQuerySpec(base=fixed_query("r"), period=30.0,
                                          num_windows=50))
        with pytest.raises(RuntimeError, match="steps"):
            session.run(max_steps=5)

    def test_infinite_horizon_guard_allows_bounded(self):
        session = Session(policy="llf-dynamic")
        session.submit(RecurringQuerySpec(base=fixed_query("r"), period=30.0,
                                          num_windows=2))
        trace = session.run_until(math.inf)
        assert len(trace.outcome_series("r")) == 2


class TestPhantomPrefixAdmission:
    """Regression: mid-session admission used to credit a "phantom prefix"
    — prewindow processing capacity in time that had ALREADY ELAPSED — so
    a tight submission whose window lay (partly) in the past could be
    admitted into a set with no room for it.  The schedulability checks
    now floor all capacity at the admission instant, composing with
    ShiftedArrival windows and nonzero stream offsets."""

    @staticmethod
    def _backlogged_session(start: float, offset: int):
        from repro.core import UniformWindowArrival

        arr = UniformWindowArrival(wind_start=start, wind_end=start + 100.0,
                                   num_tuples_total=100)
        q1 = Query("bg", start, start + 100.0, start + 130.0, 100,
                   LinearCostModel(tuple_cost=1.0), arr,
                   stream="s", stream_offset=offset)
        s = Session(policy="llf-dynamic", c_max=200.0)
        assert s.submit(q1).admitted
        s.run_until(start + 90.0)
        return s

    @pytest.mark.parametrize("start", [0.0, 250.0])
    @pytest.mark.parametrize("offset", [0, 64])
    def test_past_window_submission_rejected(self, start, offset):
        from repro.core import UniformWindowArrival

        s = self._backlogged_session(start, offset)
        now = s.now
        assert now == pytest.approx(start + 90.9, abs=0.5)
        # window already closed; 35 units of work, deadline leaves ~29
        # units from now — together with the ~10-unit backlog: infeasible.
        arr2 = UniformWindowArrival(wind_start=start + 85.0,
                                    wind_end=start + 90.0,
                                    num_tuples_total=35)
        q2 = Query("late", start + 85.0, start + 90.0, start + 120.0, 35,
                   LinearCostModel(tuple_cost=1.0), arr2,
                   stream="s", stream_offset=offset + 200)
        r = s.submit(q2)
        assert not r.admitted, (
            "phantom prefix: admission credited processing capacity in "
            f"the past (reasons: {r.report.reasons})"
        )

    @pytest.mark.parametrize("start", [0.0, 250.0])
    def test_loose_deadline_still_admitted(self, start):
        from repro.core import UniformWindowArrival

        s = self._backlogged_session(start, 0)
        arr2 = UniformWindowArrival(wind_start=start + 85.0,
                                    wind_end=start + 90.0,
                                    num_tuples_total=35)
        q2 = Query("late", start + 85.0, start + 90.0, start + 200.0, 35,
                   LinearCostModel(tuple_cost=1.0), arr2)
        assert s.submit(q2).admitted

    def test_doomed_active_does_not_lock_out_admissions(self):
        """Companion to the now-floor fix (no overload opt-in needed): an
        active query whose deadline is already beyond saving must not make
        every later admission infeasible — its lost deadline is relaxed in
        the snapshot while its remaining work still counts."""
        from repro.core import UniformWindowArrival

        arr = UniformWindowArrival(wind_start=0.0, wind_end=100.0,
                                   num_tuples_total=100)
        doomed = Query("doomed", 0.0, 100.0, 105.0, 100,
                       LinearCostModel(tuple_cost=2.0), arr)  # 200 units
        s = Session(policy="llf-dynamic", c_max=200.0)
        assert s.submit(doomed, force=True).admitted  # born infeasible
        s.run_until(120.0)
        arr2 = UniformWindowArrival(wind_start=120.0, wind_end=130.0,
                                    num_tuples_total=5)
        newcomer = Query("ok", 120.0, 130.0, 400.0, 5,
                         LinearCostModel(tuple_cost=1.0), arr2)
        assert s.submit(newcomer).admitted

    @pytest.mark.parametrize("shift", [0.0, 40.0])
    def test_max_prewindow_floors_at_now(self, shift):
        from repro.core import ShiftedArrival, UniformWindowArrival
        from repro.core.schedulability import max_prewindow_tuples

        base = UniformWindowArrival(wind_start=0.0, wind_end=10.0,
                                    num_tuples_total=10)
        arr = base if shift == 0 else ShiftedArrival(base=base, shift=shift)
        q = Query("w", shift, shift + 10.0, shift + 15.0, 10,
                  LinearCostModel(tuple_cost=1.0), arr)
        assert max_prewindow_tuples(q) > 0          # offline: capacity exists
        after = q.wind_end + 1.0
        assert max_prewindow_tuples(q, now=after) == 0  # window in the past


class TestWithdrawSharerResync:
    """Regression: withdrawing a sharing query mid-window re-amortized the
    survivors' SharedCostModels but left their MinBatches sized under the
    cheaper pre-withdraw cost — a single batch could then exceed C_max,
    breaking the §4.2-4.3 blocking bound."""

    C_MAX = 25.0

    def _session(self):
        from repro.core import UniformWindowArrival

        s = Session(policy="llf-dynamic", sharing=True, c_max=self.C_MAX,
                    admission_control=False)
        for qid in ("a", "b", "c"):
            arr = UniformWindowArrival(wind_start=0.0, wind_end=40.0,
                                       num_tuples_total=40)
            q = Query(qid, 0.0, 40.0, 90.0, 40,
                      LinearCostModel(tuple_cost=1.0, overhead=0.5), arr,
                      stream="s", stream_offset=0)
            assert s.submit(RecurringQuerySpec(base=q, period=40.0,
                                               num_windows=2))
        s.run_until(10.0)
        return s

    @staticmethod
    def _live_runtimes(session):
        rts = []
        for base in session.live_ids:
            live = session._runtime._live[base]
            rts.extend(rt for rt in live.runtimes
                       if rt.admitted and not (rt.completed or rt.deleted))
        return rts

    def test_exhausted_specs_still_count_as_sharers(self):
        from repro.core.cost_model import SharedCostModel

        s = self._session()
        shared = [rt.q.cost_model for rt in self._live_runtimes(s)
                  if isinstance(rt.q.cost_model, SharedCostModel)]
        assert shared, "expected shared in-flight windows"
        # three specs in flight: the divisor must say 3, even though every
        # spec has already instantiated its last window ("exhausted")
        assert {m.sharers for m in shared} == {3}

    def test_withdraw_resyncs_divisor_and_minbatch(self):
        from repro.core.cost_model import SharedCostModel

        s = self._session()
        s.withdraw("c")
        survivors = self._live_runtimes(s)
        assert survivors
        for rt in survivors:
            cm = rt.q.cost_model
            if isinstance(cm, SharedCostModel):
                assert cm.sharers == 2  # stale divisor would still say 3
            # the C_max blocking bound must hold under the NEW pricing —
            # stale MinBatches violated it (cost(40) ~ 40.5 > 25)
            if rt.min_batch > 0:
                pending = rt.q.num_tuples_total - rt.processed
                assert cm.cost(min(rt.min_batch, max(pending, 1))) \
                    <= self.C_MAX + 1e-6

    def test_withdraw_trace_still_consistent(self):
        s = self._session()
        s.withdraw("c")
        trace = s.run_until(300.0)
        done = {o.query_id for o in trace.outcomes}
        assert window_query_id("a", 1) in done
        assert window_query_id("b", 1) in done
        assert window_query_id("c", 1) not in done
