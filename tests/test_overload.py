"""Overload-control tests (repro.core.overload).

Coverage demanded by the ISSUE: shed-fraction monotonicity vs offered load,
strict priority tiers under the executor pool (workers > 1), the deadline-
renegotiation round trip, and trace byte-identity for all 9 policies when
overload control is disabled.  Plus the building blocks: ThinnedArrival's
inverse invariant, the error-bound formula, minimum-shed planning and the
real-backend sampled scans.
"""
import dataclasses
import math

import pytest

from repro.core import (
    EPS,
    LinearCostModel,
    OverloadConfig,
    Planner,
    Query,
    Session,
    ThinnedArrival,
    TraceArrival,
    UniformWindowArrival,
    apply_shed,
    list_policies,
    min_deadline_extension,
    plan_shedding,
    shed_error_bound,
)
from repro.core.schedulability import admission_check, work_demand_condition
from repro.core.session import SessionRuntime


def overload_query(qid: str, n: int = 100, start: float = 0.0,
                   window: float = 100.0, slack: float = 30.0,
                   tuple_cost: float = 1.0, tier: int = 0,
                   shed: bool = True) -> Query:
    """n tuples uniformly over [start, start+window], deadline window end +
    slack.  With tuple_cost=1 one such query saturates the executor; k
    concurrent queries offer k-times capacity."""
    arr = UniformWindowArrival(wind_start=start, wind_end=start + window,
                               num_tuples_total=n)
    return Query(query_id=qid, wind_start=start, wind_end=start + window,
                 deadline=start + window + slack, num_tuples_total=n,
                 cost_model=LinearCostModel(tuple_cost=tuple_cost),
                 arrival=arr, tier=tier, shed=shed)


class TestThinnedArrival:
    def test_inverse_invariant(self):
        base = UniformWindowArrival(wind_start=0.0, wind_end=99.0,
                                    num_tuples_total=100)
        for prefix in (0, 10, 37):
            for keep in (1, 13, 50, 100 - prefix):
                t = ThinnedArrival(base=base, keep=keep, prefix=prefix)
                assert t.num_tuples_total == prefix + keep
                for k in range(1, t.num_tuples_total + 1):
                    avail = t.tuples_available(t.input_time(k))
                    assert avail >= k
                    # exact inverse: nothing extra arrived strictly before
                    if k < t.num_tuples_total:
                        assert t.input_time(k) <= t.input_time(k + 1)

    def test_systematic_sample_keeps_last_tuple(self):
        base = UniformWindowArrival(wind_start=0.0, wind_end=99.0,
                                    num_tuples_total=100)
        t = ThinnedArrival(base=base, keep=7, prefix=20)
        assert t.base_index(t.num_tuples_total) == 100
        assert t.wind_end == base.wind_end
        # prefix passes through 1:1
        for k in range(1, 21):
            assert t.base_index(k) == k
            assert t.input_time(k) == base.input_time(k)

    def test_keep_zero(self):
        base = UniformWindowArrival(wind_start=0.0, wind_end=9.0,
                                    num_tuples_total=10)
        t = ThinnedArrival(base=base, keep=0, prefix=4)
        assert t.num_tuples_total == 4
        assert t.tuples_available(1e9) == 4

    def test_validation(self):
        base = UniformWindowArrival(wind_start=0.0, wind_end=9.0,
                                    num_tuples_total=10)
        with pytest.raises(ValueError):
            ThinnedArrival(base=base, keep=11)
        with pytest.raises(ValueError):
            ThinnedArrival(base=base, keep=1, prefix=-1)
        with pytest.raises(ValueError):
            ThinnedArrival(base=base, keep=8, prefix=5)


class TestErrorBound:
    def test_monotone_in_shed_fraction(self):
        bounds = [shed_error_bound(f, int((1 - f) * 1000))
                  for f in (0.0, 0.1, 0.3, 0.5, 0.7, 0.9)]
        assert bounds == sorted(bounds)
        assert bounds[0] == 0.0

    def test_shrinks_with_sample_size(self):
        assert shed_error_bound(0.5, 1000) < shed_error_bound(0.5, 10)
        assert shed_error_bound(0.5, 0) == math.inf


class TestApplyShed:
    def test_fraction_realized_and_reported(self):
        q = overload_query("q", n=100)
        thin, cum, bound = apply_shed(q, 0.4)
        assert thin.num_tuples_total == 60
        assert cum == pytest.approx(0.4)
        assert bound == pytest.approx(shed_error_bound(0.4, 60))
        assert isinstance(thin.arrival, ThinnedArrival)

    def test_processed_prefix_exempt(self):
        q = overload_query("q", n=100)
        thin, cum, bound = apply_shed(q, 0.5, processed=40)
        # half of the 60 remaining dropped -> 40 + 30 kept
        assert thin.num_tuples_total == 70
        assert cum == pytest.approx(0.3)

    def test_composes_cumulatively(self):
        q = overload_query("q", n=100)
        thin1, cum1, _ = apply_shed(q, 0.5)
        thin2, cum2, _ = apply_shed(thin1, 0.5)
        assert thin1.num_tuples_total == 50
        assert thin2.num_tuples_total == 25
        assert cum2 == pytest.approx(0.75)  # vs the ORIGINAL total

    def test_noop_below_resolution(self):
        q = overload_query("q", n=100)
        thin, cum, _ = apply_shed(q, 0.0)
        assert thin is q and cum == 0.0

    def test_shed_history_survives_window_shifts(self):
        """Windows >= 1 of an admission-shed recurring spec wrap the
        thinned arrival in ShiftedArrival; the shed history must still be
        visible through the shift (cumulative caps depend on it)."""
        from repro.core import RecurringQuerySpec
        from repro.core.overload import existing_shed, original_total

        thin, cum, _ = apply_shed(overload_query("r", n=100), 0.4)
        spec = RecurringQuerySpec(base=thin, period=200.0, num_windows=3)
        w1 = spec.window_query(1)
        assert original_total(w1) == 100
        assert existing_shed(w1) == pytest.approx(cum)


class TestWorkDemandCondition:
    def test_detects_joint_overload_smooth_arrivals(self):
        """Two queries that individually keep up but jointly offer 2x
        capacity: the post-window condition alone passes (per-query
        prewindow capacity assumes a dedicated executor) — the processor-
        demand bound is what catches the overload."""
        qs = [overload_query("a"), overload_query("b")]
        assert not work_demand_condition(qs)
        assert not admission_check([qs[1]], [qs[0]])

    def test_feasible_workload_passes(self):
        qs = [overload_query("a"), overload_query("b", start=200.0)]
        assert work_demand_condition(qs)

    def test_now_floor(self):
        q = overload_query("a", slack=120.0)  # deadline 220, work 100
        assert work_demand_condition([q])
        # at now=130 only 90 time units remain for 100 units of work
        assert not work_demand_condition([q], now=130.0)


class TestTieredWorkDemand:
    def test_early_query_not_charged_with_late_higher_tier_work(self):
        """A tier-1 query whose stream (and therefore earliest completion)
        ends before the tier-0 work even ARRIVES is not delayed by it —
        the charge horizon is the query's own last-tuple arrival."""
        from repro.core import tiered_work_demand_condition

        q1 = Query("fast1", 0.0, 0.0, 10.0, 1,
                   LinearCostModel(tuple_cost=1.0),
                   TraceArrival(timestamps=(0.0,)), tier=1)
        q0 = Query("big0", 5.0, 9.0, 100.0, 20,
                   LinearCostModel(tuple_cost=1.0),
                   TraceArrival(timestamps=tuple(5.0 + 0.2 * i
                                                 for i in range(20))),
                   tier=0)
        assert tiered_work_demand_condition([q1, q0])

    def test_overlapping_higher_tier_work_charged(self):
        from repro.core import tiered_work_demand_condition

        # both streams run through [0, 100]; tier-1 deadline 110 must
        # absorb tier-0's 60 units first -> 60 + 80 > 110: infeasible.
        q1 = overload_query("t1", n=80, slack=10.0, tier=1)
        q0 = overload_query("t0", n=60, slack=200.0, tier=0)
        assert not tiered_work_demand_condition([q1, q0])
        # tier-blind, same deadlines structure: generic condition passes
        from repro.core.schedulability import work_demand_condition
        assert work_demand_condition([q1, q0])


class TestPlanShedding:
    def test_minimum_shed_restores_feasibility(self):
        qs = [overload_query("t0", tier=0, shed=False),
              overload_query("t1", tier=1)]
        plan = plan_shedding(qs)
        assert plan.feasible
        assert set(plan.fractions) == {"t1"}
        f = plan.fractions["t1"]
        # minimal: shedding noticeably less must stay infeasible
        thin, _, _ = apply_shed(qs[1], max(f - 0.05, 0.0))
        assert not admission_check([qs[0], thin])
        assert plan.error_bounds["t1"] <= OverloadConfig().max_error_bound

    def test_monotone_in_offered_load(self):
        """Shed fraction grows monotonically with offered load (1x-8x)."""
        sheds = []
        for load in (1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0):
            qs = [overload_query("t0", tier=0, shed=False),
                  overload_query("t1", n=int(100 * load), tier=1)]
            plan = plan_shedding(qs, config=OverloadConfig(
                max_shed=0.99, max_error_bound=10.0))
            assert plan.feasible
            sheds.append(plan.fractions.get("t1", 0.0))
        assert sheds == sorted(sheds)
        assert sheds[0] < sheds[-1]

    def test_lowest_tier_sheds_first(self):
        qs = [overload_query("t0", tier=0),
              overload_query("t1", tier=1),
              overload_query("t2", tier=2)]
        plan = plan_shedding(qs, config=OverloadConfig(max_shed=0.95,
                                                       max_error_bound=10.0))
        assert plan.feasible
        # tier 2 sheds at least as much as tier 1; tier 0 only if needed
        assert plan.fractions.get("t2", 0.0) >= plan.fractions.get("t1", 0.0)
        assert plan.fractions.get("t2", 0.0) > 0

    def test_unsheddable_never_touched(self):
        qs = [overload_query("t0", tier=0, shed=False),
              overload_query("t1", tier=1, shed=False)]
        plan = plan_shedding(qs)
        assert not plan.feasible
        assert plan.fractions == {}

    def test_error_bound_cap_bounds_search(self):
        """A tight error-bound cap limits how much may be shed — the plan
        must respect it or report infeasible, never exceed it."""
        qs = [overload_query("t0", tier=0, shed=False),
              overload_query("t1", tier=1)]
        cfg = OverloadConfig(max_error_bound=0.05)
        plan = plan_shedding(qs, config=cfg)
        for b in plan.error_bounds.values():
            assert b <= cfg.max_error_bound + 1e-9

    def test_feasible_workload_needs_no_shed(self):
        plan = plan_shedding([overload_query("a", slack=200.0)])
        assert plan.feasible and plan.fractions == {}


class TestRenegotiation:
    def test_minimal_extension(self):
        active = [overload_query("a", shed=False)]
        incoming = overload_query("b", shed=False)
        prop = min_deadline_extension(incoming, active)
        assert prop is not None
        assert prop.extension == pytest.approx(70.0, abs=1e-3)
        # minimality: a visibly smaller extension is still infeasible
        smaller = dataclasses.replace(
            incoming, deadline=incoming.deadline + prop.extension - 0.1)
        assert not admission_check([smaller], active)

    def test_none_when_feasible(self):
        assert min_deadline_extension(overload_query("a", slack=200.0)) is None

    def test_capped_extension(self):
        active = [overload_query("a", shed=False)]
        incoming = overload_query("b", shed=False)
        cfg = OverloadConfig(max_extension=10.0)  # needs ~70
        assert min_deadline_extension(incoming, active, config=cfg) is None


class TestSessionOverload:
    def test_admit_with_shed_end_to_end(self):
        s = SessionRuntime(policy="llf-dynamic", overload=True, c_max=50.0)
        assert s.submit(overload_query("t0", tier=0, shed=False)).decision == "admit"
        r = s.submit(overload_query("t1", tier=1))
        assert r.admitted and r.decision == "shed"
        assert 0.0 < r.shed_fraction < 1.0
        assert 0.0 < r.error_bound <= OverloadConfig().max_error_bound
        trace = s.run_until(500.0)
        o0 = trace.outcome("t0")
        o1 = trace.outcome("t1")
        assert o0.met_deadline and o0.shed_fraction == 0.0
        assert o1.shed_fraction == pytest.approx(r.shed_fraction)
        assert o1.error_bound == pytest.approx(r.error_bound)
        assert o1.complete  # the SAMPLED stream was fully processed
        events = trace.events_for("shed")
        assert [e.query_id for e in events] == ["t1"]

    def test_renegotiation_round_trip(self):
        """The proposal reaches the hook, acceptance extends the deadline,
        the event logs the exchange, and the result carries the proposal."""
        seen = []

        def accept(proposal):
            seen.append(proposal)
            return True

        s = SessionRuntime(policy="llf-dynamic", overload=True,
                           on_renegotiate=accept)
        s.submit(overload_query("a", shed=False))
        r = s.submit(overload_query("b", shed=False))
        assert r.admitted and r.decision == "renegotiate"
        assert len(seen) == 1 and seen[0].query_id == "b"
        assert r.proposal is seen[0]
        assert r.proposal.proposed_deadline == pytest.approx(200.0, abs=1e-3)
        ev = s.trace.events_for("renegotiate")
        assert len(ev) == 1 and "accepted=True" in ev[0].detail
        trace = s.run_until(500.0)
        ob = trace.outcome("b")
        assert ob.deadline == pytest.approx(200.0, abs=1e-3)
        assert ob.met_deadline

    def test_renegotiation_declined_rejects(self):
        s = SessionRuntime(policy="llf-dynamic", overload=True,
                           on_renegotiate=lambda p: False)
        s.submit(overload_query("a", shed=False))
        r = s.submit(overload_query("b", shed=False))
        assert not r.admitted and r.decision == "reject"
        assert r.proposal is not None  # what was offered is on record
        ev = s.trace.events_for("renegotiate")
        assert len(ev) == 1 and "accepted=False" in ev[0].detail

    def test_no_hook_means_declined(self):
        s = SessionRuntime(policy="llf-dynamic", overload=True)
        s.submit(overload_query("a", shed=False))
        assert s.submit(overload_query("b", shed=False)).decision == "reject"

    def test_reject_report_carries_failing_reasons(self):
        """An overload-path rejection must explain itself: the returned
        report is the FAILING one (shedding could not restore the
        conditions), not the feasible report of some probe."""
        s = SessionRuntime(policy="llf-dynamic",
                           overload=OverloadConfig(renegotiate=False))
        s.submit(overload_query("a", shed=False))
        r = s.submit(overload_query("b", shed=False))
        assert not r.admitted and r.decision == "reject"
        assert not r.report.feasible
        assert r.report.reasons
        ev = [e for e in s.trace.events_for("reject") if e.query_id == "b"]
        assert ev and ev[0].detail  # the reasons reached the event log

    def test_overload_disabled_rejects_as_before(self):
        s = SessionRuntime(policy="llf-dynamic")
        s.submit(overload_query("a"))
        r = s.submit(overload_query("b"))
        assert not r.admitted and r.decision == "reject"
        assert not s.trace.events_for("shed")
        assert not s.trace.events_for("renegotiate")

    def test_active_lower_tier_shed_for_incoming_tier0(self):
        """An unsheddable tier-0 arrival sheds the ACTIVE tier-1 query
        instead of being rejected."""
        s = SessionRuntime(policy="llf-dynamic", overload=True, c_max=50.0)
        assert s.submit(overload_query("t1", tier=1)).decision == "admit"
        r = s.submit(overload_query("t0", tier=0, shed=False))
        assert r.admitted and r.decision == "shed"
        assert r.shed_fraction == 0.0  # the INCOMING query stays whole
        shed_ev = s.trace.events_for("shed")
        assert [e.query_id for e in shed_ev] == ["t1"]
        trace = s.run_until(500.0)
        assert trace.outcome("t0").met_deadline
        assert trace.outcome("t0").shed_fraction == 0.0
        assert trace.outcome("t1").shed_fraction > 0.0

    def test_static_policy_shed_admission(self):
        """The shed path works for static policies too (pending windows are
        thinned before planning)."""
        s = SessionRuntime(policy="single", overload=True)
        s.submit(overload_query("a", shed=False))
        r = s.submit(overload_query("b", tier=1))
        assert r.admitted and r.decision == "shed"
        trace = s.run_until(500.0)
        ob = trace.outcome("b")
        assert ob.shed_fraction == pytest.approx(r.shed_fraction)
        assert ob.num_tuples_total < 100


class TestTierStrictness:
    @pytest.mark.parametrize("workers", [1, 2])
    def test_tier0_always_dispatched_first(self, workers):
        """Strict tiers under the bare loop AND the pool: while a tier-0
        query has dispatchable work, no tier-1 batch starts — even though
        LLF alone would prefer the tier-1 query (tighter laxity)."""
        ts = tuple(0.0 for _ in range(40))
        cm = LinearCostModel(tuple_cost=1.0, overhead=0.5)
        q0 = Query("big0", 0.0, 0.0, 500.0, 40, cm,
                   TraceArrival(timestamps=ts), tier=0)
        q1 = Query("urgent1", 0.0, 0.0, 120.0, 40, cm,
                   TraceArrival(timestamps=ts), tier=1)
        trace = Planner(policy="llf-dynamic", c_max=12.0).run(
            [q0, q1], workers=workers if workers > 1 else None)
        starts0 = [e.start for e in trace.executions
                   if e.query_id == "big0" and e.kind == "batch"]
        starts1 = [e.start for e in trace.executions
                   if e.query_id == "urgent1" and e.kind == "batch"]
        assert starts0 and starts1
        assert max(starts0) <= min(starts1) + EPS

    def test_default_tier_keeps_llf_order(self):
        """Without tiers the tighter-laxity query wins — proof the tier
        test above is exercising the tier, not the strategy."""
        ts = tuple(0.0 for _ in range(40))
        cm = LinearCostModel(tuple_cost=1.0, overhead=0.5)
        q0 = Query("big0", 0.0, 0.0, 500.0, 40, cm,
                   TraceArrival(timestamps=ts))
        q1 = Query("urgent1", 0.0, 0.0, 120.0, 40, cm,
                   TraceArrival(timestamps=ts))
        trace = Planner(policy="llf-dynamic", c_max=12.0).run([q0, q1])
        first = min((e.start, e.query_id) for e in trace.executions
                    if e.kind == "batch")
        assert first[1] == "urgent1"


class TestByteIdentityWhenDisabled:
    """With overload control disabled the new knobs must be invisible:
    traces are byte-identical whether the tier/shed fields are left at
    their defaults or set explicitly, for all 9 registered policies — and
    an ENABLED overload session that never trips the conditions matches a
    plain session exactly."""

    @staticmethod
    def _workload(explicit: bool):
        qs = []
        for i in range(3):
            arr = UniformWindowArrival(wind_start=2.0 * i,
                                       wind_end=2.0 * i + 12.0,
                                       num_tuples_total=10)
            q = Query(f"q{i}", arr.wind_start, arr.wind_end,
                      arr.wind_end + 40.0, 10,
                      LinearCostModel(tuple_cost=0.4, overhead=0.3,
                                      agg_per_batch=0.2), arr)
            if explicit:
                q = dataclasses.replace(q, tier=0, shed=True)
            qs.append(q)
        return qs

    @pytest.mark.parametrize("policy_name", sorted(list_policies()))
    def test_trace_identical_all_policies(self, policy_name):
        base = Planner(policy=policy_name).run(self._workload(False))
        explicit = Planner(policy=policy_name).run(self._workload(True))
        assert base.executions == explicit.executions
        assert base.outcomes == explicit.outcomes

    @pytest.mark.parametrize("policy_name",
                             ["llf-dynamic", "edf-dynamic", "single"])
    def test_feasible_session_identical_with_overload_enabled(
            self, policy_name):
        def drive(**kw):
            s = Session(policy=policy_name, **kw)
            for q in self._workload(False):
                assert s.submit(q).admitted
            return s.run_until(200.0)

        plain = drive()
        armed = drive(overload=True)
        assert plain.executions == armed.executions
        assert plain.outcomes == armed.outcomes
        assert not armed.events_for("shed")


class TestSampledScansRealBackend:
    def test_shed_aggregate_is_scaled_estimate(self):
        """Real segagg backend: a shed query's batches fetch the
        systematically sampled files and weight records by the inverse keep
        rate — with identical files the estimate is EXACT, proving the
        scaling is applied."""
        np = pytest.importorskip("numpy")
        from repro.core.runtime import run as run_loop
        from repro.data.tpch import AnalyticsQuery, StreamScale
        from repro.serve.analytics import AnalyticsRuntimeExecutor

        rows = 16
        files = [{"k": np.arange(rows) % 4,
                  "v": np.ones((rows, 1), np.float32)} for _ in range(8)]
        aq = AnalyticsQuery("cnt", "orders", lambda sc: 4,
                            key_fn=lambda b: b["k"],
                            value_fn=lambda b: b["v"])
        arr = TraceArrival(timestamps=tuple(float(t) for t in range(8)))
        q = Query("cnt", 0.0, 7.0, 100.0, 8,
                  LinearCostModel(tuple_cost=1.0), arr)
        thin, cum, _ = apply_shed(q, 0.5)
        assert thin.num_tuples_total == 4

        def result(query):
            ex = AnalyticsRuntimeExecutor({"cnt": (aq, files)},
                                          StreamScale(scale=0.01))
            run_loop(Planner(policy="llf-dynamic").policy, [query], ex)
            return ex.results["cnt"]

        exact = result(q)
        estimate = result(thin)
        np.testing.assert_allclose(estimate, exact, rtol=1e-5)
        assert float(exact.sum()) == rows * 8
