"""Planner / SchedulingPolicy / registry API tests.

* registry round-trip: every registered name instantiates via get_policy;
* parity: each policy returns byte-identical schedules to the legacy
  ``schedule_*`` function it subsumes — on the §3.1 worked example AND the
  TPC-H benchmark configs (paper §7.1 cost models);
* every legacy shim emits a DeprecationWarning, exactly once per call site.
"""
import contextlib
import warnings

import pytest

from repro.core import (
    ConstantRateArrival,
    DynamicQuerySpec,
    LinearCostModel,
    Plan,
    Planner,
    Query,
    SimulatedExecutor,
    Strategy,
    execute_single,
    get_policy,
    list_policies,
    register_policy,
    run,
    schedule_dynamic,
    schedule_single,
    schedule_via_constraints,
    schedule_with_agg_cost,
    schedule_without_agg_cost,
    brute_force_optimal,
    validate_schedule,
)
from repro.core.policies.constraint import brute_force_search
from repro.core.policies.single import StaticPolicy
from repro.data.tpch import paper_cost_model

EXPECTED_POLICIES = {
    "single", "single-no-agg", "single-agg",
    "constraints", "brute-force",
    "llf-dynamic", "edf-dynamic", "sjf-dynamic", "rr-dynamic",
}


def paper_31_query(deadline: float) -> Query:
    """§3.1 worked example: 10 tuples at 1/s over [1, 10], 2 tuples/unit."""
    arr = ConstantRateArrival(wind_start=1.0, rate=1.0, num_tuples_total=10)
    return Query(f"p{deadline}", 1.0, 10.0, deadline, 10,
                 LinearCostModel(tuple_cost=0.5), arr)


def tpch_query(qid: str, num_files: int = 4500, deadline_frac: float = 0.5,
               cost_model=None) -> Query:
    """One of the paper's §7.1 queries over the 1 file/s stream."""
    cm = cost_model if cost_model is not None else paper_cost_model(qid)
    arr = ConstantRateArrival(wind_start=0.0, rate=1.0,
                              num_tuples_total=num_files)
    return Query(qid, 0.0, arr.wind_end,
                 arr.wind_end + deadline_frac * cm.cost(num_files),
                 num_files, cm, arr)


def tpch_linear(qid: str, **kw) -> Query:
    """Linearized TPC-H cost model (the §3.2 solver requires Eq. (1))."""
    cm = paper_cost_model(qid)
    lin = LinearCostModel(tuple_cost=(cm.cost(4500) - cm.cost(1)) / 4499,
                          overhead=cm.cost(1), agg_per_batch=0.05)
    return tpch_query(qid, cost_model=lin, **kw)


class TestRegistry:
    def test_round_trip_every_name(self):
        names = list_policies()
        assert set(names) == EXPECTED_POLICIES
        for name in names:
            pol = get_policy(name)
            assert pol.name == name
            assert pol.kind in ("static", "dynamic")

    def test_unknown_name_lists_known(self):
        with pytest.raises(KeyError, match="llf-dynamic"):
            get_policy("no-such-policy")

    def test_register_custom_policy(self):
        @register_policy("test-custom")
        class CustomPolicy(StaticPolicy):
            def plan_query(self, query):
                from repro.core.policies.single import plan_single
                return plan_single(query)

        try:
            pol = get_policy("test-custom")
            assert pol.name == "test-custom"
            q = paper_31_query(12.0)
            assert pol.plan(q)[q.query_id] == plan_via_planner(q, "single")
        finally:
            from repro.core import api as _api
            _api._REGISTRY.pop("test-custom", None)

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            @register_policy("single")
            class Clash(StaticPolicy):  # pragma: no cover
                pass

    def test_planner_facade(self):
        planner = Planner(policy="single")
        assert planner.name == "single"
        plan = planner.plan([paper_31_query(12.0), paper_31_query(16.0)])
        assert isinstance(plan, Plan)
        assert plan.policy == "single"
        assert len(plan.query_ids) == 2

    def test_planner_accepts_instance(self):
        pol = get_policy("constraints", max_batches=16)
        assert Planner(policy=pol).name == "constraints"
        with pytest.raises(TypeError):
            Planner(policy=pol, max_batches=16)


def plan_via_planner(q: Query, policy: str, **kw):
    return Planner(policy=policy, **kw).schedule(q)


@contextlib.contextmanager
def _silence():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        yield


class TestParity:
    """Each policy == the legacy function it subsumes, byte-identical."""

    @pytest.mark.parametrize("deadline", [16.0, 15.0, 12.0, 11.0])
    def test_single_paper_cases(self, deadline):
        q = paper_31_query(deadline)
        with _silence():
            legacy = schedule_single(q)
        assert plan_via_planner(q, "single") == legacy

    @pytest.mark.parametrize("qid", ["CQ1", "CQ2", "CQ3", "CQ4", "TPC-Q10"])
    @pytest.mark.parametrize("frac", [0.1, 0.5, 2.0])
    def test_single_tpch(self, qid, frac):
        q = tpch_query(qid, deadline_frac=frac)
        with _silence():
            legacy = schedule_single(q)
        plan = plan_via_planner(q, "single")
        assert plan == legacy
        validate_schedule(q, plan)

    def test_single_no_agg_tpch(self):
        q = tpch_query("CQ3", deadline_frac=1.0)
        with _silence():
            legacy = schedule_without_agg_cost(q, q.deadline)
        assert plan_via_planner(q, "single-no-agg") == legacy

    def test_single_agg_tpch(self):
        q = tpch_query("CQ2", deadline_frac=0.2)
        with _silence():
            legacy = schedule_with_agg_cost(q)
        assert plan_via_planner(q, "single-agg") == legacy

    @pytest.mark.parametrize("qid", ["CQ1", "CQ2", "CQ3", "CQ4"])
    def test_constraints_tpch(self, qid):
        q = tpch_linear(qid, deadline_frac=0.3)
        with _silence():
            legacy = schedule_via_constraints(q)
        assert plan_via_planner(q, "constraints") == legacy

    def test_brute_force_small(self):
        q = paper_31_query(11.0)
        with _silence():
            n, sizes = brute_force_optimal(q)
        plan = plan_via_planner(q, "brute-force")
        assert plan.num_batches == n
        assert tuple(plan.sch_tuples) == sizes
        assert brute_force_search(q) == (n, sizes)

    @pytest.mark.parametrize("strategy", list(Strategy))
    def test_dynamic_tpch(self, strategy):
        def specs():
            return [
                DynamicQuerySpec(query=tpch_query(qid, num_files=900,
                                                  deadline_frac=4.0))
                for qid in ("CQ1", "CQ2", "CQ3")
            ]

        with _silence():
            legacy = schedule_dynamic(specs(), strategy,
                                      delta_rsf=0.5, c_max=30.0)
        policy = get_policy(f"{strategy.value}-dynamic",
                            delta_rsf=0.5, c_max=30.0)
        trace = run(policy, specs(), SimulatedExecutor())
        assert trace.executions == legacy.executions
        assert trace.outcomes == legacy.outcomes

    def test_dynamic_plan_projection_matches_trace(self):
        q = tpch_query("CQ2", num_files=600, deadline_frac=4.0)
        policy = get_policy("llf-dynamic")
        trace = run(policy, [DynamicQuerySpec(query=q)], SimulatedExecutor())
        plan = policy.plan(q)
        realized = [(e.start, e.num_tuples) for e in trace.executions
                    if e.kind == "batch"]
        assert [(b.sched_time, b.num_tuples)
                for b in plan[q.query_id].batches] == realized

    def test_cost_model_override(self):
        q = paper_31_query(16.0)
        fast = LinearCostModel(tuple_cost=0.1)
        plan = Planner(policy="single").plan(q, cost_model=fast)
        assert plan[q.query_id].batches[0].sched_time == pytest.approx(15.0)


class TestDeprecationShims:
    def test_each_shim_warns(self):
        q = paper_31_query(12.0)
        lin = tpch_linear("CQ1", deadline_frac=0.3)
        with pytest.warns(DeprecationWarning, match="schedule_single"):
            plan = schedule_single(q)
        with pytest.warns(DeprecationWarning, match="schedule_with_agg_cost"):
            schedule_with_agg_cost(q)
        with pytest.warns(DeprecationWarning, match="schedule_without_agg_cost"):
            schedule_without_agg_cost(q, q.deadline)
        with pytest.warns(DeprecationWarning, match="schedule_via_constraints"):
            schedule_via_constraints(lin)
        with pytest.warns(DeprecationWarning, match="brute_force_optimal"):
            brute_force_optimal(q)
        with pytest.warns(DeprecationWarning, match="execute_single"):
            execute_single(q, plan)
        with pytest.warns(DeprecationWarning, match="schedule_dynamic"):
            schedule_dynamic([DynamicQuerySpec(query=q)])

    def test_warns_exactly_once_per_call_site(self):
        q = paper_31_query(16.0)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("default")
            for _ in range(3):
                schedule_single(q)  # ONE call site, three calls
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1, [str(w.message) for w in dep]

    def test_distinct_call_sites_each_warn(self):
        q = paper_31_query(16.0)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("default")
            schedule_single(q)  # call site A
            schedule_single(q)  # call site B
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 2

    def test_shim_results_identical_to_policy(self):
        q = paper_31_query(11.0)
        with _silence():
            assert schedule_single(q) == get_policy("single").plan(q)[q.query_id]
