"""Event-heap decision core tests (PR "Event-heap scheduler core").

Headline property: ``runtime="heap"`` (``HeapLoopCore``) produces traces
BYTE-IDENTICAL to the reference scan core for every registered policy —
through the bare runtime loop, executor pools, and full sessions with
withdrawals, shedding, overload control and forecasting.  On top of that:
heap lazy-deletion invariants checked in lockstep against the scan walk,
the PR-6 stale-wake livelock regression driven through the heap path,
vectorized policy-selection parity around the ``_VECTOR_MIN`` crossover,
``find_min_batch_sizes`` vector/scalar parity (values AND error messages),
``DemandLedger`` incremental-vs-rebuilt equivalence, the session's
incremental admission fast path, and the pool's precomputed worker ranks.
"""
import dataclasses

import pytest

from repro.core import (
    ConstantRateArrival,
    DemandLedger,
    DynamicQuerySpec,
    ExecutionTrace,
    ExecutorPool,
    HeapLoopCore,
    InfeasibleDeadline,
    LinearCostModel,
    OverloadConfig,
    Planner,
    Query,
    RecurringQuerySpec,
    Session,
    SimulatedExecutor,
    TenantQuota,
    UniformWindowArrival,
    admission_check,
    edf_order,
    find_min_batch_size,
    find_min_batch_sizes,
    get_policy,
    heap_capable,
    list_policies,
    post_window_condition,
    run,
    work_demand_condition,
)
from repro.core.policies.dynamic import (
    _VECTOR_MIN,
    _vector_select,
    LLFPolicy,
)
from repro.core.runtime import (
    DynamicLoopCore,
    QueryRuntime,
    RuntimeState,
    _core_class,
)

N_TUPLES = 8

DYNAMIC_POLICIES = sorted(
    n for n in list_policies()
    if getattr(get_policy(n), "kind", "static") == "dynamic"
)


def make_query(qid: str, start: float = 0.0, rate: float = 1.0,
               n: int = N_TUPLES, slack: float = 3.0, tier: int = 0,
               submit: float = None) -> Query:
    arr = ConstantRateArrival(wind_start=start, rate=rate, num_tuples_total=n)
    cm = LinearCostModel(tuple_cost=0.4, overhead=0.3, agg_per_batch=0.2)
    return Query(qid, start, arr.wind_end, arr.wind_end + slack * cm.cost(n),
                 n, cm, arr, submit_time=submit, tier=tier)


def churn_specs():
    """A workload exercising every heap event kind: staggered windows,
    a late submission, a strict-tier query, and a mid-run deletion."""
    specs = [DynamicQuerySpec(query=make_query(f"q{i}", start=1.5 * i,
                                               slack=4.0))
             for i in range(4)]
    specs.append(DynamicQuerySpec(
        query=make_query("tiered", start=2.0, tier=1, slack=6.0)))
    specs.append(DynamicQuerySpec(
        query=make_query("late", start=0.0, submit=4.0, slack=6.0)))
    specs.append(DynamicQuerySpec(
        query=make_query("gone", start=0.0, slack=6.0), delete_time=5.0))
    return specs


def _traces_equal(a: ExecutionTrace, b: ExecutionTrace) -> bool:
    return a.executions == b.executions and a.outcomes == b.outcomes


# ---------------------------------------------------------------------------
# Scan/heap trace identity
# ---------------------------------------------------------------------------


class TestHeapScanParity:
    """runtime="heap" is decision-for-decision identical to the scan core."""

    @pytest.mark.parametrize("policy_name", sorted(list_policies()))
    def test_all_policies_trace_identical(self, policy_name):
        def queries():
            return [make_query(f"q{i}", start=float(i), slack=5.0)
                    for i in range(3)]

        scan = Planner(policy=policy_name).run(queries(), runtime="scan")
        heap = Planner(policy=policy_name).run(queries(), runtime="heap")
        assert _traces_equal(scan, heap)

    @pytest.mark.parametrize("policy_name", DYNAMIC_POLICIES)
    def test_churn_workload_trace_identical(self, policy_name):
        """Late submissions, tiers and scheduled deletions flow through the
        admit/delete/ready heaps exactly like the scan walk."""
        policy = get_policy(policy_name)
        scan = run(policy, churn_specs(), SimulatedExecutor(), runtime="scan")
        heap = run(get_policy(policy_name), churn_specs(),
                   SimulatedExecutor(), runtime="heap")
        assert scan.executions, "churn workload must actually run batches"
        assert _traces_equal(scan, heap)

    @pytest.mark.parametrize("policy_name", DYNAMIC_POLICIES)
    def test_pool_trace_identical(self, policy_name):
        def queries():
            return [make_query(f"q{i}", start=float(i), slack=5.0)
                    for i in range(4)]

        planner = Planner(policy=policy_name)
        scan = planner.run(queries(), workers=3, runtime="scan")
        heap = planner.run(queries(), workers=3, runtime="heap")
        assert _traces_equal(scan, heap)

    def test_sharded_dispatch_trace_identical(self):
        """Worker-sharded decisions (shard_across > 1) go through the heap
        core's own shard path — identical shard extents and workers."""
        def queries():
            return [make_query(f"q{i}", start=0.0, n=16, rate=8.0, slack=5.0)
                    for i in range(2)]

        scan = run(get_policy("llf-dynamic", shard_across=2), queries(),
                   ExecutorPool(workers=2), runtime="scan")
        heap = run(get_policy("llf-dynamic", shard_across=2), queries(),
                   ExecutorPool(workers=2), runtime="heap")
        assert any(e.worker for e in scan.executions)
        assert _traces_equal(scan, heap)


class TestCoreSelection:
    """heap_capable / _core_class routing and knob validation."""

    def test_dynamic_policies_are_heap_capable(self):
        for name in DYNAMIC_POLICIES:
            assert heap_capable(get_policy(name)), name

    def test_static_policies_are_not(self):
        for name in sorted(set(list_policies()) - set(DYNAMIC_POLICIES)):
            assert not heap_capable(get_policy(name)), name

    def test_custom_replan_falls_back_to_scan(self):
        class Custom(LLFPolicy):
            def replan(self, event, state):
                return super().replan(event, state)

        policy = Custom()
        assert not heap_capable(policy)
        assert _core_class(policy, "heap") is DynamicLoopCore
        # Capable policy + runtime="heap" is the only heap route.
        assert _core_class(get_policy("llf-dynamic"), "heap") is HeapLoopCore
        assert _core_class(get_policy("llf-dynamic"), "scan") is DynamicLoopCore
        assert _core_class(get_policy("llf-dynamic"), None) is DynamicLoopCore

    def test_bad_runtime_value_raises(self):
        with pytest.raises(ValueError, match="runtime must be"):
            run(get_policy("llf-dynamic"), [make_query("q")],
                runtime="btree")
        with pytest.raises(ValueError, match="runtime must be"):
            Session(runtime="btree")

    def test_bad_admission_value_raises(self):
        with pytest.raises(ValueError, match="admission must be"):
            Session(admission="ledger")


# ---------------------------------------------------------------------------
# Heap bookkeeping invariants (lockstep against the scan definitions)
# ---------------------------------------------------------------------------


def _drive(runtime: str, mutate_at=None):
    """Tick a core over the churn workload, checking heap invariants against
    the walk-based definitions after EVERY tick.  ``mutate_at`` maps tick
    index -> callable(core, state, now) for mid-run external changes."""
    policy = get_policy("llf-dynamic")
    specs = churn_specs()
    runts = [QueryRuntime(spec=s) for s in specs]
    trace = ExecutionTrace()
    executor = SimulatedExecutor()
    executor.reset(min(r.q.submit_time for r in runts))
    state = RuntimeState(runtimes=runts, trace=trace)
    core = _core_class(policy, runtime)(policy, executor, state,
                                        c_max=policy.c_max)
    statuses = []
    for i in range(2000):
        if mutate_at and i in mutate_at:
            mutate_at[i](core, state, executor.clock())
        status = core.tick()
        statuses.append(status)
        if isinstance(core, HeapLoopCore):
            active = state.active()
            unadmitted = [r for r in state.runtimes
                          if not r.admitted and not r.deleted]
            assert core._num_active == len(active)
            assert core._num_unadmitted == len(unadmitted)
            # Pool members are always live (lazy deletion never leaves a
            # dead runtime competing for the executor).
            for idx in core._ready_pool:
                rt = state.runtimes[idx]
                assert rt.admitted and not (rt.completed or rt.deleted)
            # drained() from counters == drained() from the scan walk.
            assert core.drained() == (
                not active and all(r.admitted or r.deleted
                                   for r in state.runtimes))
        if status in ("done", "stop"):
            break
    return trace, statuses


class TestHeapInvariants:
    def test_counters_match_walk_every_tick(self):
        trace, statuses = _drive("heap")
        assert statuses[-1] == "done"
        assert trace.outcomes  # deleted runtime emits no outcome; rest do

    def test_statuses_match_scan_tick_for_tick(self):
        scan_trace, scan_statuses = _drive("scan")
        heap_trace, heap_statuses = _drive("heap")
        assert scan_statuses == heap_statuses
        assert _traces_equal(scan_trace, heap_trace)

    def test_lazy_deletion_with_duplicate_events(self):
        """Repeated notify() pushes duplicate delete-heap entries; stale
        entries must be skipped on pop and the deletion applied once."""
        def withdraw(core, state, now):
            rt = state.by_id("q3")
            rt.spec.delete_time = now
            core.notify(rt)
            core.notify(rt)  # duplicate lazy-deletion event
            core.notify(rt)

        scan_trace, _ = _drive("scan", mutate_at={4: withdraw})
        heap_trace, _ = _drive("heap", mutate_at={4: withdraw})
        assert all(o.query_id != "q3" for o in heap_trace.outcomes)
        assert _traces_equal(scan_trace, heap_trace)

    def test_future_delete_event_is_honored_once_due(self):
        """A delete_time pushed for a FUTURE instant sits in the heap until
        due; revoking it (delete_time=None) makes the entry stale."""
        def schedule_then_revoke(core, state, now):
            rt = state.by_id("q2")
            rt.spec.delete_time = now + 0.5
            core.notify(rt)
            rt.spec.delete_time = None  # the heap entry is now stale

        _, _ = _drive("heap", mutate_at={3: schedule_then_revoke})
        trace, _ = _drive("heap", mutate_at={3: schedule_then_revoke})
        assert any(o.query_id == "q2" for o in trace.outcomes)

    def test_minbatch_resize_notify_parity(self):
        """An external MinBatch resize (shed/recalibrate path) re-indexes
        readiness via notify(); traces still match the scan."""
        def resize(core, state, now):
            rt = state.by_id("q1")
            if not rt.completed and not rt.deleted:
                rt.min_batch = max(1, rt.min_batch - 1)
                core.notify(rt)

        scan_trace, _ = _drive("scan", mutate_at={5: resize})
        heap_trace, _ = _drive("heap", mutate_at={5: resize})
        assert _traces_equal(scan_trace, heap_trace)


# ---------------------------------------------------------------------------
# PR-6 stale-wake livelock regression, through the heap path
# ---------------------------------------------------------------------------


SPAN = 50.0


def burst_truth_spec(qid: str = "r", n: int = 40, windows: int = 3,
                     slack: float = 30.0) -> RecurringQuerySpec:
    """Predicted uniform, truth bursty: every window's tuples land in the
    last 10 time units — the PR-6 livelock shape (predicted readiness
    passes long before the truth stream delivers, so a stale wake instant
    must not eps-step the wait loop)."""
    base = Query(
        query_id=qid, wind_start=0.0, wind_end=SPAN, deadline=SPAN + slack,
        num_tuples_total=n,
        cost_model=LinearCostModel(tuple_cost=0.2, overhead=0.1,
                                   agg_per_batch=0.1),
        arrival=UniformWindowArrival(wind_start=0.0, wind_end=SPAN,
                                     num_tuples_total=n),
    )

    def truth(w):
        start = w * SPAN
        return UniformWindowArrival(wind_start=start + SPAN - 10.0,
                                    wind_end=start + SPAN,
                                    num_tuples_total=n)

    return RecurringQuerySpec(base=base, period=SPAN, num_windows=windows,
                              truth_factory=truth)


class TestStaleWakeLivelock:
    def _session_trace(self, runtime):
        session = Session(policy="llf-dynamic", runtime=runtime,
                          admission_control=False)
        session.submit(burst_truth_spec())
        # A livelocked wait loop would eps-step and exhaust max_steps long
        # before the horizon; the bound is the regression assertion.
        return session.run_until(SPAN * 3 + 40.0, max_steps=5_000)

    def test_heap_completes_within_step_bound(self):
        trace = self._session_trace("heap")
        assert len(trace.outcomes) == 3  # every window closed

    def test_heap_matches_scan_on_bursty_truth(self):
        scan = self._session_trace("scan")
        heap = self._session_trace("heap")
        assert _traces_equal(scan, heap)

    def test_bare_loop_bursty_truth_parity(self):
        """Same shape through run(): truth arrivals later than predicted."""
        def specs():
            q = make_query("b", n=20, rate=1.0, slack=6.0)
            truth = UniformWindowArrival(wind_start=q.wind_end - 4.0,
                                         wind_end=q.wind_end,
                                         num_tuples_total=20)
            return [DynamicQuerySpec(query=q, truth=truth)]

        policy = get_policy("llf-dynamic")
        scan = run(policy, specs(), SimulatedExecutor(), runtime="scan",
                   max_steps=5_000)
        heap = run(policy, specs(), SimulatedExecutor(), runtime="heap",
                   max_steps=5_000)
        assert scan.outcomes and _traces_equal(scan, heap)


# ---------------------------------------------------------------------------
# Session parity: heap + incremental admission under the full feature set
# ---------------------------------------------------------------------------


class TestSessionHeapParity:
    def _workload(self):
        specs = []
        for i in range(4):
            base = make_query(f"r{i}", start=2.0 * i, n=6, slack=6.0,
                              tier=i % 2)
            specs.append(RecurringQuerySpec(base=base, period=30.0,
                                            num_windows=2))
        return specs

    def _run(self, runtime, admission="snapshot"):
        session = Session(policy="llf-dynamic", workers=2, overload=True,
                          runtime=runtime, admission=admission)
        for spec in self._workload():
            session.submit(spec)
        session.run_until(20.0)
        session.withdraw("r2")  # mid-run withdrawal through the delete heap
        session.run_until(100.0)
        return session.trace

    def test_overload_withdraw_pool_parity(self):
        scan = self._run("scan")
        heap = self._run("heap")
        incr = self._run("heap", admission="incremental")
        assert scan.executions
        assert _traces_equal(scan, heap)
        assert _traces_equal(scan, incr)

    def test_forecast_session_parity(self):
        def go(runtime):
            session = Session(policy="llf-dynamic", runtime=runtime,
                              overload=True, forecast=True)
            session.submit(burst_truth_spec(slack=20.0))
            return session.run_until(SPAN * 3 + 40.0, max_steps=10_000)

        assert _traces_equal(go("scan"), go("heap"))

    def test_calibrating_session_parity(self):
        cm_true = LinearCostModel(tuple_cost=0.6, overhead=0.45,
                                  agg_per_batch=0.3)

        def go(runtime):
            session = Session(policy="llf-dynamic", calibrate=True,
                              runtime=runtime)
            base = make_query("d", n=20, rate=2.0, slack=4.0)
            session.submit(RecurringQuerySpec(base=base, period=30.0,
                                              num_windows=3,
                                              true_cost_model=cm_true))
            return session.run_until(120.0)

        assert _traces_equal(go("scan"), go("heap"))


class TestTenantChurnParity:
    """Tenant identity, quotas and cascades ride the same decision loop:
    scan and heap traces stay byte-identical under tenant submissions,
    mid-run quota changes (``set_quota`` → rebalance/shed) and tenanted
    withdrawals."""

    def _run(self, runtime):
        session = Session(
            policy="llf-dynamic", runtime=runtime,
            overload=OverloadConfig(max_shed=0.9, max_error_bound=5.0),
            tenancy={"t0": TenantQuota(weight=2.0)})
        for i, tenant in enumerate(("t0", "t1", "t2")):
            base = make_query(f"r{i}", start=2.0 * i, n=6, slack=6.0,
                              tier=i % 2)
            base = dataclasses.replace(base, tenant=tenant)
            session.submit(RecurringQuerySpec(base=base, period=30.0,
                                              num_windows=2))
        session.run_until(10.0)
        # Quota churn: tighten one tenant (its own windows shed against the
        # new share), then a late tenanted submission, then withdraw+relax.
        session.set_quota("t1", TenantQuota(weight=0.5, capacity=0.4))
        late = dataclasses.replace(make_query("late", start=12.0, n=4,
                                              slack=6.0), tenant="t2")
        session.submit(late)
        session.run_until(20.0)
        session.withdraw("r2")
        session.set_quota("t1", None)
        session.run_until(100.0)
        return session.trace

    def test_scan_heap_identical_under_tenant_churn(self):
        scan = self._run("scan")
        heap = self._run("heap")
        assert scan.executions
        assert _traces_equal(scan, heap)

    def test_cascade_defer_parity(self):
        """A deferred (cascaded) window flows through both cores' admit
        paths at the same instants."""
        def go(runtime):
            session = Session(policy="llf-dynamic", runtime=runtime)
            silver = make_query("silver", start=0.0, n=6, slack=6.0)
            session.submit(RecurringQuerySpec(base=silver, period=30.0,
                                              num_windows=2))
            gold = dataclasses.replace(
                make_query("gold", start=0.0, n=4, slack=40.0),
                upstream="silver")
            session.submit(RecurringQuerySpec(base=gold, period=60.0,
                                              num_windows=1))
            session.run_until(120.0)
            return session.trace

        scan, heap = go("scan"), go("heap")
        assert any(o.query_id.startswith("gold") for o in scan.outcomes)
        assert _traces_equal(scan, heap)


# ---------------------------------------------------------------------------
# Vectorized policy selection
# ---------------------------------------------------------------------------


def ready_set(width: int, now: float = 6.0, cost_model=None):
    """``width`` admitted, ready runtimes with clashing deadlines, mixed
    tiers and rotated rr tickets — enough structure to catch any ordering
    divergence between the lexsort and the Python keys."""
    cm = cost_model or LinearCostModel(tuple_cost=0.01, overhead=0.02,
                                       agg_per_batch=0.01)
    ready = []
    for i in range(width):
        arr = ConstantRateArrival(wind_start=0.0, rate=10.0,
                                  num_tuples_total=50)
        q = Query(f"q{i}", 0.0, arr.wind_end,
                  deadline=20.0 + (i % 7), num_tuples_total=50,
                  cost_model=cm, arrival=arr, tier=i % 3,
                  latency_target=(5.0 if i % 5 == 0 else None))
        rt = QueryRuntime(spec=DynamicQuerySpec(query=q), min_batch=3,
                          processed=i % 4, admitted=True,
                          rr_seq=(width - i) % width)
        assert rt.ready(now)
        ready.append(rt)
    return ready


class TestVectorSelectParity:
    WIDTHS = (3, _VECTOR_MIN - 1, _VECTOR_MIN, _VECTOR_MIN + 1, 200)

    @pytest.mark.parametrize("policy_name", ["llf-dynamic", "edf-dynamic",
                                             "sjf-dynamic", "rr-dynamic"])
    @pytest.mark.parametrize("width", WIDTHS)
    def test_winner_matches_python_keys(self, policy_name, width):
        policy = get_policy(policy_name)
        now = 6.0
        ready = ready_set(width, now)
        scalar = min(ready,
                     key=lambda r: (r.q.tier, *policy.priority(r, now)))
        assert policy.select(ready, now) is scalar
        i = _vector_select(policy, ready, now)  # forced, any width
        assert i is not None and ready[i] is scalar

    def test_unpackable_rows_fall_back(self):
        class WrappedLinear(LinearCostModel):
            pass

        policy = get_policy("llf-dynamic")
        now = 6.0
        ready = ready_set(
            _VECTOR_MIN + 5, now,
            cost_model=WrappedLinear(tuple_cost=0.01, overhead=0.02,
                                     agg_per_batch=0.01))
        assert _vector_select(policy, ready, now) is None
        scalar = min(ready,
                     key=lambda r: (r.q.tier, *policy.priority(r, now)))
        assert policy.select(ready, now) is scalar

    def test_custom_priority_falls_back(self):
        class Custom(LLFPolicy):
            def priority(self, rt, now):
                return (rt.q.deadline,)

        now = 6.0
        ready = ready_set(_VECTOR_MIN + 5, now)
        policy = Custom()
        assert _vector_select(policy, ready, now) is None
        assert policy.select(ready, now) is min(
            ready, key=lambda r: (r.q.tier, *policy.priority(r, now)))


# ---------------------------------------------------------------------------
# Vectorized MinBatch sizing
# ---------------------------------------------------------------------------


class TestFindMinBatchSizes:
    MODELS = [
        LinearCostModel(tuple_cost=0.4, overhead=0.3, agg_per_batch=0.2),
        LinearCostModel(tuple_cost=0.05, overhead=1.0, agg_per_batch=0.0),
        LinearCostModel(tuple_cost=1.0, overhead=0.0, agg_per_batch=0.5,
                        agg_overhead=0.3),
        LinearCostModel(tuple_cost=0.001, overhead=0.02,
                        agg_per_batch=0.004),
    ]

    @pytest.mark.parametrize("delta", [0.1, 0.5, 2.0])
    @pytest.mark.parametrize("c_max", [3.0, 30.0, 1e6])
    def test_elementwise_parity(self, delta, c_max):
        ns = [0, 1, 2, 7, 64, 1000]
        rows = [(n, m) for n in ns for m in self.MODELS]
        groups = [(i % 4) for i in range(len(rows))]
        try:
            expect = [find_min_batch_size(n, m, delta, c_max, g)
                      for (n, m), g in zip(rows, groups)]
        except InfeasibleDeadline as e:
            with pytest.raises(InfeasibleDeadline) as ei:
                find_min_batch_sizes([n for n, _ in rows],
                                     [m for _, m in rows], delta, c_max,
                                     groups)
            assert str(ei.value) == str(e)
            return
        got = find_min_batch_sizes([n for n, _ in rows],
                                   [m for _, m in rows], delta, c_max,
                                   groups)
        assert got == expect

    def test_error_message_parity_first_row_wins(self):
        cm = LinearCostModel(tuple_cost=5.0, overhead=1.0)
        with pytest.raises(InfeasibleDeadline) as scalar:
            find_min_batch_size(10, cm, 0.5, 2.0)
        with pytest.raises(InfeasibleDeadline) as vector:
            find_min_batch_sizes([4, 10, 10], [self.MODELS[0], cm, cm],
                                 0.5, 2.0)
        assert str(vector.value) == str(scalar.value)

    def test_non_linear_models_fall_back_to_scalar(self):
        class Quirk(LinearCostModel):
            pass

        models = [self.MODELS[0], Quirk(tuple_cost=0.4, overhead=0.3)]
        got = find_min_batch_sizes([64, 64], models, 0.5, 30.0)
        assert got == [find_min_batch_size(64, m, 0.5, 30.0) for m in models]

    def test_empty_input(self):
        assert find_min_batch_sizes([], [], 0.5, 30.0) == []

    def test_length_mismatch_raises(self):
        with pytest.raises(ValueError):
            find_min_batch_sizes([1, 2], self.MODELS[:1], 0.5, 30.0)


# ---------------------------------------------------------------------------
# Incremental admission: DemandLedger + session fast path
# ---------------------------------------------------------------------------


def deadline_spread(k: int = 6):
    qs = []
    for i in range(k):
        q = make_query(f"a{i}", start=2.0 * i, slack=2.0 + (i % 3))
        if i in (2, 4):  # deadline ties exercise the stable EDF merge
            q = dataclasses.replace(q, deadline=qs[1].deadline)
        qs.append(q)
    return qs


class TestDemandLedger:
    def test_incremental_equals_rebuilt(self):
        qs = deadline_spread()
        ledger = DemandLedger()
        for q in qs:
            ledger.add(q)
        ledger.discard("a3")
        resized = dataclasses.replace(qs[1], deadline=qs[1].deadline + 4.0)
        ledger.update(resized)
        live = [q for q in qs if q.query_id not in ("a1", "a3")] + [resized]
        rebuilt = DemandLedger(live)
        assert [q.query_id for q in ledger.queries] == [
            q.query_id for q in rebuilt.queries]
        for now in (None, 3.0):
            assert ledger.work_demand(now=now) == rebuilt.work_demand(now=now)
            assert ledger.post_window(now=now) == rebuilt.post_window(now=now)
            assert ledger.check(now=now) == rebuilt.check(now=now)

    def test_matches_scalar_conditions(self):
        qs = deadline_spread()
        ledger = DemandLedger(qs)
        for now in (None, 1.0):
            assert ledger.work_demand(now=now) == work_demand_condition(
                edf_order(qs), now)
            assert ledger.post_window(now=now) == post_window_condition(
                edf_order(qs), now)

    def test_extra_merge_does_not_mutate(self):
        qs = deadline_spread(4)
        ledger = DemandLedger(qs[:3])
        extra = [qs[3], dataclasses.replace(qs[0], query_id="dup",
                                            deadline=qs[1].deadline)]
        merged = ledger.check(extra=extra, now=0.0)
        assert merged == DemandLedger(qs[:3] + extra).check(now=0.0)
        assert len(ledger) == 3 and "dup" not in ledger

    def test_admission_check_ledger_vs_snapshot(self):
        """Full-window rows: the ledger path must agree with the snapshot
        path when the active set IS its full windows (fresh admission)."""
        qs = deadline_spread()
        ledger = DemandLedger(qs[:-1])
        incoming = [qs[-1]]
        fast = admission_check(incoming, c_max=30.0, now=0.0, ledger=ledger)
        exact = admission_check(incoming, qs[:-1], c_max=30.0, now=0.0)
        assert fast.feasible == exact.feasible
        assert fast.reasons == exact.reasons

    def test_edf_order_is_stable(self):
        qs = deadline_spread()
        ordered = edf_order(qs)
        assert [q.deadline for q in ordered] == sorted(
            q.deadline for q in qs)
        ties = [q.query_id for q in ordered
                if q.deadline == qs[1].deadline]
        submitted = [q.query_id for q in qs if q.deadline == qs[1].deadline]
        assert ties == submitted  # equal deadlines keep submission order


class TestSessionIncrementalAdmission:
    def _submit_all(self, admission):
        session = Session(policy="llf-dynamic", admission=admission)
        verdicts = []
        # Feasible spread, then a hopeless deadline that must be REJECTED
        # identically (incremental falls back to the exact snapshot path
        # before rejecting).
        for q in deadline_spread(4):
            verdicts.append(session.submit(q).admitted)
        doomed = make_query("doomed", start=0.0, n=50, rate=10.0)
        doomed = dataclasses.replace(doomed, deadline=doomed.wind_end + 0.05)
        res = session.submit(doomed)
        verdicts.append(res.admitted)
        return session, verdicts, res

    def test_same_verdicts_and_traces(self):
        snap, v_snap, r_snap = self._submit_all("snapshot")
        incr, v_incr, r_incr = self._submit_all("incremental")
        assert v_snap == v_incr
        assert v_snap[-1] is False  # the doomed one was rejected by both
        assert r_snap.report == r_incr.report  # exact-path reasons, verbatim
        assert _traces_equal(snap.run(), incr.run())

    def test_ledger_tracks_window_lifecycle(self):
        session = Session(policy="llf-dynamic", admission="incremental")
        session.submit(RecurringQuerySpec(base=make_query("r", slack=6.0),
                                          period=30.0, num_windows=2))
        ledger = session._runtime._ledger
        assert len(ledger) == 1  # window 0 registered on submit
        session.run()
        assert len(ledger) == 0  # closed windows discharged


# ---------------------------------------------------------------------------
# ExecutorPool worker ranks
# ---------------------------------------------------------------------------


class TestPoolRank:
    def test_tie_break_is_declaration_order_not_lexicographic(self):
        pool = ExecutorPool(names=("zeta", "alpha"))
        assert pool.earliest_free() == "zeta"
        assert pool.earliest_free(exclude=["zeta"]) == "alpha"

    def test_rank_map_matches_names(self):
        pool = ExecutorPool(workers=4)
        assert pool._rank == {n: i for i, n in enumerate(pool.worker_names)}


# ---------------------------------------------------------------------------
# Hypothesis sweep (gated; slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestHeapParitySweep:
    def test_random_workloads_scan_heap_identical(self):
        pytest.importorskip("hypothesis", reason="hypothesis not installed")
        from hypothesis import given, settings, strategies as st

        rows = st.lists(
            st.tuples(
                st.floats(min_value=0.0, max_value=6.0),   # window start
                st.integers(min_value=1, max_value=12),    # tuples
                st.floats(min_value=0.5, max_value=4.0),   # rate
                st.floats(min_value=1.0, max_value=5.0),   # slack
                st.integers(min_value=0, max_value=2),     # tier
                st.floats(min_value=0.0, max_value=4.0),   # submit delay
                st.one_of(st.none(),
                          st.floats(min_value=1.0, max_value=8.0)),  # delete
            ),
            min_size=1, max_size=6,
        )

        @settings(max_examples=40, deadline=None)
        @given(rows=rows, policy_name=st.sampled_from(DYNAMIC_POLICIES))
        def check(rows, policy_name):
            def specs():
                out = []
                for i, (start, n, rate, slack, tier, delay, dele) in \
                        enumerate(rows):
                    q = make_query(f"q{i}", start=start, n=n, rate=rate,
                                   slack=slack, tier=tier,
                                   submit=start + delay)
                    out.append(DynamicQuerySpec(
                        query=q,
                        delete_time=None if dele is None else start + dele))
                return out

            scan = run(get_policy(policy_name), specs(),
                       SimulatedExecutor(), runtime="scan", max_steps=20_000)
            heap = run(get_policy(policy_name), specs(),
                       SimulatedExecutor(), runtime="heap", max_steps=20_000)
            assert _traces_equal(scan, heap)

        check()

    def test_random_tenant_churn_scan_heap_identical(self):
        """Session-level sweep: random tenant assignments, a mid-run quota
        change and a withdrawal — the tenancy layer acts only through
        admission/shedding, so both cores see identical decision streams."""
        pytest.importorskip("hypothesis", reason="hypothesis not installed")
        from hypothesis import given, settings, strategies as st

        rows = st.lists(
            st.tuples(
                st.floats(min_value=0.0, max_value=4.0),   # window start
                st.integers(min_value=2, max_value=8),     # tuples
                st.floats(min_value=3.0, max_value=8.0),   # slack
                st.integers(min_value=0, max_value=1),     # tier
                st.integers(min_value=0, max_value=2),     # tenant index
            ),
            min_size=1, max_size=4,
        )
        quota = st.tuples(
            st.integers(min_value=0, max_value=2),         # tenant index
            st.floats(min_value=0.3, max_value=3.0),       # new weight
            st.one_of(st.none(),
                      st.floats(min_value=0.2, max_value=0.9)),  # capacity
        )

        @settings(max_examples=25, deadline=None)
        @given(rows=rows, quota=quota,
               withdraw=st.integers(min_value=0, max_value=3))
        def check(rows, quota, withdraw):
            def go(runtime):
                session = Session(
                    policy="llf-dynamic", runtime=runtime,
                    overload=OverloadConfig(max_shed=0.9,
                                            max_error_bound=5.0),
                    tenancy={"t0": TenantQuota(weight=2.0)})
                for i, (start, n, slack, tier, t) in enumerate(rows):
                    base = dataclasses.replace(
                        make_query(f"r{i}", start=start, n=n, slack=slack,
                                   tier=tier),
                        tenant=f"t{t}")
                    session.submit(RecurringQuerySpec(base=base, period=25.0,
                                                      num_windows=2))
                session.run_until(8.0)
                ti, w, cap = quota
                session.set_quota(f"t{ti}", TenantQuota(weight=w,
                                                        capacity=cap))
                if withdraw < len(rows):
                    session.withdraw(f"r{withdraw}")
                session.run_until(80.0)
                return session.trace

            assert _traces_equal(go("scan"), go("heap"))

        check()
