"""Device-mesh execution layer (repro.dist.mesh + the WorkerBackend seam).

Pinned properties:

* extents <-> spec consistency — ``batch_shard_extents`` over the device
  count produces exactly the per-device row splits ``batch_spec`` encodes
  when divisible, and the replicated fallback fires a ``sharding_fallback``
  event when it does not;
* shard_map parity — ``DeviceMesh.segagg``/``pane_segagg`` are exactly
  equal (integer-valued f32) to the single-device references on 1-, 2- and
  8-device meshes (multi-device cases skip unless the host exposes the
  devices; CI forces 8 via XLA_FLAGS);
* the pool's dispatch seam — ``ExecutorPool(worker_backend=...)`` delegates
  to any ``WorkerBackend`` while the legacy modelled path stays identical;
* weighted sharding + per-worker calibration — largest-remainder extents,
  ``CalibratingCostModel.worker_scale``/``worker_weights``, and
  ``MeshBackend``'s measured-heterogeneity gate.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    ExecutorPool,
    LinearCostModel,
    Query,
    ShardedCostModel,
    SimulatedExecutor,
    TraceArrival,
    get_policy,
    run,
)
from repro.core.cost_model import CalibratingCostModel
from repro.core.runtime import Dispatch, ModelledWorkerBackend, WorkerBackend
from repro.data.tpch import PAPER_QUERIES, StreamScale, stream_files
from repro.dist import (
    DeviceMesh,
    MeshBackend,
    on_fallback,
    weighted_shard_extents,
)
from repro.dist.sharding import batch_shard_extents, batch_spec
from repro.kernels.segagg.ref import pane_segagg_ref, segagg_ref
from repro.serve.analytics import MeshAnalyticsBackend, run_batched

NDEV = jax.device_count()


def needs_devices(k: int):
    return pytest.mark.skipif(
        NDEV < k,
        reason=f"needs {k} jax devices (have {NDEV}); set "
               f"XLA_FLAGS=--xla_force_host_platform_device_count={k}",
    )


def int_valued(rng, n, v=3):
    """Integer-valued f32 rows: sums are exact regardless of association,
    so mesh-vs-reference parity can assert EXACT equality."""
    return rng.integers(0, 8, size=(n, v)).astype(np.float32)


# ---------------------------------------------------------------------------
# extents <-> batch_spec consistency
# ---------------------------------------------------------------------------


class TestExtents:
    @pytest.mark.parametrize("n,ways,expect", [
        (8, 2, ((0, 4), (4, 4))),
        (7, 2, ((0, 4), (4, 3))),
        (3, 8, ((0, 1), (1, 1), (2, 1))),   # empty shards dropped
        (0, 4, ()),
    ])
    def test_batch_shard_extents(self, n, ways, expect):
        assert batch_shard_extents(n, ways) == expect

    @pytest.mark.parametrize("n", [0, 1, 7, 8, 64, 100])
    @pytest.mark.parametrize("ways", [1, 2, 3, 8])
    def test_equal_weights_reduce_to_unweighted(self, n, ways):
        weighted = tuple(e for e in weighted_shard_extents(n, [1.0] * ways)
                         if e[1] > 0)
        assert weighted == batch_shard_extents(n, ways)

    def test_weighted_proportions_and_alignment(self):
        # ideal 7.5 / 2.5 -> floors 7/2, leftover to the tied-earliest.
        assert weighted_shard_extents(10, [3.0, 1.0]) == ((0, 8), (8, 2))
        # zero-weight workers keep their (empty) slot for 1:1 zipping.
        ext = weighted_shard_extents(6, [1.0, 0.0, 2.0])
        assert ext == ((0, 2), (2, 0), (2, 4))
        assert sum(s for _, s in ext) == 6

    def test_weighted_validation(self):
        with pytest.raises(ValueError):
            weighted_shard_extents(-1, [1.0])
        with pytest.raises(ValueError):
            weighted_shard_extents(4, [])
        with pytest.raises(ValueError):
            weighted_shard_extents(4, [0.0, 0.0])
        with pytest.raises(ValueError):
            weighted_shard_extents(4, [1.0, -1.0])


class TestExtentsSpecConsistency:
    """The pool's 1-D splits and the mesh's NamedShardings agree."""

    @pytest.mark.parametrize("devices", [1, 2, 8])
    def test_divisible_rows_match_spec_shards(self, devices):
        if NDEV < devices:
            pytest.skip(f"needs {devices} devices")
        mesh = DeviceMesh(devices)
        n = devices * 6
        extents = mesh.shard_extents(n)
        assert len(extents) == devices
        assert all(size == n // devices for _, size in extents)
        # batch_spec shards dim 0 over the data axis for the same rows.
        spec = batch_spec(mesh.mesh, n, 2)
        assert spec[0] == "data" and spec[1] is None
        sharding = mesh.batch_sharding(n, 2)
        assert mesh.events == []  # no fallback on the divisible path
        # Per-device row ranges of the NamedSharding == the pool extents.
        if devices > 1:
            idx = sharding.addressable_devices_indices_map((n, 3))
            rows = sorted(
                (sl[0].start or 0, (sl[0].stop or n) - (sl[0].start or 0))
                for sl in idx.values()
            )
            assert tuple(rows) == extents

    @needs_devices(2)
    def test_non_divisible_rows_fall_back_with_event(self):
        seen = []
        mesh = DeviceMesh(2, on_event=seen.append)
        sharding = mesh.batch_sharding(7, 2)
        # Replicated: nothing sharded, and the fallback was reported.
        assert sharding.spec == jax.sharding.PartitionSpec(None, None)
        assert [e["kind"] for e in mesh.events] == ["sharding_fallback"]
        assert seen == mesh.events
        # ...while the pool extents still cover all 7 tuples unevenly.
        assert mesh.shard_extents(7) == ((0, 4), (4, 3))

    def test_on_fallback_unsubscribe(self):
        events = []
        unsub = on_fallback(events.append)
        unsub()
        unsub()  # idempotent
        mesh = DeviceMesh(1)
        mesh.batch_sharding(7, 1)
        assert events == []


# ---------------------------------------------------------------------------
# shard_map parity
# ---------------------------------------------------------------------------


class TestDeviceMeshParity:
    @pytest.mark.parametrize("devices", [1, 2, 8])
    @pytest.mark.parametrize("n", [64, 100])  # 100: padding path on 8 dev
    def test_segagg_matches_reference(self, devices, n):
        if NDEV < devices:
            pytest.skip(f"needs {devices} devices")
        rng = np.random.default_rng(devices * 1000 + n)
        G = 16
        keys = rng.integers(0, G, size=n).astype(np.int32)
        vals = int_valued(rng, n)
        ref = np.asarray(segagg_ref(keys, vals, G))
        got = np.asarray(DeviceMesh(devices).segagg(keys, vals.copy(), G))
        assert np.array_equal(got, ref)

    @pytest.mark.parametrize("devices", [1, 2, 8])
    def test_pane_segagg_matches_reference(self, devices):
        if NDEV < devices:
            pytest.skip(f"needs {devices} devices")
        rng = np.random.default_rng(7)
        n, P, G = 90, 5, 8
        keys = rng.integers(0, G, size=n).astype(np.int32)
        panes = rng.integers(0, P, size=n).astype(np.int32)
        vals = int_valued(rng, n, v=2)
        ref = np.asarray(pane_segagg_ref(keys, vals, panes, P, G))
        got = np.asarray(
            DeviceMesh(devices).pane_segagg(keys, vals.copy(), panes, P, G)
        )
        assert np.array_equal(got, ref)

    def test_1d_values_and_empty_batch(self):
        mesh = DeviceMesh(1)
        out = np.asarray(mesh.segagg(
            np.array([0, 1, 1], np.int32), np.array([1.0, 2.0, 3.0]), 4))
        assert out.shape == (4, 1)
        assert np.array_equal(out[:, 0], [1.0, 5.0, 0.0, 0.0])

    def test_device_count_validation(self):
        with pytest.raises(ValueError):
            DeviceMesh(0)
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            DeviceMesh(NDEV + 1)
        with pytest.raises(ValueError):
            DeviceMesh([])


# ---------------------------------------------------------------------------
# the WorkerBackend seam
# ---------------------------------------------------------------------------


class StubBackend(WorkerBackend):
    """Deterministic WorkerBackend: every batch takes ``dur`` modelled
    seconds, aggregation is free; records every physical call."""

    def __init__(self, names, dur=2.0):
        super().__init__(names)
        self.dur = dur
        self.calls = []

    def run_batch(self, query, num_tuples, offset, worker):
        self.calls.append(("batch", worker, num_tuples, offset))
        start = self._clocks[worker]
        end = start + self.dur
        self._clocks[worker] = end
        return Dispatch(worker=worker, start=start, end=end), self.dur

    def run_agg(self, query, num_batches, worker, start, barrier):
        self.calls.append(("agg", worker, num_batches))
        return Dispatch(worker=worker, start=barrier, end=barrier), 0.0


def fixed_query(qid="q0", n=8, slack=3.0):
    arr = TraceArrival(timestamps=tuple(float(i) for i in range(n)))
    cm = LinearCostModel(tuple_cost=0.4, overhead=0.3, agg_per_batch=0.2)
    return Query(qid, arr.wind_start, arr.wind_end,
                 arr.wind_end + slack * cm.cost(n), n, cm, arr)


class TestPoolSeam:
    def test_worker_backend_exclusive_with_legacy_args(self):
        wb = StubBackend(("a", "b"))
        with pytest.raises(TypeError, match="not both"):
            ExecutorPool(backend=SimulatedExecutor(), worker_backend=wb)
        with pytest.raises(ValueError, match="declares its own workers"):
            ExecutorPool(workers=2, worker_backend=wb)
        with pytest.raises(ValueError, match="declares its own workers"):
            ExecutorPool(names=("x",), worker_backend=wb)

    def test_legacy_pool_uses_modelled_backend(self):
        pool = ExecutorPool(workers=2)
        assert isinstance(pool.worker_backend, ModelledWorkerBackend)
        assert pool.prefers_group_dispatch is False
        assert pool.worker_weights == (1.0, 1.0)

    def test_stub_backend_drives_the_loop(self):
        wb = StubBackend(("a", "b"))
        pool = ExecutorPool(worker_backend=wb)
        assert pool.worker_names == ("a", "b")
        trace = run(get_policy("llf-dynamic"), [fixed_query()], pool)
        assert trace.outcome("q0").complete
        kinds = {c[0] for c in wb.calls}
        assert kinds == {"batch", "agg"}
        # every modelled batch costs exactly the stub duration
        batches = [e for e in trace.executions if e.kind == "batch"]
        assert all(abs((e.end - e.start) - wb.dur) < 1e-12 for e in batches)

    def test_default_shard_group_is_sequential_batches(self):
        wb = StubBackend(("a", "b", "c"))
        dispatches = wb.run_shard_group(
            fixed_query(), (3, 3, 2), 0, ("a", "b", "c"))
        assert [d.worker for d in dispatches] == ["a", "b", "c"]
        assert [c[0] for c in wb.calls] == ["batch"] * 3
        offsets = [c[3] for c in wb.calls]
        assert offsets == [0, 3, 6]

    def test_requeue_is_noop_by_default(self):
        wb = StubBackend(("a",))
        wb.requeue_batch(fixed_query(), 4, 0)  # must not raise
        assert wb.calls == []


class TestShardedCostModel:
    def test_planning_cost_divides_rounding_up(self):
        base = LinearCostModel(tuple_cost=1.0, overhead=1.0)
        cm = ShardedCostModel(base, 4)
        assert cm.cost(8) == base.cost(2)
        assert cm.cost(9) == base.cost(3)     # ceil division
        assert cm.cost(0) == base.cost(0)
        assert cm.shard_cost(8) == base.cost(8)  # modelled clock charge
        assert cm.agg_cost(3) == base.agg_cost(3)

    def test_ways_one_is_identity(self):
        base = LinearCostModel(tuple_cost=0.5, overhead=0.1)
        cm = ShardedCostModel(base, 1)
        for n in (0, 1, 7, 64):
            assert cm.cost(n) == base.cost(n)

    def test_validation(self):
        with pytest.raises(ValueError):
            ShardedCostModel(LinearCostModel(tuple_cost=1.0), 0)


# ---------------------------------------------------------------------------
# per-worker calibration -> weighted shards
# ---------------------------------------------------------------------------


class TestWorkerCalibration:
    def _calibrated(self):
        cal = CalibratingCostModel(LinearCostModel(tuple_cost=1.0))
        for _ in range(6):
            cal.observe(10, 10.0, worker="fast")
            cal.observe(10, 20.0, worker="slow")  # consistently 2x the cost
        return cal

    def test_worker_scale_and_cost(self):
        cal = self._calibrated()
        # The pooled fit absorbs the level; the 2x speed skew survives in
        # the RATIO of the per-worker scales.
        assert cal.worker_scale("slow") == pytest.approx(
            2 * cal.worker_scale("fast"), rel=1e-6)
        assert cal.worker_cost(10, "slow") > cal.worker_cost(10, "fast")
        assert cal.worker_scale("unseen") == 1.0

    def test_worker_weights_inverse_normalized(self):
        cal = self._calibrated()
        w = cal.worker_weights(("fast", "slow"))
        assert sum(w) == pytest.approx(len(w))
        assert w[0] == pytest.approx(2 * w[1], rel=1e-6)

    def test_under_two_samples_stays_neutral(self):
        cal = CalibratingCostModel(LinearCostModel(tuple_cost=1.0))
        cal.observe(10, 30.0, worker="w")
        assert cal.worker_scale("w") == 1.0


class TestMeshBackendWeights:
    class _FakeMesh:
        """num_devices is all MeshBackend.__init__ reads off the mesh."""

        def __init__(self, n):
            self.num_devices = n

    def make(self, solo):
        wb = MeshBackend(self._FakeMesh(len(solo)), names=tuple(solo))
        for name, (tuples, secs) in solo.items():
            wb._solo_tuples[name] = tuples
            wb._solo_secs[name] = secs
        return wb

    def test_no_solo_data_is_neutral(self):
        wb = self.make({"a": (0.0, 0.0), "b": (0.0, 0.0)})
        assert wb.worker_weights == (1.0, 1.0)

    def test_below_threshold_noise_is_neutral(self):
        wb = self.make({"a": (100.0, 1.0), "b": (100.0, 1.1)})
        assert wb.worker_weights == (1.0, 1.0)

    def test_heterogeneous_weights_normalize_to_mean_one(self):
        wb = self.make({"a": (100.0, 1.0), "b": (100.0, 2.0)})
        w = wb.worker_weights
        assert sum(w) == pytest.approx(len(w))
        assert w[0] == pytest.approx(2 * w[1], rel=1e-6)

    def test_name_count_must_match_devices(self):
        with pytest.raises(ValueError, match="names"):
            MeshBackend(DeviceMesh(1), names=("a", "b"))


# ---------------------------------------------------------------------------
# MeshBackend end-to-end: real segagg work under the scheduler
# ---------------------------------------------------------------------------


class TestMeshBackendEndToEnd:
    SCALE = StreamScale(scale=0.005)

    def _run(self, devices):
        aq = PAPER_QUERIES[1]  # CQ2: 5 groups
        files = [(line if aq.stream == "lineitem" else o)
                 for _, o, line in
                 stream_files(seed=5, num_files=16, sc=self.SCALE)]
        mesh = DeviceMesh(devices)
        wb = MeshAnalyticsBackend({"q0": (aq, files)}, self.SCALE, mesh)
        pool = ExecutorPool(worker_backend=wb)
        base = LinearCostModel(tuple_cost=1.0, overhead=1.0)
        cm = ShardedCostModel(base, devices) if devices > 1 else base
        query = dataclasses.replace(
            fixed_query("q0", n=16, slack=50.0), cost_model=cm)
        trace = run(get_policy("llf-dynamic", shard_across=devices),
                    [query], pool)
        assert trace.outcome("q0").complete
        return wb, trace

    def test_single_device_matches_oneshot(self):
        wb, _ = self._run(1)
        aq = PAPER_QUERIES[1]
        files = [(line if aq.stream == "lineitem" else o)
                 for _, o, line in
                 stream_files(seed=5, num_files=16, sc=self.SCALE)]
        oneshot, _, _ = run_batched(aq, files, 16, self.SCALE)
        assert np.array_equal(wb.results["q0"].ravel(),
                              np.asarray(oneshot).ravel())

    @needs_devices(2)
    def test_sharded_run_is_exact_and_fused(self):
        wb1, _ = self._run(1)
        wbN, trace = self._run(min(NDEV, 8))
        assert np.array_equal(wbN.results["q0"], wb1.results["q0"])
        # Group dispatch: sharded batches share one fused start/end per
        # group, and every mesh worker participates.
        batches = [e for e in trace.executions if e.kind == "batch"]
        starts = {e.start for e in batches}
        assert len(starts) < len(batches)
        assert {e.worker for e in batches} == set(wbN.worker_names)

    def test_wall_clock_bookkeeping(self):
        wb, _ = self._run(1)
        assert wb.wall_seconds["q0"] > 0.0
