"""Integration tests: real executors driven by the paper's scheduler,
fault-tolerant checkpointing, and the end-to-end training driver."""
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Planner, Query, Strategy, TraceArrival, UniformWindowArrival
from repro.data.tpch import PAPER_QUERIES, StreamScale, stream_files
from repro.serve.analytics import (
    AnalyticsExecutor,
    concat_files,
    measure_cost_model,
    run_batched,
    run_plan,
)

SCALE = StreamScale(scale=0.005)


def _files(stream: str, n: int = 48, seed: int = 3):
    files, times = [], []
    for t, o, l in stream_files(seed=seed, num_files=n, sc=SCALE):
        files.append(l if stream == "lineitem" else o)
        times.append(t)
    return files, times


class TestAnalyticsExecutor:
    @pytest.mark.parametrize("query", PAPER_QUERIES, ids=lambda q: q.query_id)
    def test_partials_equal_oneshot(self, query):
        files, _ = _files(query.stream, 24)
        one, _, _ = run_batched(query, files, 24, SCALE)
        many, _, nb = run_batched(query, files, 5, SCALE)
        assert nb == 5
        np.testing.assert_allclose(one, many, rtol=1e-5, atol=1e-5)

    def test_kernel_path_matches_ref_path(self):
        query = PAPER_QUERIES[1]  # CQ2, 5 groups
        files, _ = _files(query.stream, 8)
        ref, _, _ = run_batched(query, files, 4, SCALE, use_kernel=False)
        ker, _, _ = run_batched(query, files, 4, SCALE, use_kernel=True)
        np.testing.assert_allclose(ref, ker, rtol=1e-4, atol=1e-4)

    def test_scheduled_plan_executes_and_meets_deadline(self):
        query = PAPER_QUERIES[2]
        files, times = _files(query.stream, 48)
        cm = measure_cost_model(query, files, SCALE)
        arr = TraceArrival(timestamps=tuple(times))
        q = Query("it", arr.wind_start, arr.wind_end,
                  arr.wind_end + 1.5 * cm.cost(48), 48, cm, arr)
        plan = Planner(policy="single").schedule(q)
        result, log, agg_s = run_plan(query, files, plan, SCALE)
        oneshot, _, _ = run_batched(query, files, 48, SCALE)
        np.testing.assert_allclose(result, oneshot, rtol=1e-5)
        assert sum(b.num_records for b in log) == sum(
            len(f["ts"]) for f in files)

    def test_jit_cache_shared_across_executors(self):
        """Regression: a per-instance ``jax.jit(lambda ...)`` recompiled the
        segagg kernel for EVERY AnalyticsExecutor; the module-level jitted
        function must compile once per (num_groups, shape)."""
        from repro.serve.analytics import _segagg_ref_jit

        query = PAPER_QUERIES[1]  # CQ2: 5 groups
        files, _ = _files(query.stream, 6)
        batch = concat_files(files[:2])
        before = _segagg_ref_jit._cache_size()
        for _ in range(3):
            ex = AnalyticsExecutor(query, SCALE)
            ex.process_batch(batch)
            ex.process_batch(batch)
        after = _segagg_ref_jit._cache_size()
        assert after - before <= 1  # ONE new entry at most, not one per executor

    def test_recurring_session_real_backend(self):
        """Session mode over real segagg batches: per-window results equal
        the one-shot reference, wall-second feedback calibrates the model."""
        from repro.core import LinearCostModel
        from repro.serve.analytics import run_session

        aq = PAPER_QUERIES[1]  # CQ2: 5 groups
        nw, nf = 2, 6
        windows, wts = [], []
        for w in range(nw):
            files, times = _files(aq.stream, nf, seed=10 + w)
            windows.append(files)
            wts.append([t + w * 10.0 for t in times])
        cm = LinearCostModel(tuple_cost=0.4, overhead=0.3, agg_per_batch=0.2)
        results, trace = run_session(aq, windows, wts, SCALE, cm,
                                     period=10.0, calibrate=True)
        assert sorted(results) == [0, 1]
        for w in range(nw):
            ref, _, _ = run_batched(aq, windows[w], nf, SCALE)
            np.testing.assert_allclose(results[w], ref, rtol=1e-5)
        series = trace.outcome_series(aq.query_id)
        assert [o.complete for o in series] == [True, True]
        kinds = [e.kind for e in trace.events]
        assert kinds.count("window_open") == nw

    def test_straggler_requeue_real_backend(self):
        """C_max straggler re-queue on a REAL backend: a slow ``_execute``
        gets every batch flagged + re-dispatched, and the offset-keyed
        partials make the retry overwrite instead of double-count."""
        from repro.core import LinearCostModel, get_policy, run
        from repro.serve.analytics import AnalyticsRuntimeExecutor

        class SlowAnalytics(AnalyticsRuntimeExecutor):
            def _execute(self, query, num_tuples, offset):
                super()._execute(query, num_tuples, offset)
                return 10.0  # every real batch blows C_max

        query = PAPER_QUERIES[1]
        n = 12
        files, times = _files(query.stream, n)
        cm = LinearCostModel(tuple_cost=0.4, overhead=0.3, agg_per_batch=0.2)
        arr = TraceArrival(timestamps=tuple(times))
        q = Query("st", arr.wind_start, arr.wind_end,
                  arr.wind_end + 5.0 * cm.cost(n), n, cm, arr)
        slow = SlowAnalytics({q.query_id: (query, files)}, SCALE)
        trace = run(get_policy("llf-dynamic", delta_rsf=0.5, c_max=2.0),
                    [q], slow)
        phys = slow.physical(q.query_id)
        n_batches = sum(1 for e in trace.executions if e.kind == "batch")
        assert n_batches > 0
        assert trace.stragglers.count(q.query_id) == n_batches
        # re-dispatch executed each batch twice...
        assert len(phys.batch_log) == 2 * n_batches
        # ...but the offset-keyed partials were overwritten, not appended
        assert phys.num_batches == n_batches
        # and the combined result is exactly the clean one-shot answer
        oneshot, _, _ = run_batched(query, files, n, SCALE)
        np.testing.assert_allclose(slow.results[q.query_id], oneshot,
                                   rtol=1e-5)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        from repro.train.checkpoint import (
            latest_valid, restore_checkpoint, save_checkpoint)

        tree = {"a/w": jnp.arange(12.0).reshape(3, 4),
                "b/x": jnp.ones((5,), jnp.int32)}
        save_checkpoint(tmp_path, 7, tree, extra={"note": "hi"})
        ckpt = latest_valid(tmp_path)
        assert ckpt is not None
        step, restored, extra = restore_checkpoint(ckpt)
        assert step == 7 and extra["note"] == "hi"
        np.testing.assert_array_equal(restored["a/w"], tree["a/w"])

    def test_corrupted_checkpoint_is_skipped(self, tmp_path):
        from repro.train.checkpoint import latest_valid, save_checkpoint

        tree = {"w": jnp.ones((4, 4))}
        save_checkpoint(tmp_path, 1, tree)
        save_checkpoint(tmp_path, 2, tree)
        # corrupt the newest (simulates a node dying mid-write)
        victim = sorted(tmp_path.glob("step_*"))[-1] / "w.npy"
        victim.write_bytes(b"garbage")
        ckpt = latest_valid(tmp_path)
        assert ckpt is not None and ckpt.name == "step_00000001"

    def test_partial_checkpoint_is_skipped(self, tmp_path):
        from repro.train.checkpoint import latest_valid, save_checkpoint

        tree = {"w": jnp.ones((4, 4)), "v": jnp.zeros((2,))}
        save_checkpoint(tmp_path, 1, tree)
        save_checkpoint(tmp_path, 2, tree)
        (sorted(tmp_path.glob("step_*"))[-1] / "v.npy").unlink()
        assert latest_valid(tmp_path).name == "step_00000001"


class TestServingEngine:
    def test_multi_job_llf_serves_all(self):
        from repro.models.base import get_config
        from repro.models.lm import build_specs
        from repro.models.params import init_params
        from repro.serve.engine import (
            PrefillExecutor, WindowJob, serve_multi_jobs)
        from repro.core import LinearCostModel

        cfg = dataclasses.replace(get_config("yi_6b").reduced(),
                                  vocab_size=512)
        params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
        ex = PrefillExecutor(cfg, params, buckets=(1, 2, 4, 8))
        cm = LinearCostModel(tuple_cost=0.02, overhead=0.05)
        rng = np.random.default_rng(0)
        jobs = [
            WindowJob(
                job_id=f"j{i}",
                prompts=rng.integers(0, cfg.vocab_size, (n, 16)).astype(np.int32),
                arrival=UniformWindowArrival(0.0, 10.0, n),
                deadline=10.0 + 3.0 * cm.cost(n),
            )
            for i, n in enumerate((6, 10))
        ]
        report = serve_multi_jobs(jobs, ex, cm, Strategy.LLF,
                                  delta_rsf=0.5, c_max=2.0)
        for j in jobs:
            assert report[j.job_id]["processed"] == j.num_requests
            assert report[j.job_id]["met_modelled"]
            got = np.concatenate(j.results)
            assert got.shape == (j.num_requests, cfg.vocab_size)
            assert np.all(np.isfinite(got))

    def test_serve_session_online_admission(self):
        """Jobs join the continuously running engine one by one; every
        admitted request is served; the session clock carries over."""
        from repro.core import LinearCostModel, UniformWindowArrival
        from repro.models.base import get_config
        from repro.models.lm import build_specs
        from repro.models.params import init_params
        from repro.serve.engine import (
            PrefillExecutor, WindowJob, serve_session)

        cfg = dataclasses.replace(get_config("yi_6b").reduced(),
                                  vocab_size=128)
        params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
        ex = PrefillExecutor(cfg, params, buckets=(1, 2, 4, 8))
        cm = LinearCostModel(tuple_cost=0.02, overhead=0.05)
        rng = np.random.default_rng(0)
        jobs = [
            WindowJob(job_id=f"j{i}",
                      prompts=rng.integers(0, cfg.vocab_size, (n, 8)).astype(
                          np.int32),
                      arrival=UniformWindowArrival(i * 2.0, i * 2.0 + 10.0, n),
                      deadline=i * 2.0 + 10.0 + 3.0 * cm.cost(n))
            for i, n in enumerate((5, 7))
        ]
        report, session = serve_session(jobs, ex, cm, policy="llf-dynamic",
                                        c_max=2.0)
        for j in jobs:
            row = report[j.job_id]
            assert row["admitted"] and row["completed"]
            assert row["processed"] == j.num_requests
            assert row["shortfall"] == 0
            got = np.concatenate(j.results)
            assert got.shape == (j.num_requests, cfg.vocab_size)
        assert session.now >= max(r["completion"] for r in report.values())

    def test_oversized_batch_split_into_bucket_sized_subbatches(self):
        """Regression: n above the largest bucket used to crash run_batch
        with a broadcast ValueError (``padded[:n] = prompts`` with n > b);
        it must split into bucket-sized sub-batches and sum the wall time."""
        from repro.models.base import get_config
        from repro.models.lm import build_specs
        from repro.models.params import init_params
        from repro.serve.engine import PrefillExecutor

        cfg = dataclasses.replace(get_config("yi_6b").reduced(),
                                  vocab_size=128)
        params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
        prefill = PrefillExecutor(cfg, params, buckets=(1, 2, 4, 8, 16, 32))
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, cfg.vocab_size, (40, 8)).astype(np.int32)
        out, dt = prefill.run_batch(prompts)  # n=40 > max bucket 32
        assert out.shape == (40, cfg.vocab_size)
        assert dt > 0.0
        # identical logits to running the same rows in small batches
        # (prefill rows are independent; padding must not leak)
        ref, _ = prefill.run_batch(prompts[32:])
        np.testing.assert_allclose(out[32:], ref, rtol=1e-5, atol=1e-5)


@pytest.mark.slow  # full train-driver loop: the single heaviest test
def test_train_driver_loss_improves(tmp_path):
    """End-to-end driver: a few real steps, loss goes down, checkpoint
    written, resume works (run in-process via main())."""
    import repro.launch.train as trainer

    argv = sys.argv
    sys.argv = ["train", "--arch", "mamba2_370m", "--steps", "8",
                "--batch", "4", "--seq", "32", "--lr", "5e-3",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"]
    try:
        trainer.main()
    finally:
        sys.argv = argv
    from repro.train.checkpoint import latest_valid

    assert latest_valid(tmp_path) is not None
