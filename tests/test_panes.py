"""Pane-based shared execution (repro.core.panes).

Covers: pane-width GCD decomposition, PaneStore refcount/eviction
semantics, the SharedCostModel one-scan-+-k-merges identity, the
share-disabled byte-identity guarantee for all registered policies, the
>=3x cost reduction at 8 overlapping queries (the bench_shared_panes
acceptance gate), session cache carry-over across recurring windows, and —
on the real segagg backend — equality of shared fan-out results with
per-query unshared aggregation over random window sets.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    LinearCostModel,
    Planner,
    Query,
    RecurringQuerySpec,
    Session,
    SublinearCostModel,
    UniformWindowArrival,
    list_policies,
    run,
)
from repro.core.cost_model import SharedCostModel
from repro.core.panes import (
    PaneStore,
    SharedBook,
    pane_width,
    panes_in,
    run_shared,
    share_workload,
)
from repro.core.runtime import DynamicQuerySpec, QueryRuntime
from repro.core.types import PaneSpec

COST = LinearCostModel(tuple_cost=0.05, overhead=0.5, agg_per_batch=0.02)


def shared_queries(k: int, n: int = 64, slide: int = 16,
                   stream: str = "s") -> list:
    """k overlapping windows over one stream, staggered by ``slide``."""
    qs = []
    for i in range(k):
        off = i * slide
        arr = UniformWindowArrival(wind_start=float(off),
                                   wind_end=float(off + n),
                                   num_tuples_total=n)
        qs.append(Query(f"q{i}", arr.wind_start, arr.wind_end,
                        arr.wind_end + 3.0 * COST.cost(n), n, COST, arr,
                        stream=stream, stream_offset=off))
    return qs


# ---------------------------------------------------------------------------
# Decomposition
# ---------------------------------------------------------------------------


class TestDecomposition:
    def test_pane_width_gcd(self):
        assert pane_width([64], [16]) == 16
        assert pane_width([64, 48], [8]) == 8
        assert pane_width([60, 90], []) == 30
        assert pane_width([], []) == 1  # degenerate: no subscribers yet
        assert pane_width([64], [0]) == 64  # zero slide contributes nothing

    def test_panes_in_exact_cover(self):
        panes = panes_in("s", 16, 32, 96)
        assert [p.index for p in panes] == [2, 3, 4, 5]
        assert panes[0].offset == 32 and panes[-1].end == 96
        assert all(p.num_tuples == 16 for p in panes)

    def test_panes_in_misaligned_keeps_fragments_unshared(self):
        # [10, 50) over width 16: only pane 1 ([16,32)) and pane 2 ([32,48))
        # are fully contained; the [10,16) and [48,50) fragments stay out.
        panes = panes_in("s", 16, 10, 50)
        assert [p.index for p in panes] == [1, 2]
        assert panes_in("s", 16, 10, 12) == []

    def test_pane_spec_validation(self):
        with pytest.raises(ValueError):
            PaneSpec(stream="s", index=0, offset=0, num_tuples=0)
        with pytest.raises(ValueError):
            PaneSpec(stream="s", index=-1, offset=0, num_tuples=4)


# ---------------------------------------------------------------------------
# PaneStore refcounts / eviction
# ---------------------------------------------------------------------------


class TestPaneStore:
    def pane(self, i: int) -> PaneSpec:
        return PaneSpec(stream="s", index=i, offset=i * 4, num_tuples=4)

    def test_refcounted_eviction(self):
        store = PaneStore()
        p = self.pane(0)
        store.subscribe(p, "a")
        store.subscribe(p, "b")
        assert store.refcount("s", 0) == 2
        assert store.deposit("s", 0, by="a", data="partial")
        assert store.resident == 1
        assert store.entry("s", 0).data == "partial"
        store.release("s", 0, "a")
        assert store.refcount("s", 0) == 1  # b still needs it: cached
        assert store.resident == 1
        store.release("s", 0, "b")
        assert store.refcount("s", 0) == 0
        assert store.entry("s", 0) is None  # last ref gone: evicted
        assert store.resident == 0
        assert store.stats.scans == 1
        assert store.stats.evictions == 1
        assert store.stats.peak_resident == 1

    def test_deposit_is_idempotent(self):
        store = PaneStore()
        store.subscribe(self.pane(0), "a")
        assert store.deposit("s", 0, by="a", data=1)
        assert not store.deposit("s", 0, by="b", data=2)  # straggler/no-op
        assert store.entry("s", 0).data == 1
        assert store.entry("s", 0).depositor == "a"
        assert store.stats.scans == 1

    def test_unsubscribed_deposit_not_cached(self):
        store = PaneStore()
        assert not store.deposit("s", 7, by="a", data=1)
        assert store.entry("s", 7) is None
        assert store.stats.scans == 0

    def test_release_before_compute_vanishes_silently(self):
        store = PaneStore()
        store.subscribe(self.pane(1), "a")
        store.release("s", 1, "a")
        assert store.entry("s", 1) is None
        assert store.stats.evictions == 0  # nothing was ever cached

    def test_peak_resident_tracks_high_water_mark(self):
        store = PaneStore()
        for i in range(3):
            store.subscribe(self.pane(i), "a")
            store.subscribe(self.pane(i), "b")
            store.deposit("s", i, by="a")
        assert store.stats.peak_resident == 3
        for i in range(3):
            store.release("s", i, "a")
            store.release("s", i, "b")
        assert store.resident == 0
        assert store.stats.peak_resident == 3


# ---------------------------------------------------------------------------
# SharedCostModel
# ---------------------------------------------------------------------------


class TestSharedCostModel:
    def test_one_scan_plus_k_merges_identity(self):
        k, pane, n = 8, 16, 64
        shared = SharedCostModel(COST, sharers=k, pane_tuples=pane)
        merges = COST.merge_cost(n // pane)
        assert shared.cost(n) == pytest.approx(COST.cost(n) / k + merges)
        # summed over the k subscribers: one scan + k merge folds
        assert k * shared.cost(n) == pytest.approx(
            COST.cost(n) + k * merges)

    def test_agg_and_merge_pass_through(self):
        shared = SharedCostModel(COST, sharers=4, pane_tuples=8)
        assert shared.agg_cost(5) == COST.agg_cost(5)
        assert shared.merge_cost(3) == COST.merge_cost(3)
        assert COST.merge_cost(0) == 0.0
        assert COST.merge_cost(1) == COST.agg_cost(2)

    def test_monotone_and_invertible(self):
        shared = SharedCostModel(SublinearCostModel(scale=0.3, overhead=0.4,
                                                    agg_per_batch=0.05),
                                 sharers=3, pane_tuples=8)
        costs = [shared.cost(n) for n in range(0, 120)]
        assert all(b >= a - 1e-12 for a, b in zip(costs, costs[1:]))
        for d in (0.5, 1.0, 3.0):
            n = shared.tuples_processable(d)
            assert shared.cost(n) <= d + 1e-9
            assert shared.cost(n + 1) > d - 1e-9

    def test_validation(self):
        with pytest.raises(ValueError):
            SharedCostModel(COST, sharers=0, pane_tuples=4)
        with pytest.raises(ValueError):
            SharedCostModel(COST, sharers=2, pane_tuples=0)


# ---------------------------------------------------------------------------
# Workload transform
# ---------------------------------------------------------------------------


class TestShareWorkload:
    def test_wraps_groups_and_leaves_rest_alone(self):
        qs = shared_queries(2)
        lone = dataclasses.replace(qs[0], query_id="lone", stream="other")
        private = dataclasses.replace(qs[0], query_id="priv", stream=None)
        specs, book = share_workload([*qs, lone, private])
        by_id = {s.query.query_id: s.query for s in specs}
        assert isinstance(by_id["q0"].cost_model, SharedCostModel)
        assert isinstance(by_id["q1"].cost_model, SharedCostModel)
        assert by_id["q0"].cost_model.sharers == 2
        assert by_id["lone"].cost_model is COST   # alone on its stream
        assert by_id["priv"].cost_model is COST   # no stream at all
        assert book.widths == {"s": pane_width([64], [16])}
        # inputs never mutated
        assert all(q.cost_model is COST for q in qs)

    def test_pane_tuples_override(self):
        specs, book = share_workload(shared_queries(2), pane_tuples=8)
        assert book.widths["s"] == 8
        assert specs[0].query.cost_model.pane_tuples == 8

    def test_pane_aligned_min_batch(self):
        specs, book = share_workload(shared_queries(2))
        policy = Planner(policy="llf-dynamic", c_max=10.0).policy
        rt = QueryRuntime(spec=specs[0])
        policy.on_admit(rt, 0.0)
        width = book.widths["s"]
        assert rt.min_batch % width == 0 or rt.min_batch == rt.q.num_tuples_total
        # unshared sizing is untouched
        rt_u = QueryRuntime(spec=DynamicQuerySpec(query=shared_queries(2)[0]))
        policy.on_admit(rt_u, 0.0)
        assert rt_u.min_batch >= 1


# ---------------------------------------------------------------------------
# Runtime behaviour
# ---------------------------------------------------------------------------


class TestSharedRuntime:
    def test_disabled_sharing_is_trace_identical_for_all_policies(self):
        # Queries may carry stream placement; with share off the runtime
        # must produce byte-identical traces to a plain run for every
        # registered policy.
        qs = shared_queries(3)
        for name in list_policies():
            kw = {"c_max": 10.0} if name.endswith("-dynamic") else {}
            if name == "brute-force":
                continue  # exponential in N=64 — covered by its own suite
            planner = Planner(policy=name, **kw)
            a = planner.run(qs)
            b = run(Planner(policy=name, **kw).policy, qs)
            assert a == b, name
            assert a.pane_book is None

    def test_shared_cost_reduction_floor_at_8_queries(self):
        # The bench_shared_panes acceptance gate, pinned as a test.
        for pane_tuples, regime in ((16, "aligned"), (None, "sliding")):
            qs = shared_queries(8, slide=0 if regime == "aligned" else 8)
            if regime == "aligned":
                qs = [dataclasses.replace(q, stream_offset=0) for q in qs]
            planner = Planner(policy="llf-dynamic", c_max=10.0)
            unshared = planner.run(qs)
            shared, book = run_shared(planner.policy, qs,
                                      pane_tuples=pane_tuples)
            assert shared.all_met
            ratio = unshared.total_cost / shared.total_cost
            assert ratio >= 3.0, (regime, ratio)
            assert book.store.stats.hits > 0

    def test_book_drains_and_counts(self):
        qs = shared_queries(4, n=64, slide=16)
        _, book = run_shared(Planner(policy="llf-dynamic", c_max=10.0).policy,
                             qs)
        stats = book.store.stats
        # distinct panes scanned once each; everything else served as hits
        n_panes = (64 + 3 * 16) // 16
        assert stats.scans == n_panes
        assert stats.hits == 4 * (64 // 16) - n_panes
        assert stats.evictions == stats.scans
        assert book.store.resident == 0 and len(book.store) == 0

    def test_static_policy_shares_too(self):
        qs = shared_queries(4)
        planner = Planner(policy="single")
        unshared = planner.run(qs)
        shared, book = run_shared(planner.policy, qs)
        assert shared.total_cost < unshared.total_cost / 2
        assert book.store.stats.hits > 0
        assert len(book.store) == 0

    def test_unaligned_offsets_still_share(self):
        # Regression: the pane grid is anchored at global stream index 0,
        # so the width must divide the ABSOLUTE offsets — windows at
        # offsets 5/15 with range 10 must land on a 5-tuple grid (not a
        # 10-tuple grid nothing aligns to).
        qs = []
        for i, off in enumerate((5, 10)):
            arr = UniformWindowArrival(float(off), float(off + 10), 10)
            qs.append(Query(f"q{i}", arr.wind_start, arr.wind_end,
                            arr.wind_end + 3.0 * COST.cost(10), 10, COST,
                            arr, stream="s", stream_offset=off))
        specs, book = share_workload(qs)
        assert book.widths["s"] == 5
        assert all(len(book._subs[q.query_id].panes) == 2 for q in qs)
        trace, book = run_shared(
            Planner(policy="llf-dynamic", c_max=10.0).policy, qs)
        assert book.store.stats.hits > 0  # the shared pane actually shared

    def test_fragment_covered_pane_not_cached_and_no_phantom_hits(self):
        # Regression: a pane covered across two batches of one query has
        # no reusable whole-pane partial — it must stay undeposited (a
        # later subscriber computes it properly) and never masquerade as
        # cache activity.
        from repro.core.types import BatchExecution

        book = SharedBook(pane_tuples=8)
        book.register_stream("s", 8)
        qs = shared_queries(3, n=8, slide=0)
        for q in qs:
            q.stream_offset = 0
            book.register(q)
        # q0 straddles the pane: 5 + 3 tuples
        book.observe(BatchExecution("q0", 0.0, 1.0, 5))
        book.observe(BatchExecution("q0", 1.0, 2.0, 3))
        stats = book.store.stats
        assert stats.fragment_scans == 1
        assert stats.scans == 0 and stats.hits == 0
        entry = book.store.entry("s", 0)
        assert entry is not None and not entry.computed
        # q1 covers the pane in ONE batch: deposits it...
        book.observe(BatchExecution("q1", 2.0, 3.0, 8))
        assert stats.scans == 1 and stats.hits == 0
        # ...and q2 gets a genuine hit; last release evicts.
        book.observe(BatchExecution("q2", 3.0, 4.0, 8))
        assert stats.hits == 1
        assert book.store.resident == 0

    def test_withdraw_releases_refs(self):
        specs, book = share_workload(shared_queries(2))
        sub = book._subs["q1"]
        assert book.store.refcount("s", sub.panes[0].index) >= 1
        book.withdraw("q1")
        assert all(book.store.refcount("s", p.index) <= 1 for p in sub.panes)
        book.withdraw("q1")  # idempotent
        book.close()
        assert len(book.store) == 0


# ---------------------------------------------------------------------------
# Session: cache carry-over across recurring windows
# ---------------------------------------------------------------------------


class TestSessionSharing:
    def sliding_spec(self, n=32, slide=8, windows=6):
        arr = UniformWindowArrival(wind_start=0.0, wind_end=float(n),
                                   num_tuples_total=n)
        base = Query("recur", 0.0, arr.wind_end,
                     arr.wind_end + 4.0 * COST.cost(n), n, COST, arr,
                     stream="sensor", stream_offset=0)
        # period == slide's share of the window: windows overlap in BOTH
        # time and stream position, exactly the pane-sharing regime.
        return RecurringQuerySpec(base=base, period=float(slide),
                                  num_windows=windows, slide_tuples=slide)

    def test_panes_carry_over_across_windows(self):
        s = Session(policy="llf-dynamic", c_max=10.0, sharing=True)
        res = s.submit(self.sliding_spec())
        assert res.admitted
        s.run()
        stats = s.pane_stats
        # windows 1.. reuse the panes their predecessors scanned
        assert stats.hits > 0
        assert stats.scans < stats.scans + stats.hits
        # refcounted eviction drained the cache with the last window
        assert s.book.store.resident == 0
        series = s.trace.outcome_series("recur")
        assert len(series) == 6 and all(o.complete for o in series)

    def test_session_sharing_cheaper_than_unshared(self):
        spec = self.sliding_spec()
        su = Session(policy="llf-dynamic", c_max=10.0)
        su.submit(spec)
        su.run()
        ss = Session(policy="llf-dynamic", c_max=10.0, sharing=True)
        ss.submit(self.sliding_spec())
        ss.run()
        assert ss.trace.total_cost < su.trace.total_cost

    def test_tumbling_single_spec_does_not_share(self):
        # slide == range: no overlap, nothing to share — the session must
        # not wrap cost models or touch the store.
        arr = UniformWindowArrival(wind_start=0.0, wind_end=32.0,
                                   num_tuples_total=32)
        base = Query("tumble", 0.0, 32.0, 32.0 + 4.0 * COST.cost(32), 32,
                     COST, arr, stream="sensor")
        spec = RecurringQuerySpec(base=base, period=32.0, num_windows=3)
        s = Session(policy="llf-dynamic", c_max=10.0, sharing=True)
        s.submit(spec)
        s.run()
        assert s.pane_stats.scans == 0 and s.pane_stats.hits == 0

    def test_session_withdraw_releases_panes(self):
        s = Session(policy="llf-dynamic", c_max=10.0, sharing=True)
        s.submit(self.sliding_spec(windows=None))
        s.run_until(20.0)
        s.withdraw("recur")
        s.run_until(200.0)
        assert s.book.store.resident == 0

    def test_incompatible_spec_runs_unshared(self):
        # Regression: a later spec whose geometry the established pane
        # width does not divide must run UNSHARED (no amortized cost
        # model, no subscriptions) instead of promising amortization the
        # grid cannot deliver — and it must not inflate the sharer count.
        s = Session(policy="llf-dynamic", c_max=10.0, sharing=True)
        s.submit(self.sliding_spec(n=32, slide=8))          # width -> 8
        arr = UniformWindowArrival(0.0, 12.0, 12)           # range 12: 12 % 8 != 0
        base = Query("odd", 0.0, 12.0, 12.0 + 4.0 * COST.cost(12), 12,
                     COST, arr, stream="sensor")
        s.submit(RecurringQuerySpec(base=base, period=12.0, num_windows=2))
        assert s.trace.events_for("pane_incompatible")
        assert s._runtime._live["odd"].pane_ok is False
        assert not s.book.knows("odd#w0")  # no pane subscriptions
        s.run()
        # every window of the incompatible spec ran on its plain model
        for o in s.trace.outcome_series("odd"):
            assert o.complete

    def test_withdraw_resyncs_sharers(self):
        # Regression: withdrawing a sharer must re-amortize the surviving
        # in-flight windows' SharedCostModels (documented mutability).
        s = Session(policy="llf-dynamic", c_max=10.0, sharing=True)
        s.submit(self.sliding_spec(n=32, slide=8, windows=None))
        arr = UniformWindowArrival(0.0, 32.0, 32)
        other = Query("other", 0.0, 32.0, 32.0 + 4.0 * COST.cost(32), 32,
                      COST, arr, stream="sensor")
        s.submit(RecurringQuerySpec(base=other, period=8.0,
                                    num_windows=None, slide_tuples=8))
        s.run_until(10.0)
        models = [m for _, m in s._runtime._shared_models["sensor"]]
        assert models and all(m.sharers == 8 for m in models)  # 4 + 4
        s.withdraw("other")
        live = [m for qid, m in s._runtime._shared_models["sensor"]
                if not s.book._subs[qid].done]
        assert live and all(m.sharers == 4 for m in live)
        s.withdraw("recur")

    def test_pane_tuples_requires_sharing(self):
        with pytest.raises(ValueError):
            Session(policy="llf-dynamic", pane_tuples=8)
        with pytest.raises(ValueError):
            Planner(policy="single").run(shared_queries(2), pane_tuples=8)


# ---------------------------------------------------------------------------
# Real-backend fan-out equality (property-style)
# ---------------------------------------------------------------------------


def _real_stream(num_files: int):
    from repro.data.tpch import PAPER_QUERIES, StreamScale, stream_files

    scale = StreamScale(scale=0.005)
    aq = PAPER_QUERIES[1]  # CQ2: small group count
    files = [l if aq.stream == "lineitem" else o
             for _, o, l in stream_files(seed=11, num_files=num_files,
                                         sc=scale)]
    return aq, files, scale


def _direct_groupby(aq, files, scale, lo, hi):
    recs = {k: np.concatenate([f[k] for f in files[lo:hi]])
            for k in files[0]}
    keys = np.asarray(aq.key_fn(recs))
    vals = np.asarray(aq.value_fn(recs), np.float32)
    if vals.ndim == 1:
        vals = vals[:, None]
    out = np.zeros((aq.num_groups(scale), vals.shape[1]), np.float32)
    np.add.at(out, keys, vals)
    return out


def _check_windows(windows):
    from repro.serve.analytics import run_shared_jobs

    aq, files, scale = _real_stream(max(hi for lo, n in windows
                                        for hi in (lo + n,)))
    cm = LinearCostModel(tuple_cost=0.02, overhead=0.1, agg_per_batch=0.01)
    shared, _, book = run_shared_jobs(aq, files, windows, scale, cm,
                                      share=True, c_max=5.0)
    unshared, _, _ = run_shared_jobs(aq, files, windows, scale, cm,
                                     share=False, c_max=5.0)
    for i, (lo, n) in enumerate(windows):
        qid = f"{aq.query_id}-w{i}"
        np.testing.assert_allclose(shared[qid], unshared[qid],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            shared[qid], _direct_groupby(aq, files, scale, lo, lo + n),
            rtol=1e-4, atol=1e-4,
        )
    return book


class TestSharedFanOutEquality:
    def test_overlapping_windows_match_unshared(self):
        book = _check_windows([(0, 16), (4, 16), (8, 16)])
        assert book.store.stats.hits > 0

    def test_random_window_sets(self):
        # Deterministic sweep over random window sets; the hypothesis
        # variant below widens the net when the dependency is installed.
        rng = np.random.default_rng(0)
        for _ in range(3):
            k = int(rng.integers(2, 5))
            windows = []
            for _ in range(k):
                n = int(rng.integers(2, 13))
                lo = int(rng.integers(0, 20 - n))
                windows.append((lo, n))
            _check_windows(windows)

    def test_hypothesis_random_window_sets(self):
        hypothesis = pytest.importorskip(
            "hypothesis", reason="hypothesis not installed"
        )
        from hypothesis import given, settings, strategies as st

        window = st.tuples(st.integers(0, 12), st.integers(2, 8))

        @settings(max_examples=5, deadline=None)
        @given(st.lists(window, min_size=2, max_size=4))
        def inner(windows):
            _check_windows([(lo, n) for lo, n in windows])

        inner()


class _StoreBackend:
    """Real-backend stand-in that interacts with the pane store the way
    ``SharedAnalyticsExecutor`` does — folding cached partials at merge
    cost, scanning + depositing uncached panes — and reports a fixed wall
    time so C_max straggling is controllable."""

    def __init__(self, book, wall: float = 1.0):
        from repro.core.runtime import BaseExecutor

        class _Inner(BaseExecutor):
            def __init__(inner):
                super().__init__()
                inner.pane_scans = 0
                inner.pane_merges = 0
                inner.fragment_scans = 0

            def _execute(inner, query, num_tuples, offset):
                width = book.widths.get(query.stream,
                                        max(query.num_tuples_total, 1))
                store = book.store
                pos = query.stream_offset + offset
                end = pos + num_tuples
                while pos < end:
                    idx = pos // width
                    lo, hi = idx * width, (idx + 1) * width
                    if pos == lo and hi <= end:
                        e = store.entry(query.stream, idx)
                        if e is not None and e.computed and e.data is not None:
                            inner.pane_merges += 1
                        else:
                            inner.pane_scans += 1
                            store.deposit(query.stream, idx,
                                          by=query.query_id, data=object())
                        pos = hi
                    else:
                        inner.fragment_scans += 1
                        pos = min(hi, end)
                return wall

        self.executor = _Inner()


class TestStragglerSharedWindow:
    """Regression: a C_max straggler re-queue used to run AFTER the
    SharedBook had already observed the batch (releasing/evicting its
    panes), so the re-execution rescanned partials it had just shared and
    attempted re-deposits on evicted panes.  The requeue now settles
    BEFORE the book observes."""

    @staticmethod
    def _run(c_max):
        qs = []
        for i in range(2):
            arr = UniformWindowArrival(wind_start=0.0, wind_end=7.0,
                                       num_tuples_total=8)
            qs.append(Query(f"q{i}", 0.0, 7.0, 200.0, 8, COST, arr,
                            stream="s", stream_offset=0))
        specs, book = share_workload(qs, pane_tuples=4)
        backend = _StoreBackend(book, wall=1.0).executor
        trace = run(Planner(policy="llf-dynamic", c_max=1e9).policy,
                    specs, backend, sharing=book, c_max=c_max)
        book.close()
        return trace, book, backend

    def test_requeue_does_not_rescan_shared_panes(self):
        clean_trace, clean_book, clean_be = self._run(c_max=None)
        strag_trace, strag_book, strag_be = self._run(c_max=0.5)
        assert clean_trace.stragglers == []
        assert len(strag_trace.stragglers) > 0
        # identical physical scan work: every requeued batch folded its
        # panes from the still-live cache instead of rescanning
        assert strag_be.pane_scans == clean_be.pane_scans
        assert strag_be.fragment_scans == clean_be.fragment_scans
        # book-level accounting identical too (no double deposit/release)
        cs, ss = clean_book.store.stats, strag_book.store.stats
        assert (ss.scans, ss.hits, ss.fragment_scans, ss.evictions) == (
            cs.scans, cs.hits, cs.fragment_scans, cs.evictions)
        # and the modelled traces agree batch for batch
        assert strag_trace.executions == clean_trace.executions
        assert strag_trace.outcomes == clean_trace.outcomes

    def test_requeued_merges_cost_merge_not_scan(self):
        _, _, be = self._run(c_max=0.5)
        # the requeues re-read every full pane through the cache
        assert be.pane_merges > 0
