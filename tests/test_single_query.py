"""Paper §3.1 worked examples (Fig 2, Cases 1-4) + Algorithm-1 invariants."""
import pytest

from repro.core import (
    ConstantRateArrival,
    InfeasibleDeadline,
    LinearCostModel,
    Query,
    SublinearCostModel,
    execute_single,
    plan_cost,
    schedule_single,
    validate_schedule,
)

# This suite exists to pin down the LEGACY shim API, so it opts back out
# of the project-wide DeprecationWarning-as-error filter (pyproject.toml).
pytestmark = pytest.mark.filterwarnings("default::DeprecationWarning")



def paper_query(deadline: float) -> Query:
    """§3.1 example: window [1, 10], 1 tuple/s, 10 tuples, cost model
    'two tuples per time unit' with no overhead."""
    arr = ConstantRateArrival(wind_start=1.0, rate=1.0, num_tuples_total=10)
    assert arr.wind_end == 10.0
    return Query(
        query_id=f"paper-d{deadline}",
        wind_start=1.0,
        wind_end=10.0,
        deadline=deadline,
        num_tuples_total=10,
        cost_model=LinearCostModel(tuple_cost=0.5),
        arrival=arr,
    )


class TestPaperCases:
    def test_case1_positive_slack(self):
        # deadline 16: slack = 16 - 10 - 5 = +1 -> single batch at t=11.
        q = paper_query(16.0)
        plan = schedule_single(q)
        assert plan.num_batches == 1
        assert plan.batches[0].sched_time == pytest.approx(11.0)
        assert plan.batches[0].num_tuples == 10
        validate_schedule(q, plan)

    def test_case2_zero_slack(self):
        # deadline 15: slack = 0 -> single batch starting exactly at window end.
        q = paper_query(15.0)
        plan = schedule_single(q)
        assert plan.num_batches == 1
        assert plan.batches[0].sched_time == pytest.approx(10.0)
        validate_schedule(q, plan)

    def test_case3_two_batches(self):
        # deadline 12: last batch 4 tuples in [10,12]; pending 6 available at
        # t=6, processed in [7,10] (paper: "scheduled at time 7").
        q = paper_query(12.0)
        plan = schedule_single(q)
        assert plan.sch_tuples == [6, 4]
        assert plan.sch_points == pytest.approx([7.0, 10.0])
        validate_schedule(q, plan)

    def test_case4_three_batches(self):
        # deadline 11: batches of 4 @ t=6, 4 @ t=8, 2 @ t=10 (paper Fig 2).
        q = paper_query(11.0)
        plan = schedule_single(q)
        assert plan.sch_tuples == [4, 4, 2]
        assert plan.sch_points == pytest.approx([6.0, 8.0, 10.0])
        validate_schedule(q, plan)

    def test_infeasible_deadline(self):
        # deadline 10.4: after window end only 0.4 time units -> cannot even
        # finish the final tuple (arrives at t=10, needs 0.5).
        with pytest.raises(InfeasibleDeadline):
            schedule_single(paper_query(10.4))

    def test_execution_matches_plan_cost(self):
        q = paper_query(11.0)
        plan = schedule_single(q)
        trace = execute_single(q, plan)
        out = trace.outcomes[0]
        assert out.met_deadline
        assert out.num_batches == 3
        assert out.total_cost == pytest.approx(plan_cost(q, plan))


class TestGeneralModels:
    def test_overhead_model_prefers_fewer_batches(self):
        # Processing (20 tuples/s + 1.0 per-batch overhead) faster than
        # arrival (10/s): minCompCost = 6.0, window [0, 9.9].
        cm = LinearCostModel(tuple_cost=0.05, overhead=1.0)
        arr = ConstantRateArrival(wind_start=0.0, rate=10.0, num_tuples_total=100)
        loose = Query("loose", 0.0, arr.wind_end, 17.0, 100, cm, arr)
        tight = Query("tight", 0.0, arr.wind_end, 13.0, 100, cm, arr)
        pl, pt = schedule_single(loose), schedule_single(tight)
        validate_schedule(loose, pl)
        validate_schedule(tight, pt)
        assert pl.num_batches <= pt.num_batches
        assert plan_cost(loose, pl) <= plan_cost(tight, pt)

    def test_sublinear_model(self):
        cm = SublinearCostModel(scale=0.1, exponent=0.8, agg_per_batch=0.2)
        arr = ConstantRateArrival(wind_start=0.0, rate=5.0, num_tuples_total=200)
        q = Query("sub", 0.0, arr.wind_end, arr.wind_end + 3.0, 200, cm, arr)
        plan = schedule_single(q)
        validate_schedule(q, plan)

    def test_agg_cost_shifts_last_batch(self):
        # With per-batch agg cost, the multi-batch plan must complete the last
        # batch agg_cost earlier (Eq. 4).
        cm = LinearCostModel(tuple_cost=0.5, agg_per_batch=0.25)
        arr = ConstantRateArrival(wind_start=1.0, rate=1.0, num_tuples_total=10)
        q = Query("agg", 1.0, 10.0, 12.0, 10, cm, arr)
        plan = schedule_single(q)
        validate_schedule(q, plan)  # validate includes agg in finish time
        assert plan.num_batches >= 2
