"""Predictive-scheduling tests (repro.core.forecast + session wiring).

Coverage: the forecaster's fit (level/trend/bands/burstiness), the offered-
arrival unwrapping, the forecast stand-in query, proactive shedding at
window roll-over (forecast session meets deadlines a reactive session
misses), the mid-window forecast-miss check with shed refund, the public
``Session.history()`` record, Cameo-style per-query latency targets in the
dynamic policies, and speculative pane pre-warming counters.
"""
import dataclasses
import math

import pytest

from repro.core import (
    ArrivalForecaster,
    ArrivalObservation,
    ForecastConfig,
    LinearCostModel,
    OverloadConfig,
    Planner,
    Query,
    RecurringQuerySpec,
    Session,
    ShiftedArrival,
    SpecHistory,
    ThinnedArrival,
    TraceArrival,
    UniformWindowArrival,
    forecast_query,
    list_policies,
    observe_arrival,
    offered_arrival,
)
from repro.core.session import SessionRuntime

SPAN = 100.0


def ev(trace, kind, qid=None):
    """Session events of ``kind``, optionally filtered to one window id."""
    return [e for e in trace.events_for(kind)
            if qid is None or e.query_id == qid]


def uniform_arr(start: float = 0.0, n: int = 100,
                span: float = SPAN) -> UniformWindowArrival:
    return UniformWindowArrival(wind_start=start, wind_end=start + span,
                                num_tuples_total=n)


def burst_arr(start: float = 0.0, n: int = 100, span: float = SPAN,
              burst: float = 20.0) -> UniformWindowArrival:
    """All n tuples in the LAST ``burst`` time units of the window."""
    return UniformWindowArrival(wind_start=start + span - burst,
                                wind_end=start + span, num_tuples_total=n)


def recurring_burst(qid: str = "r", n: int = 100, windows: int = 6,
                    slack: float = 30.0, tuple_cost: float = 1.0,
                    burst: float = 20.0, tier: int = 0,
                    truths: dict = None) -> RecurringQuerySpec:
    """Recurring query PREDICTED uniform but TRULY bursty: every window's
    tuples land in the last ``burst`` time units.  ``truths`` overrides the
    truth of individual windows (window index -> arrival)."""
    base = Query(
        query_id=qid, wind_start=0.0, wind_end=SPAN, deadline=SPAN + slack,
        num_tuples_total=n, cost_model=LinearCostModel(tuple_cost=tuple_cost),
        arrival=uniform_arr(0.0, n), tier=tier,
    )
    overrides = truths or {}

    def truth(w: int):
        if w in overrides:
            return overrides[w]
        return burst_arr(w * SPAN, n, burst=burst)

    return RecurringQuerySpec(base=base, period=SPAN, num_windows=windows,
                              truth_factory=truth)


# ---------------------------------------------------------------------------
# Observations + forecaster fit
# ---------------------------------------------------------------------------


class TestObservation:
    def test_uniform_burstiness_is_one(self):
        obs = observe_arrival(uniform_arr(0.0, 100), window=3)
        assert obs.window == 3
        assert obs.num_tuples == 100
        assert obs.burstiness == pytest.approx(1.0, abs=0.1)
        assert obs.mean_rate == pytest.approx(1.0)

    def test_tail_burst_burstiness(self):
        # Everything in the last 1/5 of the window, observed against the
        # FULL window frame: the peak 1/8-segment holds ~half the tuples
        # -> burstiness ~4-5.
        obs = observe_arrival(burst_arr(0.0, 100, burst=20.0),
                              wind_start=0.0, wind_end=SPAN)
        assert obs.burstiness > 3.0
        assert obs.mean_rate == pytest.approx(1.0)

    def test_own_frame_default(self):
        # Without a frame override the arrival's own span is the frame:
        # the same burst reads as uniform.
        obs = observe_arrival(burst_arr(0.0, 100, burst=20.0))
        assert obs.burstiness == pytest.approx(1.0, abs=0.2)

    def test_offered_unwraps_thinning_preserves_shift(self):
        base = uniform_arr(0.0, 100)
        thin = ThinnedArrival(base=ThinnedArrival(base=base, keep=50),
                              keep=20)
        shifted = ShiftedArrival(base=thin, shift=7.0)
        off = offered_arrival(shifted)
        assert isinstance(off, ShiftedArrival)
        assert off.shift == 7.0
        assert off.num_tuples_total == 100
        assert offered_arrival(base) is base

    def test_observation_span_properties(self):
        obs = ArrivalObservation(window=0, wind_start=10.0, wind_end=10.0,
                                 num_tuples=5)
        assert obs.span == 0.0
        assert math.isinf(obs.mean_rate)


class TestForecaster:
    def test_constant_series_converges(self):
        f = ArrivalForecaster(ForecastConfig(alpha=0.5, min_history=2))
        for w in range(6):
            f.observe(ArrivalObservation(window=w, wind_start=w * SPAN,
                                         wind_end=(w + 1) * SPAN,
                                         num_tuples=80))
        fc = f.forecast(6)
        assert fc.tuples == pytest.approx(80.0, abs=1.0)
        assert fc.std == pytest.approx(0.0, abs=1e-6)
        assert fc.contains(80)
        assert not fc.contains(200)

    def test_linear_trend_extrapolates_exactly_at_alpha_one(self):
        f = ArrivalForecaster(ForecastConfig(alpha=1.0))
        for w, n in enumerate((10, 20, 30, 40)):
            f.observe(ArrivalObservation(window=w, wind_start=w * SPAN,
                                         wind_end=(w + 1) * SPAN,
                                         num_tuples=n))
        assert f.forecast(4).tuples == pytest.approx(50.0)

    def test_ready_gate(self):
        f = ArrivalForecaster(ForecastConfig(min_history=3))
        assert f.forecast(0) is None
        for w in range(2):
            f.observe(ArrivalObservation(window=w, wind_start=0.0,
                                         wind_end=SPAN, num_tuples=10))
            assert not f.ready
        f.observe(ArrivalObservation(window=2, wind_start=0.0,
                                     wind_end=SPAN, num_tuples=10))
        assert f.ready

    def test_band_widens_on_noise(self):
        smooth = ArrivalForecaster(ForecastConfig(alpha=0.5))
        noisy = ArrivalForecaster(ForecastConfig(alpha=0.5))
        for w in range(8):
            smooth.observe(ArrivalObservation(
                window=w, wind_start=0.0, wind_end=SPAN, num_tuples=100))
            noisy.observe(ArrivalObservation(
                window=w, wind_start=0.0, wind_end=SPAN,
                num_tuples=100 + (60 if w % 2 else -60)))
        assert noisy.forecast(8).std > smooth.forecast(8).std + 1.0

    def test_config_validation(self):
        with pytest.raises(ValueError):
            ForecastConfig(alpha=0.0)
        with pytest.raises(ValueError):
            ForecastConfig(z=-1.0)
        with pytest.raises(ValueError):
            ForecastConfig(min_history=0)
        with pytest.raises(ValueError):
            ForecastConfig(miss_check_frac=0.0)
        with pytest.raises(ValueError):
            ForecastConfig(miss_tolerance=1.5)


class TestForecastQuery:
    def _query(self, n: int = 100) -> Query:
        return Query(query_id="q", wind_start=0.0, wind_end=SPAN,
                     deadline=SPAN + 30.0, num_tuples_total=n,
                     cost_model=LinearCostModel(tuple_cost=1.0),
                     arrival=uniform_arr(0.0, n))

    def _burst_forecaster(self, rounds: int = 3) -> ArrivalForecaster:
        f = ArrivalForecaster(ForecastConfig(alpha=1.0))
        for w in range(rounds):
            f.observe(observe_arrival(burst_arr(0.0, 100, burst=20.0),
                                      window=w, wind_start=0.0,
                                      wind_end=SPAN))
        return f

    def test_compresses_into_window_tail(self):
        fc = self._burst_forecaster().forecast(3)
        q = self._query()
        fq = forecast_query(q, fc)
        assert fq.query_id == q.query_id
        assert fq.num_tuples_total == 100        # planned count, not forecast
        assert fq.wind_end == SPAN
        assert fq.wind_start > SPAN / 2          # compressed to the tail
        assert fq.arrival.tuples_available(fq.wind_start) <= 1
        assert fq.arrival.tuples_available(SPAN) == 100

    def test_uniform_forecast_is_noop(self):
        f = ArrivalForecaster(ForecastConfig(alpha=1.0))
        for w in range(3):
            f.observe(observe_arrival(uniform_arr(0.0, 100), window=w))
        q = self._query()
        assert forecast_query(q, f.forecast(3)) is q

    def test_expected_by_curve(self):
        f = self._burst_forecaster().forecast(3)
        bs = f.burst_span(0.0, SPAN)
        assert 10.0 < bs < 40.0
        assert f.expected_by(SPAN - bs, 0.0, SPAN) == pytest.approx(0.0)
        assert f.expected_by(SPAN, 0.0, SPAN) == pytest.approx(f.lower)
        mid = f.expected_by(SPAN - bs / 2, 0.0, SPAN)
        assert 0.0 < mid < f.lower


# ---------------------------------------------------------------------------
# Proactive replanning in sessions
# ---------------------------------------------------------------------------


class TestProactiveSession:
    # Window instantiation runs ONE period ahead of the clock (the next
    # window is planned when the previous one is admitted), and window w
    # closes ~w*SPAN+180: with min_history=2 the first window whose
    # roll-over sees a ready forecaster is w4.
    FIRST_SHED = 4

    def _run(self, forecast, windows: int = 8):
        s = SessionRuntime(policy="llf-dynamic", overload=True,
                           forecast=forecast)
        s.submit(recurring_burst(windows=windows))
        s.run()
        return s

    def test_reactive_session_misses_tail_bursts(self):
        s = self._run(forecast=None)
        outs = s.trace.outcome_series("r")
        assert len(outs) == 8
        # 100 cost arriving in the last 20 units vs a +30 slack deadline:
        # every window finishes ~50 late.
        assert all(not o.met_deadline for o in outs)
        assert not ev(s.trace, "forecast_shed")

    def test_forecast_session_sheds_proactively_and_meets(self):
        s = self._run(forecast=True)
        outs = s.trace.outcome_series("r")
        assert len(outs) == 8
        # Early windows learn (and miss, like the reactive run); later
        # windows are shed BEFORE their burst lands and meet.
        early = outs[:self.FIRST_SHED]
        late = outs[self.FIRST_SHED:]
        assert all(not o.met_deadline for o in early)
        assert all(o.met_deadline for o in late)
        assert all(0.0 < o.shed_fraction < 0.9 for o in late)
        assert all(o.error_bound > 0 for o in late)
        for w in range(self.FIRST_SHED, 8):
            shed_ev = ev(s.trace, "forecast_shed", f"r#w{w}")
            assert len(shed_ev) == 1
            assert "fraction=" in shed_ev[0].detail
        fcr = s.forecaster("r")
        assert fcr is not None and fcr.ready
        assert fcr.hits >= 1
        assert fcr.misses == 0

    def test_forecast_refund_on_miss(self):
        # Early windows teach a tail burst; window 4's tuples arrive EVEN
        # later than forecast, so at the mid-burst check (nearly) nothing
        # has arrived -> miss recorded, shed refunded, forecaster held.
        spec = recurring_burst(
            windows=8, truths={4: burst_arr(4 * SPAN, 100, burst=4.0)})
        s = SessionRuntime(policy="llf-dynamic", overload=True, forecast=True)
        s.submit(spec)
        s.run()
        assert len(ev(s.trace, "forecast_shed", "r#w4")) == 1
        assert len(ev(s.trace, "forecast_refund", "r#w4")) == 1
        # refunded: the full window ran (all 100 true tuples ingested)
        out = next(o for o in s.trace.outcome_series("r")
                   if o.query_id == "r#w4")
        assert out.shed_fraction == 0.0
        assert out.tuples_processed == 100
        fcr = s.forecaster("r")
        assert fcr.misses >= 1
        # w5 was planned before the miss was detected (one-window lead),
        # but the hold kept w6 from being proactively shed.
        assert not ev(s.trace, "forecast_shed", "r#w6")

    def test_forecast_none_traces_identical_all_policies(self):
        # forecast=None leaves every session trace byte-identical to a
        # session that never heard of forecasting, and on a FEASIBLE
        # workload even forecast=True only watches — the observation
        # machinery must not perturb scheduling.
        for name in list_policies():
            a = SessionRuntime(policy=name, overload=True)
            b = SessionRuntime(policy=name, overload=True, forecast=None)
            c = SessionRuntime(policy=name, overload=True, forecast=True)
            for s in (a, b, c):
                s.submit(recurring_burst(windows=3, slack=120.0))
                s.run()
            assert a.trace.executions == b.trace.executions
            assert a.trace.outcomes == b.trace.outcomes
            assert ([(e.kind, e.time, e.query_id) for e in a.trace.events]
                    == [(e.kind, e.time, e.query_id) for e in b.trace.events])
            assert not ev(c.trace, "forecast_shed")
            assert a.trace.executions == c.trace.executions
            assert a.trace.outcomes == c.trace.outcomes

    def test_static_policy_proactive_shed(self):
        # Static sessions plan every window whose start falls inside the
        # horizon, so drive the timeline stepwise: each window is then
        # planned after earlier windows have closed and taught the
        # forecaster.
        s = SessionRuntime(policy="single", overload=True, forecast=True)
        s.submit(recurring_burst(windows=8))
        for t in range(100, 900, 100):
            s.run_until(float(t))
        s.run()
        shed = [w for w in range(8)
                if ev(s.trace, "forecast_shed", f"r#w{w}")]
        assert shed and min(shed) >= 2
        outs = {o.query_id: o for o in s.trace.outcome_series("r")}
        for w in shed:
            assert outs[f"r#w{w}"].shed_fraction > 0


class TestHistory:
    def test_history_collects_without_forecast(self):
        s = SessionRuntime(policy="llf-dynamic", calibrate=True)
        s.submit(recurring_burst(windows=4, slack=200.0))
        s.run()
        h = s.history("r")
        assert isinstance(h, SpecHistory)
        assert h.base_id == "r"
        assert h.num_windows_observed == 4
        assert all(o.burstiness > 2.0 for o in h.arrivals)
        assert [o.window for o in h.arrivals] == [0, 1, 2, 3]
        assert len(h.cost_samples) > 0
        assert all(n > 0 and c > 0 for n, c in h.cost_samples)
        assert h.shed_fraction == 0.0

    def test_history_dict_and_unknown_id(self):
        s = SessionRuntime(policy="llf-dynamic")
        s.submit(recurring_burst(qid="a", windows=2, slack=200.0))
        s.run()
        all_h = s.history()
        assert set(all_h) == {"a"}
        with pytest.raises(KeyError):
            s.history("nope")

    def test_facade_exposes_history_and_forecaster(self):
        s = Session(policy="llf-dynamic", forecast=True, overload=True)
        s.submit(recurring_burst(windows=3))
        s.run()
        assert s.history("r").num_windows_observed == 3
        assert s.forecaster("r") is not None
        assert s.forecaster("r").num_observations == 3


# ---------------------------------------------------------------------------
# Cameo-style latency targets
# ---------------------------------------------------------------------------


class TestLatencyTargets:
    def _pair(self, target: float = 5.0):
        cm = LinearCostModel(tuple_cost=1.0)
        arr = TraceArrival(timestamps=(0.0,) * 10)
        mk = lambda qid, lt: Query(
            query_id=qid, wind_start=0.0, wind_end=0.0, deadline=100.0,
            num_tuples_total=10, cost_model=cm, arrival=arr,
            latency_target=lt)
        return mk("loose", None), mk("tight", target)

    def test_target_time_property(self):
        loose, tight = self._pair(5.0)
        assert loose.target_time == loose.deadline
        assert tight.target_time == 5.0
        huge = dataclasses.replace(tight, latency_target=1000.0)
        assert huge.target_time == huge.deadline  # never past the deadline

    @pytest.mark.parametrize("policy", ["edf-dynamic", "llf-dynamic"])
    def test_tight_target_runs_first(self, policy):
        loose, tight = self._pair(5.0)
        trace = Planner(policy=policy).run([loose, tight])
        batches = [e for e in trace.executions if e.kind == "batch"]
        assert batches[0].query_id == "tight"
        outs = {o.query_id: o for o in trace.outcomes}
        assert outs["tight"].latency_target == 5.0
        assert outs["tight"].target_time == 5.0
        assert outs["loose"].latency_target is None
        assert outs["loose"].target_time is None
        assert outs["loose"].met_target == outs["loose"].met_deadline

    def test_met_target_vs_met_deadline(self):
        loose, tight = self._pair(5.0)
        trace = Planner(policy="edf-dynamic").run([loose, tight])
        outs = {o.query_id: o for o in trace.outcomes}
        # tight runs first: 10 cost <= ... target is 5, so it MISSES the
        # target (10 > 5) while easily meeting the 100 deadline.
        assert outs["tight"].met_deadline
        assert not outs["tight"].met_target
        assert outs["loose"].met_deadline

    def test_no_targets_byte_identical(self):
        cm = LinearCostModel(tuple_cost=1.0)
        arr = uniform_arr(0.0, 40)
        qs = [Query(query_id=f"q{i}", wind_start=0.0, wind_end=SPAN,
                    deadline=SPAN + 40 + 7 * i, num_tuples_total=40,
                    cost_model=cm, arrival=arr) for i in range(3)]
        for name in list_policies():
            t1 = Planner(policy=name).run([dataclasses.replace(q) for q in qs])
            t2 = Planner(policy=name).run([dataclasses.replace(q) for q in qs])
            assert t1.executions == t2.executions

    def test_recurring_spec_propagates_target(self):
        base = Query(query_id="r", wind_start=0.0, wind_end=SPAN,
                     deadline=SPAN + 30, num_tuples_total=10,
                     cost_model=LinearCostModel(tuple_cost=0.1),
                     arrival=uniform_arr(0.0, 10), latency_target=4.0)
        spec = RecurringQuerySpec(base=base, period=SPAN, num_windows=3)
        q2 = spec.window_query(2)
        assert q2.latency_target == 4.0
        assert q2.target_time == q2.wind_end + 4.0


# ---------------------------------------------------------------------------
# Speculative pane pre-warming
# ---------------------------------------------------------------------------


class TestPrewarm:
    def _sliding_spec(self, windows: int = 8) -> RecurringQuerySpec:
        n, slide = 100, 50
        base = Query(
            query_id="s", wind_start=0.0, wind_end=SPAN,
            deadline=SPAN + 400.0, num_tuples_total=n,
            cost_model=LinearCostModel(tuple_cost=0.05),
            arrival=uniform_arr(0.0, n), stream="clicks",
        )
        return RecurringQuerySpec(base=base, period=SPAN / 2,
                                  num_windows=windows, slide_tuples=slide)

    def test_prewarm_hits_and_stats(self):
        s = SessionRuntime(policy="llf-dynamic", sharing=True, forecast=True)
        s.submit(self._sliding_spec())
        s.run()
        st = s.pane_stats
        assert st.speculative_deposits > 0
        assert st.speculative_hits > 0
        # every pre-warm resolved: hits + misses == deposits
        assert (st.speculative_hits + st.speculative_misses
                == st.speculative_deposits)
        assert ev(s.trace, "pane_prewarm")

    def test_no_prewarm_without_forecast(self):
        s = SessionRuntime(policy="llf-dynamic", sharing=True)
        s.submit(self._sliding_spec())
        s.run()
        st = s.pane_stats
        assert st.speculative_deposits == 0
        assert st.speculative_hits == 0
        assert not ev(s.trace, "pane_prewarm")

    def test_prewarm_disabled_by_config(self):
        s = SessionRuntime(policy="llf-dynamic", sharing=True,
                           forecast=ForecastConfig(prewarm=False))
        s.submit(self._sliding_spec())
        s.run()
        assert s.pane_stats.speculative_deposits == 0

    def test_sharing_traces_identical_with_prewarm(self):
        # Pre-warming only re-times pane computation; the session's
        # executions and outcomes are untouched (simulation bookkeeping).
        a = SessionRuntime(policy="llf-dynamic", sharing=True)
        b = SessionRuntime(policy="llf-dynamic", sharing=True, forecast=True)
        for s in (a, b):
            s.submit(self._sliding_spec())
            s.run()
        assert a.trace.executions == b.trace.executions
        assert a.trace.outcomes == b.trace.outcomes
