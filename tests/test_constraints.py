"""Paper §3.2: constraint-based scheduling must match Algorithm 1 on linear
cost models (the paper reports identical results "in all the cases we
considered" — we make that a property)."""
import pytest

from repro.core import (
    ConstantRateArrival,
    InfeasibleDeadline,
    LinearCostModel,
    Query,
    brute_force_optimal,
    schedule_single,
    schedule_via_constraints,
    validate_schedule,
)

# This suite exists to pin down the LEGACY shim API, so it opts back out
# of the project-wide DeprecationWarning-as-error filter (pyproject.toml).
pytestmark = pytest.mark.filterwarnings("default::DeprecationWarning")



def paper_query(deadline: float) -> Query:
    arr = ConstantRateArrival(wind_start=1.0, rate=1.0, num_tuples_total=10)
    return Query(
        query_id=f"p{deadline}",
        wind_start=1.0,
        wind_end=10.0,
        deadline=deadline,
        num_tuples_total=10,
        cost_model=LinearCostModel(tuple_cost=0.5),
        arrival=arr,
    )


def test_paper_case3_solver():
    # §3.2: "the optimiser solved the case-3 query using 2 batches of size 6
    # and 4 tuples respectively".
    plan = schedule_via_constraints(paper_query(12.0))
    assert plan.sch_tuples == [6, 4]


def test_paper_case4_solver():
    # §3.2: "case-4 is solved in 3 batches of sizes 4, 4, and 2".
    plan = schedule_via_constraints(paper_query(11.0))
    assert plan.sch_tuples == [4, 4, 2]


def test_solver_matches_algorithm1_and_bruteforce():
    for deadline in (16.0, 15.0, 13.0, 12.0, 11.5, 11.0, 10.6):
        q = paper_query(deadline)
        a1 = schedule_single(q)
        cs = schedule_via_constraints(q)
        assert a1.num_batches == cs.num_batches, deadline
        assert a1.sch_tuples == cs.sch_tuples, deadline
        validate_schedule(q, cs)
        bf = brute_force_optimal(q, max_batches=4)
        assert bf is not None
        assert bf[0] == a1.num_batches, deadline


def test_solver_rejects_nonlinear():
    from repro.core import SublinearCostModel

    arr = ConstantRateArrival(wind_start=0.0, rate=1.0, num_tuples_total=5)
    q = Query("nl", 0.0, 4.0, 8.0, 5, SublinearCostModel(scale=0.3), arr)
    with pytest.raises(TypeError):
        schedule_via_constraints(q)


def test_solver_infeasible():
    with pytest.raises(InfeasibleDeadline):
        schedule_via_constraints(paper_query(10.2), max_batches=16)
